"""AOT path sanity: lowering to HLO text works, the text is parseable by
the XLA side (contains an ENTRY computation with the right parameter
count), and the manifest emitter records consistent metadata.

Full numeric round-trips through the PJRT loader are covered by the Rust
integration tests; these tests keep the python half honest in isolation.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, galore_step, model


def lower_text(fn, *specs):
    lowered = jax.jit(fn).lower(*specs)
    return aot.to_hlo_text(lowered)


class TestHloText:
    def test_adam_step_lowers_to_entry(self):
        one = aot.spec((1,))
        w = aot.spec((8, 16))
        text = lower_text(galore_step.adam_step, w, w, w, w, one, one)
        assert "ENTRY" in text
        # 6 parameters wired through.
        assert text.count("parameter(") == 6
        assert "f32[8,16]" in text

    def test_galore_step_contains_dots(self):
        # The fused step must contain the projection matmuls.
        one = aot.spec((1,))
        w = aot.spec((16, 32))
        m = aot.spec((4, 32))
        p = aot.spec((16, 4))
        text = lower_text(galore_step.galore_adam_step, w, m, m, w, p, one, one)
        assert "ENTRY" in text
        assert "dot(" in text or "dot." in text  # projection matmuls survive

    def test_model_train_artifact_param_count(self):
        cfg = model.CONFIGS["nano"]
        n = len(model.param_shapes(cfg))
        pspecs = [aot.spec(s) for s in model.param_shapes(cfg)]
        tok = aot.spec((2, cfg.seq), jnp.int32)
        import functools

        text = lower_text(functools.partial(model.loss_and_grads, cfg), *(pspecs + [tok, tok]))
        # Fusion subcomputations also contain parameter() lines; count only
        # the ENTRY computation's parameters.
        entry = text[text.index("ENTRY"):]
        assert entry.count("parameter(") == n + 2

    def test_no_serialized_proto_in_interchange(self):
        # Guard against regressing to .serialize() (64-bit-id protos the
        # runtime rejects): the emitter must produce *text*.
        one = aot.spec((1,))
        w = aot.spec((4, 4))
        text = lower_text(galore_step.adam_step, w, w, w, w, one, one)
        assert text.isprintable() or "\n" in text


class TestEmitter:
    def test_manifest_entries_consistent(self, tmp_path):
        em = aot.Emitter(str(tmp_path))
        one = aot.spec((1,))
        w = aot.spec((8, 8))
        em.emit(
            "adam_step_8x8",
            galore_step.adam_step,
            [w, w, w, w, one, one],
            {"kind": "adam_step", "m": 8, "n": 8, "n_outputs": 3},
        )
        em.write_manifest()
        man = json.load(open(tmp_path / "manifest.json"))
        assert len(man["artifacts"]) == 1
        a = man["artifacts"][0]
        assert a["inputs"] == [[8, 8]] * 4 + [[1]] * 2
        assert a["input_dtypes"] == ["f32"] * 6
        assert a["n_outputs"] == 3
        assert os.path.exists(tmp_path / a["file"])

    def test_emitter_caches(self, tmp_path):
        em = aot.Emitter(str(tmp_path))
        one = aot.spec((1,))
        w = aot.spec((8, 8))
        args = [w, w, w, w, one, one]
        em.emit("x", galore_step.adam_step, args, {"kind": "adam_step", "n_outputs": 3})
        mtime = os.path.getmtime(tmp_path / "x.hlo.txt")
        em2 = aot.Emitter(str(tmp_path))  # force=False: reuse
        em2.emit("x", galore_step.adam_step, args, {"kind": "adam_step", "n_outputs": 3})
        assert os.path.getmtime(tmp_path / "x.hlo.txt") == mtime


class TestShapeHelpers:
    def test_galore_shapes_short_side_first_after_norm(self):
        cfg = model.CONFIGS["micro"]
        shapes = aot.galore_shapes(cfg)
        assert (cfg.dim, cfg.dim) in shapes
        assert (cfg.dim, cfg.intermediate) in shapes
        assert (cfg.intermediate, cfg.dim) in shapes

    def test_default_ranks_quarter_and_half(self):
        cfg = model.CONFIGS["micro"]
        assert aot.default_ranks(cfg) == [cfg.dim // 4, cfg.dim // 2]

    @pytest.mark.parametrize("name", ["nano", "micro"])
    def test_ranks_below_min_target_dim(self, name):
        cfg = model.CONFIGS[name]
        for r in aot.default_ranks(cfg):
            assert r < min(cfg.dim, cfg.intermediate)
