"""L2 correctness: model shapes, loss behaviour, gradient structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.CONFIGS["nano"]


def make_batch(cfg, b=2, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (b, cfg.seq), 0, cfg.vocab, jnp.int32)
    targets = jax.random.randint(k2, (b, cfg.seq), 0, cfg.vocab, jnp.int32)
    return tokens, targets


class TestSchema:
    @pytest.mark.parametrize("name", ["nano", "micro", "mini", "small", "60m", "1b", "7b"])
    def test_names_match_shapes(self, name):
        cfg = model.CONFIGS[name]
        assert len(model.param_names(cfg)) == len(model.param_shapes(cfg))
        assert len(model.param_names(cfg)) == 3 + 9 * cfg.layers

    def test_param_counts_match_paper(self):
        # Total trainable parameters should land near the nominal size.
        # Note: the paper's own Table 5 shapes for "1B" (2048/5461/24h/32L)
        # compute to 1.74B parameters including embeddings; we check the
        # shapes, so the band is wide.
        for name, lo, hi in [("60m", 45e6, 80e6), ("130m", 100e6, 170e6),
                             ("350m", 280e6, 430e6), ("1b", 0.9e9, 1.9e9),
                             ("7b", 6e9, 8e9)]:
            cfg = model.CONFIGS[name]
            total = sum(int(np.prod(s)) for s in model.param_shapes(cfg))
            assert lo < total < hi, (name, total)

    def test_head_dim_divides(self):
        for cfg in model.CONFIGS.values():
            assert cfg.dim % cfg.heads == 0


class TestForward:
    def test_logits_shape(self):
        params = model.init_params(CFG)
        tokens, _ = make_batch(CFG)
        logits = model.forward(CFG, params, tokens)
        assert logits.shape == (2, CFG.seq, CFG.vocab)
        assert jnp.isfinite(logits).all()

    def test_initial_loss_near_uniform(self):
        # With random init the loss should be close to log(vocab).
        params = model.init_params(CFG)
        tokens, targets = make_batch(CFG)
        loss = model.loss_fn(CFG, params, tokens, targets)
        assert abs(float(loss) - np.log(CFG.vocab)) < 1.0

    def test_causality(self):
        # Changing a future token must not change past logits.
        params = model.init_params(CFG)
        tokens, _ = make_batch(CFG, b=1)
        logits1 = model.forward(CFG, params, tokens)
        tokens2 = tokens.at[0, -1].set((tokens[0, -1] + 1) % CFG.vocab)
        logits2 = model.forward(CFG, params, tokens2)
        np.testing.assert_allclose(logits1[0, :-1], logits2[0, :-1], rtol=1e-4, atol=1e-5)

    def test_rotary_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16), jnp.float32)
        y = model.rotary(x)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-4
        )


class TestGradients:
    def test_grads_shapes_and_finite(self):
        params = model.init_params(CFG)
        tokens, targets = make_batch(CFG)
        out = model.loss_and_grads(CFG, *params, tokens, targets)
        assert len(out) == 1 + len(params)
        for g, s in zip(out[1:], model.param_shapes(CFG)):
            assert g.shape == tuple(s)
            assert jnp.isfinite(g).all()

    def test_one_sgd_step_reduces_loss(self):
        params = model.init_params(CFG)
        tokens, targets = make_batch(CFG)
        out = model.loss_and_grads(CFG, *params, tokens, targets)
        loss0, grads = out[0], out[1:]
        new_params = [p - 0.5 * g for p, g in zip(params, grads)]
        loss1 = model.loss_fn(CFG, new_params, tokens, targets)
        assert float(loss1) < float(loss0)

    def test_gradient_low_rank_trend(self):
        """§3.2: the 2-D weight gradients have low stable rank relative to
        full dimensionality (the motivation for GaLore)."""
        cfg = model.CONFIGS["micro"]
        params = model.init_params(cfg)
        tokens, targets = make_batch(cfg, b=4)
        out = model.loss_and_grads(cfg, *params, tokens, targets)
        grads = out[1:]
        shapes = model.param_shapes(cfg)
        srs = []
        for g, s in zip(grads, shapes):
            if len(s) == 2 and s[0] == cfg.dim and s[1] == cfg.dim:
                sv = jnp.linalg.svd(g, compute_uv=False)
                sr = float(jnp.sum(sv**2) / (sv[0] ** 2))
                srs.append(sr)
        # stable rank well below the full dimension for attention grads
        assert np.median(srs) < cfg.dim / 4, srs
