"""GaLore optimizer-step semantics: the fused step vs a hand-rolled Adam on
the compact gradient, subspace properties, and end-to-end descent on a toy
problem (pure python; the Rust integration tests re-check the same
invariants through the AOT artifacts).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import galore_step
from compile.kernels import ref


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


class TestFusedStepSemantics:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6))
    def test_fused_equals_oracle(self, seed):
        m, n, r = 64, 96, 8
        w, g = rand(seed, m, n), rand(seed + 1, m, n)
        p = rand(seed + 2, m, r)
        mm, vv = rand(seed + 3, r, n, scale=0.01), jnp.abs(rand(seed + 4, r, n, scale=0.01))
        t = jnp.asarray([7.0], jnp.float32)
        la = jnp.asarray([0.0025], jnp.float32)
        got = galore_step.galore_adam_step(w, mm, vv, g, p, t, la)
        want = galore_step.galore_adam_step_ref(w, mm, vv, g, p, t, la)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_full_rank_projection_recovers_adam(self):
        """§3.3: with r = m and orthonormal P, GaLore's update equals
        P P^T (Adam-in-subspace) == the rotated Adam update; with P = I it
        is *exactly* full-rank Adam."""
        m, n = 32, 48
        w, g = rand(0, m, n), rand(1, m, n)
        zeros = jnp.zeros((m, n), jnp.float32)
        t = jnp.asarray([1.0], jnp.float32)
        lr = jnp.asarray([0.001], jnp.float32)
        p = jnp.eye(m, dtype=jnp.float32)
        w_g, m_g, v_g = galore_step.galore_adam_step(w, zeros, zeros, g, p, t, lr)
        w_a, m_a, v_a = galore_step.adam_step(w, zeros, zeros, g, t, lr)
        np.testing.assert_allclose(w_g, w_a, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(m_g, m_a, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(v_g, v_a, rtol=1e-5, atol=1e-7)

    def test_update_stays_in_subspace(self):
        """The weight delta must lie in span(P) (Definition 3.6)."""
        m, n, r = 64, 64, 8
        q, _ = np.linalg.qr(np.asarray(rand(5, m, r)))
        p = jnp.asarray(q, jnp.float32)
        w, g = rand(6, m, n), rand(7, m, n)
        zeros = jnp.zeros((r, n), jnp.float32)
        w2, _, _ = galore_step.galore_adam_step(
            w, zeros, zeros, g, p, jnp.asarray([1.0], jnp.float32), jnp.asarray([0.01], jnp.float32)
        )
        dw = np.asarray(w2 - w)
        # Component orthogonal to span(P) must vanish.
        residual = dw - np.asarray(p) @ (np.asarray(p).T @ dw)
        assert np.abs(residual).max() < 1e-5


class TestProjectorRefresh:
    def test_projector_orthonormal(self):
        g = rand(0, 96, 64, scale=2.0)
        omega = rand(1, 64, 8)
        (p,) = galore_step.projector_refresh(g, omega)
        np.testing.assert_allclose(p.T @ p, jnp.eye(8), atol=5e-3)

    def test_projector_captures_energy(self):
        """P from the refresh must capture at least as much gradient energy
        as a random subspace (and nearly as much as the SVD optimum)."""
        rng = np.random.default_rng(3)
        u, _ = np.linalg.qr(rng.standard_normal((96, 96)))
        v, _ = np.linalg.qr(rng.standard_normal((64, 64)))
        s = np.zeros((96, 64))
        sv = np.array([20, 15, 10, 5, 1, 0.5] + [0.05] * 58)
        np.fill_diagonal(s, sv)
        g = jnp.asarray(u @ s @ v, jnp.float32)
        omega = rand(4, 64, 6)
        (p,) = galore_step.projector_refresh(g, omega, power_iters=6)
        captured = float(jnp.linalg.norm(p.T @ g) ** 2)
        total = float(jnp.linalg.norm(g) ** 2)
        optimal = float((sv[:6] ** 2).sum()) / float((sv**2).sum())
        assert captured / total > 0.95 * optimal


class TestDescentOnToyProblem:
    def _train(self, use_galore, steps=200, r=4, refresh=50):
        """Least-squares y = W* x on a rank-deficient input distribution —
        the Lemma 3.3 setting where gradients become low-rank."""
        rng = np.random.default_rng(0)
        m, n, k = 24, 16, 6  # inputs live in a k-dim subspace
        w_star = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        basis = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        w = jnp.zeros((m, n), jnp.float32)
        mm = jnp.zeros((r, n) if use_galore else (m, n), jnp.float32)
        vv = jnp.zeros_like(mm)
        p = None
        losses = []
        for t in range(1, steps + 1):
            z = jnp.asarray(rng.standard_normal((64, k)), jnp.float32)
            x = z @ basis  # (batch, n)
            err = x @ w.T - x @ w_star.T
            loss = float(jnp.mean(err**2))
            losses.append(loss)
            g = 2.0 * err.T @ x / x.shape[0]  # (m, n)
            tt = jnp.asarray([float(t)], jnp.float32)
            lr = jnp.asarray([0.02], jnp.float32)
            if use_galore:
                if p is None or (t - 1) % refresh == 0:
                    p = ref.topr_subspace(g, r, seed=t)
                    mm = jnp.zeros((r, n), jnp.float32)
                    vv = jnp.zeros_like(mm)
                w, mm, vv = galore_step.galore_adam_step(w, mm, vv, g, p, tt, lr)
            else:
                w, mm, vv = galore_step.adam_step(w, mm, vv, g, tt, lr)
        return losses

    def test_galore_converges_like_adam(self):
        adam = self._train(use_galore=False)
        gal = self._train(use_galore=True)
        assert adam[-1] < 0.05 * adam[0]
        assert gal[-1] < 0.10 * gal[0]  # same order of convergence
