"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes (and the quant block layout); every kernel output
is pinned with assert_allclose against the oracle. These tests are the
authoritative correctness signal for the kernels that end up inside the AOT
artifacts the Rust coordinator executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import galore, quant8, ref

jax.config.update("jax_enable_x64", False)


def rand(key, *shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


dims = st.sampled_from([8, 16, 32, 48, 64, 96, 128, 192, 256])
ranks = st.sampled_from([1, 2, 4, 8, 16, 32])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestProject:
    @settings(max_examples=25, deadline=None)
    @given(m=dims, n=dims, r=ranks, seed=seeds)
    def test_matches_ref(self, m, n, r, seed):
        p = rand(seed, m, r)
        g = rand(seed + 1, m, n)
        np.testing.assert_allclose(
            galore.project(p, g), ref.project_left(p, g), rtol=1e-4, atol=1e-4
        )

    def test_nondivisible_tiles(self):
        # m=96, n=80 with preferred tile 256 -> _tile falls back to divisors.
        p, g = rand(0, 96, 8), rand(1, 96, 80)
        np.testing.assert_allclose(
            galore.project(p, g, bm=64, bn=64), ref.project_left(p, g), rtol=1e-4, atol=1e-4
        )

    def test_identity_projector_roundtrip(self):
        # r = m with orthonormal P: P P^T G == G (the r=min(m,n) property
        # from §3.3 "Difference between GaLore and LoRA").
        m, n = 32, 48
        q, _ = np.linalg.qr(np.asarray(rand(3, m, m)))
        p = jnp.asarray(q, jnp.float32)
        g = rand(4, m, n)
        r = galore.project(p, g)
        back = ref.project_back_left(p, r, 1.0)
        np.testing.assert_allclose(back, g, rtol=1e-4, atol=1e-4)


class TestAdamMoments:
    @settings(max_examples=25, deadline=None)
    @given(r0=ranks, n=dims, t=st.integers(min_value=1, max_value=10_000), seed=seeds)
    def test_matches_ref(self, r0, n, t, seed):
        m = rand(seed, r0, n, scale=0.01)
        v = jnp.abs(rand(seed + 1, r0, n, scale=0.01))
        g = rand(seed + 2, r0, n)
        tt = jnp.asarray([float(t)], jnp.float32)
        m2, v2, nn = galore.adam_moments(m, v, g, tt)
        m2r, v2r, nr = ref.adam_update(m, v, g, float(t))
        np.testing.assert_allclose(m2, m2r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(v2, v2r, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(nn, nr, rtol=1e-3, atol=1e-4)

    def test_bias_correction_step1(self):
        # At t=1 with zero-initialized moments, N == g / (|g| + eps).
        g = rand(7, 4, 32)
        z = jnp.zeros_like(g)
        _, _, n = galore.adam_moments(z, z, g, jnp.asarray([1.0], jnp.float32))
        np.testing.assert_allclose(n, g / (jnp.abs(g) + 1e-8), rtol=1e-4, atol=1e-5)


class TestProjectBack:
    @settings(max_examples=20, deadline=None)
    @given(m=dims, n=dims, r=ranks, seed=seeds)
    def test_matches_ref(self, m, n, r, seed):
        p = rand(seed, m, r)
        nmat = rand(seed + 1, r, n)
        w = rand(seed + 2, m, n)
        la = jnp.asarray([0.005], jnp.float32)
        got = galore.project_back_update(p, nmat, w, la)
        want = w - 0.005 * ref.project_back_left(p, nmat, 1.0)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestFusedStep:
    @settings(max_examples=15, deadline=None)
    @given(m=dims, n=dims, r=ranks, seed=seeds)
    def test_matches_ref(self, m, n, r, seed):
        w = rand(seed, m, n)
        g = rand(seed + 1, m, n)
        p = rand(seed + 2, m, r)
        mm = rand(seed + 3, r, n, scale=0.01)
        vv = jnp.abs(rand(seed + 4, r, n, scale=0.01))
        t = jnp.asarray([5.0], jnp.float32)
        la = jnp.asarray([0.01 * 0.25], jnp.float32)
        w2, m2, v2 = galore.galore_adam_step(w, mm, vv, g, p, t, la)
        w2r, m2r, v2r = ref.galore_adam_step(w, mm, vv, g, p, 5.0, la[0], 1.0)
        np.testing.assert_allclose(m2, m2r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(v2, v2r, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(w2, w2r, rtol=1e-4, atol=1e-5)


class TestQuant8:
    @settings(max_examples=25, deadline=None)
    @given(
        nblocks=st.integers(min_value=1, max_value=64),
        seed=seeds,
        scale=st.sampled_from([1e-4, 1.0, 1e4]),
    )
    def test_matches_ref(self, nblocks, seed, scale):
        x = rand(seed, nblocks * quant8.BLOCK, scale=scale)
        q, s = quant8.quantize_block8(x)
        qr, sr = ref.quantize_block8(x, quant8.BLOCK)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(s, sr, rtol=1e-6)
        np.testing.assert_allclose(
            quant8.dequantize_block8(q, s), ref.dequantize_block8(qr, sr, quant8.BLOCK), rtol=1e-6
        )

    @settings(max_examples=15, deadline=None)
    @given(nblocks=st.integers(min_value=1, max_value=16), seed=seeds)
    def test_roundtrip_error_bound(self, nblocks, seed):
        # absmax quantization error is bounded by absmax/254 per block.
        x = rand(seed, nblocks * quant8.BLOCK)
        q, s = quant8.quantize_block8(x)
        xd = quant8.dequantize_block8(q, s)
        err = np.abs(np.asarray(xd - x)).reshape(nblocks, -1).max(axis=1)
        absmax = np.abs(np.asarray(x)).reshape(nblocks, -1).max(axis=1)
        assert (err <= absmax / 254.0 + 1e-7).all()

    def test_zero_block(self):
        x = jnp.zeros(quant8.BLOCK, jnp.float32)
        q, s = quant8.quantize_block8(x)
        assert np.asarray(q).sum() == 0
        np.testing.assert_allclose(quant8.dequantize_block8(q, s), x)


class TestSubspaceIteration:
    @settings(max_examples=10, deadline=None)
    @given(seed=seeds)
    def test_orthonormal(self, seed):
        y = rand(seed, 64, 8, scale=3.0)
        q = ref.newton_schulz_orthonormalize(y, iters=20)
        np.testing.assert_allclose(q.T @ q, jnp.eye(8), atol=1e-3)

    def test_topr_subspace_matches_svd(self):
        # Construct a matrix with a sharp rank-4 spectrum; the randomized
        # subspace must align with the true top-4 left singular space.
        rng = np.random.default_rng(0)
        u, _ = np.linalg.qr(rng.standard_normal((64, 8)))
        v, _ = np.linalg.qr(rng.standard_normal((48, 8)))
        s = np.diag([10, 8, 6, 5, 0.01, 0.008, 0.005, 0.001])
        g = jnp.asarray(u @ s @ v.T, jnp.float32)
        p = ref.topr_subspace(g, 4, seed=1, power_iters=8)
        u4 = u[:, :4]
        # Principal angles: ||U4^T P|| should have all singular values ~ 1.
        sv = np.linalg.svd(u4.T @ np.asarray(p), compute_uv=False)
        assert sv.min() > 0.999, sv
