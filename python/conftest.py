"""Make `import compile` work whether pytest is invoked from the repo root
(`pytest python/tests/`) or from python/ (`cd python && pytest tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
