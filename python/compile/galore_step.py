"""L2: fused per-layer GaLore-Adam step built from the L1 Pallas kernels.

This is the optimizer-side compute graph that gets AOT-lowered per distinct
(m, n, r) weight shape. A LLaMA block has only a handful of distinct 2-D
shapes (d x d attention, d x i / i x d FFN), so a full model needs just a
few artifacts; the Rust coordinator dispatches each layer's gradient to the
artifact matching its shape.

Also exports ``adam_step`` (the full-rank baseline as an artifact, used by
the bit-exactness tests between the Rust Adam and the HLO Adam) and
``projector_refresh`` (matmul-only randomized subspace iteration for
computing P on-graph).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import galore as gk
from .kernels import ref


def galore_adam_step(w, m, v, g, p, t, lr_alpha, beta1=0.9, beta2=0.999, eps=1e-8):
    """One GaLore-Adam step (Algorithm 2) for a single layer.

    Shapes: w,g (m0,n0); p (m0,r); m,v (r,n0); t, lr_alpha (1,) f32.
    Returns (w', m', v'). Uses the Pallas kernels (interpret mode) so the
    lowered HLO exercises the L1 tiling.
    """
    return gk.galore_adam_step(w, m, v, g, p, t, lr_alpha, beta1=beta1, beta2=beta2, eps=eps)


def galore_adam_step_ref(w, m, v, g, p, t, lr_alpha, beta1=0.9, beta2=0.999, eps=1e-8):
    """Pure-jnp oracle for the fused step (same signature, scalar t/lr)."""
    return ref.galore_adam_step(
        w, m, v, g, p, t[0], 1.0, lr_alpha[0], beta1=beta1, beta2=beta2, eps=eps
    )


def adam_step(w, m, v, g, t, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """Full-rank Adam step on one layer (baseline artifact).

    Shapes: w,g,m,v (m0,n0); t, lr (1,) f32. Returns (w', m', v').
    """
    m_new, v_new, n = ref.adam_update(m, v, g, t[0], beta1, beta2, eps)
    return w - lr[0] * n, m_new, v_new


def projector_refresh(g, omega, power_iters: int = 4):
    """Compute a fresh left projector P from gradient g (m x n) and a fixed
    random sketch omega (n x r), using matmul-only randomized subspace
    iteration (no LAPACK custom-calls — runs on any PJRT backend).

    The Rust coordinator may instead use its own Householder-QR SVD; both
    produce the same subspace up to rotation, which is all GaLore needs
    (Theorem 3.8 holds for any fixed orthonormal P).
    """
    y = g @ omega
    y = ref.newton_schulz_orthonormalize(y)
    for _ in range(power_iters):
        y = g @ (g.T @ y)
        y = ref.newton_schulz_orthonormalize(y)
    return (y,)
