"""L2: LLaMA-style transformer forward/backward in JAX (build-time only).

The architecture follows the paper's experimental setup (§5, Table 5):
RMSNorm, SwiGLU feed-forward, rotary position embeddings, untied LM head,
no biases. The paper's size table (60M..7B) is encoded in
``rust/src/model/config.rs``; this module is parameterized by a
``ModelConfig`` so ``aot.py`` can lower any size (including the scaled-down
proxies used for CPU experiments) to a static-shape HLO artifact.

Lowered entry points (all jitted and exported by aot.py):

  * ``loss_and_grads``  — full fwd + mean next-token cross-entropy + grads
                          w.r.t. every weight (the training-step artifact).
  * ``loss_only``       — fwd + loss (the eval artifact).
  * ``logits_fwd``      — fwd returning logits (serving/inspection).

Parameter order is the *flattened schema order* defined by
``param_names(cfg)`` and mirrored exactly by ``rust/src/model/params.rs``;
the Rust runtime feeds literals in this order and reads gradients back in
this order. Keep the two in lockstep.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model shape. Mirrors rust/src/model/config.rs::ModelConfig."""

    name: str
    vocab: int
    dim: int
    intermediate: int
    heads: int
    layers: int
    seq: int

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


# Scaled-down proxy configs for CPU experiments (see DESIGN.md §3/§4) plus
# the paper's Table 5 shapes (lowered only for memory estimation / shape
# tests, never trained here).
CONFIGS = {
    "nano": ModelConfig("nano", vocab=256, dim=64, intermediate=172, heads=4, layers=2, seq=64),
    "micro": ModelConfig("micro", vocab=512, dim=128, intermediate=344, heads=4, layers=4, seq=64),
    "mini": ModelConfig("mini", vocab=1024, dim=256, intermediate=688, heads=8, layers=4, seq=128),
    "small": ModelConfig("small", vocab=2048, dim=512, intermediate=1376, heads=8, layers=6, seq=128),
    # Paper Table 5 (not trained on CPU; shapes used by the memory estimator)
    "60m": ModelConfig("60m", vocab=32000, dim=512, intermediate=1376, heads=8, layers=8, seq=256),
    "130m": ModelConfig("130m", vocab=32000, dim=768, intermediate=2048, heads=12, layers=12, seq=256),
    "350m": ModelConfig("350m", vocab=32000, dim=1024, intermediate=2736, heads=16, layers=24, seq=256),
    # Paper Table 5 lists 24 heads / 32 layers for 1B, but 2048 % 24 != 0 and
    # the paper memory tables imply ~1.3B params; we use the ReLoRA 1.3B shape.
    "1b": ModelConfig("1b", vocab=32000, dim=2048, intermediate=5461, heads=32, layers=24, seq=256),
    "7b": ModelConfig("7b", vocab=32000, dim=4096, intermediate=11008, heads=32, layers=32, seq=2048),
}


def param_names(cfg: ModelConfig) -> List[str]:
    """Flattened parameter schema; must match rust/src/model/params.rs."""
    names = ["embed.weight"]
    for l in range(cfg.layers):
        names += [
            f"layers.{l}.attn.wq",
            f"layers.{l}.attn.wk",
            f"layers.{l}.attn.wv",
            f"layers.{l}.attn.wo",
            f"layers.{l}.ffn.w_gate",
            f"layers.{l}.ffn.w_up",
            f"layers.{l}.ffn.w_down",
            f"layers.{l}.attn_norm",
            f"layers.{l}.ffn_norm",
        ]
    names += ["final_norm", "lm_head.weight"]
    return names


def param_shapes(cfg: ModelConfig) -> List[Tuple[int, ...]]:
    """Shapes in schema order. All projection matrices are stored (in, out)
    so ``x @ w`` applies them; norm gains are 1-D."""
    d, i, v = cfg.dim, cfg.intermediate, cfg.vocab
    shapes: List[Tuple[int, ...]] = [(v, d)]
    for _ in range(cfg.layers):
        shapes += [
            (d, d),  # wq
            (d, d),  # wk
            (d, d),  # wv
            (d, d),  # wo
            (d, i),  # w_gate
            (d, i),  # w_up
            (i, d),  # w_down
            (d,),    # attn_norm
            (d,),    # ffn_norm
        ]
    shapes += [(d,), (d, v)]
    return shapes


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Scaled-normal init (std = 1/sqrt(fan_in)); norm gains init to 1.

    Only used by python tests; the Rust coordinator owns real initialization
    (rust/src/model/init.rs, identical scheme) so training is reproducible
    without python at run time.
    """
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            std = 1.0 / (shape[0] ** 0.5)
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rotary(x: jax.Array, base: float = 10000.0) -> jax.Array:
    """Apply rotary position embeddings. x: (B, T, H, Dh)."""
    _, t, _, dh = x.shape
    half = dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]  # (T, half)
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention(x: jax.Array, wq, wk, wv, wo, cfg: ModelConfig) -> jax.Array:
    b, t, d = x.shape
    h, dh = cfg.heads, cfg.head_dim
    q = (x @ wq).reshape(b, t, h, dh)
    k = (x @ wk).reshape(b, t, h, dh)
    v = (x @ wv).reshape(b, t, h, dh)
    q = rotary(q)
    k = rotary(k)
    scores = jnp.einsum("bthd,bshd->bhts", q, k) / (dh**0.5)
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", probs, v).reshape(b, t, d)
    return out @ wo


def swiglu(x: jax.Array, w_gate, w_up, w_down) -> jax.Array:
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def unflatten(cfg: ModelConfig, flat: List[jax.Array]):
    """Split the schema-ordered flat list into (embed, layers, final, head)."""
    embed = flat[0]
    layers = []
    idx = 1
    for _ in range(cfg.layers):
        layers.append(tuple(flat[idx : idx + 9]))
        idx += 9
    final_norm, lm_head = flat[idx], flat[idx + 1]
    return embed, layers, final_norm, lm_head


def forward(cfg: ModelConfig, flat_params: List[jax.Array], tokens: jax.Array) -> jax.Array:
    """tokens: (B, T) int32 -> logits (B, T, V)."""
    embed, layers, final_norm, lm_head = unflatten(cfg, flat_params)
    x = embed[tokens]
    for (wq, wk, wv, wo, w_gate, w_up, w_down, attn_norm, ffn_norm) in layers:
        x = x + attention(rmsnorm(x, attn_norm), wq, wk, wv, wo, cfg)
        x = x + swiglu(rmsnorm(x, ffn_norm), w_gate, w_up, w_down)
    x = rmsnorm(x, final_norm)
    return x @ lm_head


def loss_fn(cfg: ModelConfig, flat_params: List[jax.Array], tokens, targets) -> jax.Array:
    """Mean next-token cross-entropy. targets: (B, T) int32 (already shifted)."""
    logits = forward(cfg, flat_params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def loss_and_grads(cfg: ModelConfig, *args):
    """args = (*flat_params, tokens, targets) -> (loss, *grads) tuple."""
    n = len(param_shapes(cfg))
    flat_params = list(args[:n])
    tokens, targets = args[n], args[n + 1]
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens, targets))(flat_params)
    return (loss,) + tuple(grads)


def loss_only(cfg: ModelConfig, *args):
    n = len(param_shapes(cfg))
    flat_params = list(args[:n])
    tokens, targets = args[n], args[n + 1]
    return (loss_fn(cfg, flat_params, tokens, targets),)


def logits_fwd(cfg: ModelConfig, *args):
    n = len(param_shapes(cfg))
    flat_params = list(args[:n])
    tokens = args[n]
    return (forward(cfg, flat_params, tokens),)
