"""AOT compiler: lower L2/L1 JAX graphs to HLO-text artifacts for Rust.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts \
                           [--configs nano,micro,mini,small] [--batch 8]

Emits, per model config:
  train_{cfg}_b{B}.hlo.txt   loss + per-parameter grads (the training step)
  eval_{cfg}_b{B}.hlo.txt    loss only
  fwd_{cfg}_b{B}.hlo.txt     logits (serving/inspection)
and per distinct 2-D weight shape (m, n) with its GaLore ranks r:
  galore_step_{m}x{n}_r{r}.hlo.txt   fused Pallas GaLore-Adam step
  adam_step_{m}x{n}.hlo.txt          full-rank Adam step (baseline/golden)
  proj_refresh_{m}x{n}_r{r}.hlo.txt  matmul-only randomized projector refresh
plus artifacts/manifest.json describing every artifact's I/O signature,
parsed by rust/src/runtime/manifest.rs.

Interchange is HLO *text* (not serialized HloModuleProto): jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import galore_step, model

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32", jnp.int8.dtype: "i8"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape: Sequence[int], dtype=jnp.float32) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Emitter:
    def __init__(self, out_dir: str, force: bool = False):
        self.out_dir = out_dir
        self.force = force
        self.entries: List[dict] = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs: List[jax.ShapeDtypeStruct], meta: dict):
        """Lower fn(*in_specs) to {name}.hlo.txt and record a manifest entry."""
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        n_outputs = meta.pop("n_outputs")
        entry = {
            "name": name,
            "file": fname,
            "inputs": [list(s.shape) for s in in_specs],
            "input_dtypes": [DTYPE_NAMES[s.dtype] for s in in_specs],
            "n_outputs": n_outputs,
            **meta,
        }
        self.entries.append(entry)
        if os.path.exists(path) and not self.force:
            print(f"  [cached] {fname}")
            return
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  [lowered] {fname} ({len(text)/1e3:.0f} kB)")

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"artifacts": self.entries}, f, indent=1)
        print(f"wrote {path} ({len(self.entries)} artifacts)")


def galore_shapes(cfg: model.ModelConfig) -> List[Tuple[int, int]]:
    """Distinct 2-D shapes GaLore is applied to (attention + FFN, not embed
    or lm_head — matching §5.1 'all multi-head attention layers and
    feed-forward layers')."""
    d, i = cfg.dim, cfg.intermediate
    return sorted({(d, d), (d, i), (i, d)})


def default_ranks(cfg: model.ModelConfig) -> List[int]:
    """Paper uses r/d in {1/4, 1/2} (Table 2 uses r = d/4 at 60M, d/3..d/4
    elsewhere); we lower quarter- and half-dim ranks."""
    return sorted({max(4, cfg.dim // 4), max(4, cfg.dim // 2)})


def emit_model_artifacts(em: Emitter, cfg: model.ModelConfig, batch: int):
    n_params = len(model.param_shapes(cfg))
    pspecs = [spec(s) for s in model.param_shapes(cfg)]
    tok = spec((batch, cfg.seq), jnp.int32)

    em.emit(
        f"train_{cfg.name}_b{batch}",
        functools.partial(model.loss_and_grads, cfg),
        pspecs + [tok, tok],
        {"kind": "train", "config": cfg.name, "batch": batch, "n_outputs": 1 + n_params},
    )
    em.emit(
        f"eval_{cfg.name}_b{batch}",
        functools.partial(model.loss_only, cfg),
        pspecs + [tok, tok],
        {"kind": "eval", "config": cfg.name, "batch": batch, "n_outputs": 1},
    )
    em.emit(
        f"fwd_{cfg.name}_b{batch}",
        functools.partial(model.logits_fwd, cfg),
        pspecs + [tok],
        {"kind": "fwd", "config": cfg.name, "batch": batch, "n_outputs": 1},
    )


def emit_optim_artifacts(em: Emitter, shapes: List[Tuple[int, int]], ranks_by_shape):
    one = spec((1,))
    for (m, n) in shapes:
        w = spec((m, n))
        em.emit(
            f"adam_step_{m}x{n}",
            galore_step.adam_step,
            [w, w, w, w, one, one],
            {"kind": "adam_step", "m": m, "n": n, "n_outputs": 3},
        )
        for r in ranks_by_shape[(m, n)]:
            em.emit(
                f"galore_step_{m}x{n}_r{r}",
                galore_step.galore_adam_step,
                [w, spec((r, n)), spec((r, n)), w, spec((m, r)), one, one],
                {"kind": "galore_step", "m": m, "n": n, "r": r, "n_outputs": 3},
            )
            em.emit(
                f"proj_refresh_{m}x{n}_r{r}",
                galore_step.projector_refresh,
                [w, spec((n, r))],
                {"kind": "proj_refresh", "m": m, "n": n, "r": r, "n_outputs": 1},
            )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="nano,micro,mini,small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--force", action="store_true", help="re-lower even if cached")
    args = ap.parse_args()

    em = Emitter(args.out_dir, force=args.force)
    all_shapes: dict = {}
    for name in args.configs.split(","):
        cfg = model.CONFIGS[name.strip()]
        print(f"config {cfg.name}: dim={cfg.dim} layers={cfg.layers} vocab={cfg.vocab}")
        emit_model_artifacts(em, cfg, args.batch)
        for shp in galore_shapes(cfg):
            # Only the short side is projected (§4.2): artifacts are lowered
            # for m <= n; the Rust side transposes tall gradients on entry.
            m, n = shp
            if m > n:
                m, n = n, m
            all_shapes.setdefault((m, n), set()).update(default_ranks(cfg))
    emit_optim_artifacts(em, sorted(all_shapes), {k: sorted(v) for k, v in all_shapes.items()})
    em.write_manifest()


if __name__ == "__main__":
    main()
