"""L1 Pallas kernels for block-wise 8-bit quantization of optimizer states.

Implements the absmax block quantization scheme of Dettmers et al. (2022)
(the scheme behind 8-bit Adam / "8-bit GaLore"): the state tensor is viewed
as contiguous blocks of ``BLOCK`` elements; each block is scaled by its
absolute maximum onto the signed int8 grid [-127, 127].

TPU adaptation: blocks are laid out as VMEM rows of width ``BLOCK`` (256 —
two 128-lane vregs) instead of the 2048-element CUDA thread blocks
bitsandbytes uses; the absmax reduction is a single-lane-axis reduce, and
quantize/dequantize are pure VPU element-wise ops. interpret=True for the
CPU PJRT client (see galore.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]  # (rows, BLOCK)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0]


@functools.partial(jax.jit, static_argnames=("rows_per_step",))
def quantize_block8(x: jax.Array, *, rows_per_step: int = 64):
    """Quantize flat f32 array (size % BLOCK == 0) -> (int8 q, f32 scales)."""
    size = x.size
    assert size % BLOCK == 0, f"size {size} not a multiple of {BLOCK}"
    rows = size // BLOCK
    while rows % rows_per_step != 0:
        rows_per_step -= 1
    xm = x.reshape(rows, BLOCK)
    grid = (rows // rows_per_step,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows_per_step, BLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows_per_step, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=True,
    )(xm)
    return q.reshape(x.shape), s


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("rows_per_step",))
def dequantize_block8(q: jax.Array, scales: jax.Array, *, rows_per_step: int = 64):
    """Inverse of quantize_block8. q int8 (size % BLOCK == 0), scales f32."""
    size = q.size
    rows = size // BLOCK
    while rows % rows_per_step != 0:
        rows_per_step -= 1
    qm = q.reshape(rows, BLOCK)
    grid = (rows // rows_per_step,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_step, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_step,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows_per_step, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32),
        interpret=True,
    )(qm, scales)
    return x.reshape(q.shape)
