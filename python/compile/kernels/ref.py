"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness pins).

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops only. ``python/tests`` sweeps shapes
and dtypes with hypothesis and asserts the kernel output matches the oracle
to tight tolerances. The oracles are also what the Rust-side unit tests are
cross-checked against (fixed seeds, golden values exported by aot.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# GaLore projection (Algorithm 2): R = P^T G  (left) or R = G Q (right)
# ---------------------------------------------------------------------------


def project_left(p: jax.Array, g: jax.Array) -> jax.Array:
    """R = P^T G with P in R^{m x r}, G in R^{m x n} -> R in R^{r x n}."""
    return p.T @ g


def project_right(g: jax.Array, q: jax.Array) -> jax.Array:
    """R = G Q with G in R^{m x n}, Q in R^{n x r} -> R in R^{m x r}."""
    return g @ q


def project_back_left(p: jax.Array, n: jax.Array, alpha) -> jax.Array:
    """dW = alpha * P N with N in R^{r x n} -> dW in R^{m x n}."""
    return alpha * (p @ n)


def project_back_right(n: jax.Array, q: jax.Array, alpha) -> jax.Array:
    """dW = alpha * N Q^T with N in R^{m x r} -> dW in R^{m x n}."""
    return alpha * (n @ q.T)


# ---------------------------------------------------------------------------
# Adam moment update on the compact gradient R (Eqns. 2-4 / Algorithm 2)
# ---------------------------------------------------------------------------


def adam_update(m, v, r, t, beta1=0.9, beta2=0.999, eps=1e-8):
    """One Adam moment update with bias correction.

    Returns (m_new, v_new, n) where n = m_hat / (sqrt(v_hat) + eps).
    ``t`` is the 1-based step count (float32 scalar).
    """
    m_new = beta1 * m + (1.0 - beta1) * r
    v_new = beta2 * v + (1.0 - beta2) * (r * r)
    m_hat = m_new / (1.0 - beta1**t)
    v_hat = v_new / (1.0 - beta2**t)
    n = m_hat / (jnp.sqrt(v_hat) + eps)
    return m_new, v_new, n


def galore_adam_step(w, m, v, g, p, t, lr, alpha, beta1=0.9, beta2=0.999, eps=1e-8):
    """Full fused per-layer GaLore-Adam step (Algorithm 2), left projection.

    w: (m0, n0) weight, g: (m0, n0) gradient, p: (m0, r) projector,
    m/v: (r, n0) moments. Returns (w_new, m_new, v_new).

    Note the paper's Algorithm 2 writes `W_t <- W_{t-1} + eta * G~_t` with
    G_t the *negative* gradient; we follow the conventional sign
    (W <- W - lr * update on the raw gradient), matching the official
    GaLore implementation.
    """
    r = p.T @ g
    m_new, v_new, n = adam_update(m, v, r, t, beta1, beta2, eps)
    dw = alpha * (p @ n)
    w_new = w - lr * dw
    return w_new, m_new, v_new


# ---------------------------------------------------------------------------
# Block-wise 8-bit quantization (Dettmers et al., 2022 style: per-block
# absmax scaling onto a signed-int8 grid). Block size is along the last dim.
# ---------------------------------------------------------------------------


def quantize_block8(x: jax.Array, block: int = 256):
    """Quantize a 1-D-viewable array to int8 with per-block absmax scales.

    Returns (q, scales): q int8 of x.shape, scales f32 of (nblocks,).
    x.size must be a multiple of ``block``.
    """
    flat = x.reshape(-1, block)
    absmax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale[:, 0]


def dequantize_block8(q: jax.Array, scales: jax.Array, block: int = 256):
    flat = q.reshape(-1, block).astype(jnp.float32)
    return (flat * scales[:, None]).reshape(q.shape)


# ---------------------------------------------------------------------------
# Tiled matmul oracle (for the standalone matmul kernel)
# ---------------------------------------------------------------------------


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return a @ b


# ---------------------------------------------------------------------------
# Orthonormalization via subspace (power) iteration -- the SVD-free projector
# refresh used when computing P on-graph. Matmul-only so it lowers to plain
# HLO (no LAPACK custom-calls, which the 0.5.1 CPU client may lack).
# ---------------------------------------------------------------------------


def newton_schulz_orthonormalize(y: jax.Array, iters: int = 12) -> jax.Array:
    """Orthonormalize the columns of y (m x r) by Newton-Schulz iteration.

    Converges when ||Y^T Y - I||_2 < 1; we pre-scale by the Frobenius norm
    which guarantees that. Matmul-only (MXU friendly; no QR custom call).
    """
    r = y.shape[1]
    y = y / (jnp.linalg.norm(y) + 1e-12)
    eye = jnp.eye(r, dtype=y.dtype)
    for _ in range(iters):
        yty = y.T @ y
        y = y @ (1.5 * eye - 0.5 * yty)
    return y


def topr_subspace(g: jax.Array, r: int, seed: int = 0, power_iters: int = 4) -> jax.Array:
    """Approximate top-r left singular subspace of g via randomized subspace
    iteration with Newton-Schulz orthonormalization (matmul-only).

    Returns P (m x r) with orthonormal columns spanning approximately the
    same subspace as U[:, :r] of the SVD of g.
    """
    key = jax.random.PRNGKey(seed)
    omega = jax.random.normal(key, (g.shape[1], r), dtype=g.dtype)
    y = g @ omega
    y = newton_schulz_orthonormalize(y)
    for _ in range(power_iters):
        y = g @ (g.T @ y)
        y = newton_schulz_orthonormalize(y)
    return y
