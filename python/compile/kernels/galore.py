"""L1 Pallas kernels for the GaLore hot spot (Algorithm 2).

Three kernels make up the per-layer GaLore-Adam step:

  1. ``project``       R = P^T G          (rank-r compaction of the gradient)
  2. ``adam_moments``  M,V,N update on R  (element-wise, compact space)
  3. ``project_back``  dW = alpha * P N   (expansion back to weight space)

Hardware adaptation (paper targets CUDA; we target TPU semantics):

* The gradient G (m x n) is streamed tile-by-tile HBM->VMEM with a
  ``BlockSpec`` grid over (m/bm, n/bn); the projector tile P (bm x r) rides
  along the same m-index so each grid step performs an MXU-shaped
  (r x bm) @ (bm x bn) partial product accumulated into the R output block.
  This is the role threadblock shared-memory staging plays in the CUDA
  implementation.
* The Adam update is purely element-wise on (r x n), tiled along n so the
  three compact states (M, V, R) stay resident in VMEM per tile.
* All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
  cannot execute Mosaic custom-calls; real-TPU efficiency is estimated from
  the VMEM footprint of these tilings in DESIGN.md §6.

Correctness for every kernel is pinned against ``ref.py`` by
``python/tests/test_kernels.py`` (hypothesis sweeps over shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes, chosen in DESIGN.md §6 so that per-step VMEM usage
# (G tile + P tile + R accumulator, f32) stays well under 16 MB with
# double-buffering headroom:
#   bm=256, bn=256, r<=1024:  256*256*4 + 256*1024*4 + 1024*256*4 = 2.3 MB.
DEFAULT_BM = 256
DEFAULT_BN = 256


def _tile(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (tiles must divide evenly)."""
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


# ---------------------------------------------------------------------------
# project: R = P^T G
# ---------------------------------------------------------------------------


def _project_kernel(p_ref, g_ref, r_ref):
    """Grid (m/bm, n/bn); accumulate (r x bn) partial products over the
    m-axis. The m-axis is the *innermost* grid dim so r_ref revisits the
    same output block across the accumulation, matching a VMEM-resident
    accumulator on TPU."""
    im = pl.program_id(1)

    @pl.when(im == 0)
    def _init():
        r_ref[...] = jnp.zeros_like(r_ref)

    r_ref[...] += jnp.dot(
        p_ref[...].T, g_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def project(p: jax.Array, g: jax.Array, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN) -> jax.Array:
    """R = P^T G via a tiled Pallas kernel. p: (m, r), g: (m, n) -> (r, n)."""
    m, r = p.shape
    m2, n = g.shape
    assert m == m2, f"shape mismatch {p.shape} vs {g.shape}"
    bm = _tile(m, bm)
    bn = _tile(n, bn)
    grid = (n // bn, m // bm)  # n outer, m inner (accumulation axis)
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda jn, im: (im, 0)),
            pl.BlockSpec((bm, bn), lambda jn, im: (im, jn)),
        ],
        out_specs=pl.BlockSpec((r, bn), lambda jn, im: (0, jn)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=True,
    )(p, g)


# ---------------------------------------------------------------------------
# adam_moments: compact-space Adam with bias correction (Algorithm 2)
# ---------------------------------------------------------------------------


def _adam_kernel(m_ref, v_ref, r_ref, t_ref, m_out, v_out, n_out, *, beta1, beta2, eps):
    t = t_ref[0]
    r = r_ref[...]
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * r
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * (r * r)
    bc1 = 1.0 - beta1**t
    bc2 = 1.0 - beta2**t
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    m_out[...] = m_new
    v_out[...] = v_new
    n_out[...] = m_hat / (jnp.sqrt(v_hat) + eps)


@functools.partial(jax.jit, static_argnames=("beta1", "beta2", "eps", "bn"))
def adam_moments(
    m: jax.Array,
    v: jax.Array,
    r: jax.Array,
    t: jax.Array,
    *,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    bn: int = 1024,
):
    """Element-wise Adam moment update on the compact gradient R (r0 x n).

    t is a float32 (1,) array holding the 1-based step. Returns (M', V', N).
    Tiled along the n axis so each VMEM step holds 3 input + 3 output tiles.
    """
    r0, n = r.shape
    bn = _tile(n, bn)
    grid = (n // bn,)
    kern = functools.partial(_adam_kernel, beta1=beta1, beta2=beta2, eps=eps)
    spec = pl.BlockSpec((r0, bn), lambda j: (0, j))
    tspec = pl.BlockSpec((1,), lambda j: (0,))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[spec, spec, spec, tspec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r0, n), jnp.float32)] * 3,
        interpret=True,
    )(m, v, r, t)


# ---------------------------------------------------------------------------
# project_back: dW = alpha * P N, fused with the weight update W -= lr * dW
# ---------------------------------------------------------------------------


def _project_back_kernel(p_ref, n_ref, w_ref, s_ref, w_out):
    """Grid (m/bm, n/bn): each step computes a (bm x bn) tile of P @ N and
    applies the scaled update to the matching W tile. s_ref = [lr * alpha]."""
    dw = jnp.dot(p_ref[...], n_ref[...], preferred_element_type=jnp.float32)
    w_out[...] = w_ref[...] - s_ref[0] * dw


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def project_back_update(
    p: jax.Array,
    n: jax.Array,
    w: jax.Array,
    lr_alpha: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jax.Array:
    """W' = W - (lr*alpha) * P @ N. p: (m, r), n: (r, n0), w: (m, n0)."""
    m, r = p.shape
    r2, n0 = n.shape
    assert r == r2
    bm = _tile(m, bm)
    bn = _tile(n0, bn)
    grid = (m // bm, n0 // bn)
    return pl.pallas_call(
        _project_back_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n0), jnp.float32),
        interpret=True,
    )(p, n, w, lr_alpha)


# ---------------------------------------------------------------------------
# Fused per-layer step (used by galore_step.py / the AOT artifact)
# ---------------------------------------------------------------------------


def galore_adam_step(w, m, v, g, p, t, lr_alpha, *, beta1=0.9, beta2=0.999, eps=1e-8):
    """Compose the three kernels into one traced step.

    w: (m0, n0), g: (m0, n0), p: (m0, r), m/v: (r, n0),
    t: (1,) f32 1-based step, lr_alpha: (1,) f32 = lr * alpha.
    Returns (w', m', v').
    """
    r = project(p, g)
    m_new, v_new, n = adam_moments(m, v, r, t, beta1=beta1, beta2=beta2, eps=eps)
    w_new = project_back_update(p, n, w, lr_alpha)
    return w_new, m_new, v_new
