//! Text generation through the forward artifact: train a nano model
//! briefly with GaLore, then sample continuations token-by-token via the
//! `fwd_*` AOT artifact (greedy / temperature sampling on the Rust side).
//! Demonstrates that the same artifact set serves inference — python stays
//! out of the loop end to end.
//!
//!   cargo run --release --example generate [-- steps temperature]

use galore::config::{MethodKind, RunConfig};
use galore::coordinator::Trainer;
use galore::model::ModelConfig;
use galore::rng::Rng;
use galore::runtime::Input;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(
        if galore::exp::scale::fast_mode() { 30 } else { 150 },
    );
    let temperature: f32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.8);

    let model = ModelConfig::by_name("nano").unwrap();
    let mut cfg = RunConfig::new(model, MethodKind::GaLore);
    cfg.steps = steps;
    cfg.galore.update_freq = 50;
    println!("training nano with GaLore for {steps} steps...");
    let mut trainer = Trainer::from_config(cfg)?;
    for s in 0..steps {
        let loss = trainer.train_step()?;
        if s % (steps / 5).max(1) == 0 {
            println!("  step {s:>4} loss {loss:.3}");
        }
    }

    // Greedy/temperature sampling with the fwd artifact (full-context
    // re-scoring each token; the nano seq is short enough that a KV cache
    // is unnecessary).
    let artifact = format!("fwd_{}_b{}", model.name, trainer.cfg.batch);
    trainer.engine.prepare(&artifact)?;
    let meta = trainer.engine.meta(&artifact)?.clone();
    let (b, t) = (meta.batch.unwrap(), model.seq);
    let mut rng = Rng::new(42);
    // Seed context from a held-out shard.
    let seed_batch = trainer.loader.eval_batch(7);
    let prompt_len = 8;
    let mut tokens = seed_batch.tokens.clone();
    // Zero everything after the prompt in row 0 (the row we generate).
    for i in prompt_len..t {
        tokens[i] = 0;
    }
    println!("\nprompt: {:?}", &tokens[..prompt_len]);
    for pos in prompt_len..t.min(prompt_len + 48) {
        let mut inputs: Vec<Input> = Vec::with_capacity(trainer.params.len() + 1);
        for p in &trainer.params.tensors {
            inputs.push(Input::F32(&p.data));
        }
        inputs.push(Input::I32(&tokens));
        let outs = trainer.engine.execute(&artifact, &inputs)?;
        // logits: (b, t, v); take row 0, position pos-1.
        let v = model.vocab;
        let off = (pos - 1) * v; // row 0 offset
        let logits = &outs[0].data[off..off + v];
        let next = sample(logits, temperature, &mut rng);
        tokens[pos] = next as i32;
        let _ = b;
    }
    println!("generated: {:?}", &tokens[..prompt_len + 48.min(t - prompt_len)]);
    println!("\n(token ids from the synthetic-C4 vocabulary; a model trained on the");
    println!(" byte corpus would decode to text via data::ByteTokenizer)");
    Ok(())
}

fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    if temperature <= 0.0 {
        return logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
    }
    let max = logits.iter().cloned().fold(f32::MIN, f32::max);
    let probs: Vec<f64> = logits.iter().map(|&l| (((l - max) / temperature) as f64).exp()).collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.next_f64() * total;
    for (i, p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i;
        }
    }
    probs.len() - 1
}
