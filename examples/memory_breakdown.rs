//! Reproduce Fig. 1 (7B memory breakdown) and the §5.5 numbers from the
//! analytic estimator at the paper's true shapes. No artifacts needed.
//!
//!   cargo run --release --example memory_breakdown

use galore::memory::{activations_bytes, estimate, fmt_gib, Method, TrainOpts};
use galore::model::ModelConfig;

fn main() {
    let m7b = ModelConfig::by_name("7b").unwrap();
    let opts = TrainOpts { token_batch: 256, ..Default::default() };
    let lw = TrainOpts { layerwise_updates: true, ..opts };

    println!("=== Fig. 1: LLaMA 7B memory breakdown, token batch 256 ===\n");
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "method", "weights", "optim", "grads", "activ", "TOTAL"
    );
    let rows: Vec<(&str, Method, TrainOpts)> = vec![
        ("BF16 Adam (baseline)", Method::FullRank, opts),
        ("8-bit Adam", Method::Adam8bit, opts),
        ("8-bit GaLore (retain grad)", Method::GaLore8bit { rank: 1024 }, opts),
        ("8-bit GaLore (layerwise)", Method::GaLore8bit { rank: 1024 }, lw),
    ];
    for (name, method, o) in &rows {
        let b = estimate(m7b, *method, *o);
        println!(
            "{:<34} {:>9} {:>9} {:>9} {:>9} {:>9}",
            name,
            fmt_gib(b.weights),
            fmt_gib(b.optim_states),
            fmt_gib(b.gradients),
            fmt_gib(b.activations),
            fmt_gib(b.total())
        );
    }
    let bf16 = estimate(m7b, Method::FullRank, opts).total();
    let a8 = estimate(m7b, Method::Adam8bit, opts).total();
    let g8 = estimate(m7b, Method::GaLore8bit { rank: 1024 }, lw).total();
    println!("\npaper §5.5: 8-bit GaLore saves 63.3% vs BF16 Adam, 52.3% vs 8-bit Adam");
    println!(
        "ours:       {:.1}% vs BF16 Adam, {:.1}% vs 8-bit Adam",
        100.0 * (1.0 - g8 as f64 / bf16 as f64),
        100.0 * (1.0 - g8 as f64 / a8 as f64)
    );
    println!(
        "fits RTX 4090 (24G): {}  — the paper's headline claim",
        if g8 < 24_000_000_000 { "YES" } else { "NO" }
    );

    println!("\n=== activation checkpointing (§5.5: batch up to 4096 tokens) ===");
    for tokens in [256usize, 500, 4096] {
        let plain = activations_bytes(m7b, tokens, false);
        let ckpt = activations_bytes(m7b, tokens, true);
        let total =
            estimate(m7b, Method::GaLore8bit { rank: 1024 }, TrainOpts { layerwise_updates: true, token_batch: tokens, ..Default::default() })
                .total()
                - plain
                + ckpt;
        println!(
            "  {tokens:>5} tokens: activations {} -> {} (ckpt), total w/ ckpt {} (<24G: {})",
            fmt_gib(plain),
            fmt_gib(ckpt),
            fmt_gib(total),
            total < 24_000_000_000
        );
    }
}
