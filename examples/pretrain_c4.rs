//! End-to-end pre-training driver — the recorded run of EXPERIMENTS.md.
//!
//! Trains a LLaMA-family model on the synthetic-C4 stream with 8-bit
//! GaLore + per-layer weight updates (the paper's headline configuration),
//! logs the loss curve to runs/pretrain_<model>_<method>.csv, evaluates on
//! held-out shards, and reports throughput and the memory story
//! (measured optimizer state vs the analytic estimator).
//!
//!   cargo run --release --example pretrain_c4 -- [model] [method] [steps]
//!   e.g. cargo run --release --example pretrain_c4 -- micro galore8bit 600

use galore::config::{MethodKind, RunConfig};
use galore::coordinator::Trainer;
use galore::memory::{estimate, fmt_gib, Method, TrainOpts};
use galore::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = args.first().map(String::as_str).unwrap_or("micro");
    let method_name = args.get(1).map(String::as_str).unwrap_or("galore8bit");
    let model = ModelConfig::by_name(model_name)
        .unwrap_or_else(|| panic!("unknown model {model_name}"));
    let method = MethodKind::parse(method_name).expect("unknown method");
    let mut cfg = RunConfig::new(model, method);
    cfg.steps = args
        .get(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(if galore::exp::scale::fast_mode() { 40 } else { model.steps });
    cfg.layerwise = true;
    cfg.eval_every = (cfg.steps / 10).max(1);

    println!(
        "pre-training {} with {} for {} steps (batch {} x seq {} = {} tokens/step)",
        model.name,
        method.label(),
        cfg.steps,
        cfg.batch,
        model.seq,
        cfg.batch * model.seq
    );
    println!(
        "model: {:.1}M params, rank {} (r/d = {:.2}), T = {}, alpha = {}",
        model.n_params() as f64 / 1e6,
        cfg.galore.rank,
        cfg.galore.rank as f64 / model.dim as f64,
        cfg.galore.update_freq,
        cfg.galore.scale
    );

    let mut trainer = Trainer::from_config(cfg.clone())?;
    let t0 = std::time::Instant::now();
    for step in 0..cfg.steps {
        let loss = trainer.train_step()?;
        if step % (cfg.steps / 20).max(1) == 0 {
            println!(
                "step {:>6}/{}  loss {:.4}  lr {:.5}  {:.0} tok/s",
                step,
                cfg.steps,
                loss,
                trainer.schedule.at(step),
                trainer.metrics.tokens_per_sec()
            );
        }
        if (step + 1) % cfg.eval_every == 0 {
            let l = trainer.eval(cfg.eval_batches)?;
            trainer.metrics.log_eval(step + 1, l);
            println!("  >> eval loss {:.4}  ppl {:.2}", l, l.exp());
        }
    }
    let elapsed = t0.elapsed();
    let eval = trainer.eval(cfg.eval_batches)?;
    trainer.metrics.log_eval(cfg.steps, eval);

    let csv = format!("runs/pretrain_{}_{}.csv", model.name, method.label());
    let path = trainer.metrics.write_csv(&csv)?;

    // Memory story: measured Rust-side state vs the analytic estimator,
    // through the single trainer-method -> memory-model mapping.
    let est_method = Method::for_kind(method, cfg.galore.rank);
    let est = estimate(
        model,
        est_method,
        TrainOpts { layerwise_updates: cfg.layerwise, token_batch: cfg.batch * model.seq, ..Default::default() },
    );

    println!("\n================ RESULT ================");
    println!("final eval loss {:.4}  perplexity {:.2}", eval, eval.exp());
    println!(
        "tokens {}  wall {:.1}s  throughput {:.0} tok/s (exec {:.0}%)",
        trainer.metrics.total_tokens(),
        elapsed.as_secs_f64(),
        trainer.metrics.tokens_per_sec(),
        100.0 * trainer.metrics.exec_time.as_secs_f64() / elapsed.as_secs_f64()
    );
    println!(
        "optimizer state: measured {}  (estimator: {})",
        fmt_gib(trainer.optimizer_state_bytes() as u64),
        fmt_gib(est.optim_states)
    );
    println!(
        "peak gradient memory: {} (layerwise = {})",
        fmt_gib(trainer.peak_grad_bytes as u64),
        cfg.layerwise
    );
    println!("loss curve: {}", path.display());
    Ok(())
}
