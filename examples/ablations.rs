//! Fig. 5 ablations, interactively: subspace change frequency T (left) and
//! the rank-vs-steps trade-off (right) on the nano proxy.
//!
//!   cargo run --release --example ablations

use galore::config::RunConfig;
use galore::coordinator::Trainer;
use galore::exp::scale::{fig5_freq_sweep, fig5_rank_sweep};

fn run(cfg: RunConfig) -> anyhow::Result<f32> {
    let mut trainer = Trainer::from_config(cfg.clone())?;
    for _ in 0..cfg.steps {
        trainer.train_step()?;
    }
    Ok(trainer.eval(cfg.eval_batches)?)
}

fn main() -> anyhow::Result<()> {
    println!("=== Fig. 5 (left): subspace change frequency T ===");
    let (base, freqs) = fig5_freq_sweep();
    println!("rank {} / dim {}, {} steps", base.galore.rank, base.model.dim, base.steps);
    for t in freqs {
        let mut cfg = base.clone();
        cfg.galore.update_freq = t;
        let loss = run(cfg)?;
        let label = if t >= 1_000_000 { "never".to_string() } else { t.to_string() };
        println!("  T = {:>7}: eval loss {:.4}", label, loss);
    }
    println!("expected shape: a U-curve — too frequent and 'never' both worse than T≈50–250.");

    println!("\n=== Fig. 5 (right): rank vs training steps ===");
    let (base, sweep) = fig5_rank_sweep();
    for (rank, steps) in sweep {
        let mut cfg = base.clone();
        cfg.galore.rank = rank;
        cfg.lowrank_rank = rank;
        cfg.steps = steps;
        let loss = run(cfg)?;
        println!("  rank {:>3} x {:>5} steps: eval loss {:.4}", rank, steps, loss);
    }
    println!("expected shape: smaller rank + more steps reaches similar/lower loss (memory-compute trade-off).");
    Ok(())
}
