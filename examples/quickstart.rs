//! Quickstart: train a nano LLaMA on synthetic C4 with GaLore vs full-rank
//! Adam and watch both loss curves fall together while GaLore's optimizer
//! state stays a fraction of Adam's.
//!
//!   make artifacts           # once
//!   cargo run --release --example quickstart

use galore::config::{MethodKind, RunConfig};
use galore::coordinator::Trainer;
use galore::memory::fmt_gib;
use galore::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::by_name("nano").unwrap();
    let steps = if galore::exp::scale::fast_mode() { 30 } else { 120 };

    let mut results = Vec::new();
    for method in [MethodKind::FullRank, MethodKind::GaLore] {
        let mut cfg = RunConfig::new(model, method);
        cfg.steps = steps;
        cfg.galore.rank = model.dim / 4;
        cfg.galore.update_freq = 50;
        println!("\n=== {} ({} steps) ===", method.label(), steps);
        let mut trainer = Trainer::from_config(cfg)?;
        for step in 0..steps {
            let loss = trainer.train_step()?;
            if step % (steps / 6).max(1) == 0 {
                println!("  step {step:>4}  loss {loss:.4}");
            }
        }
        let eval = trainer.eval(trainer.cfg.eval_batches)?;
        let state = trainer.optimizer_state_bytes();
        println!("  final eval loss {:.4} (ppl {:.2}), optimizer state {}", eval, eval.exp(), fmt_gib(state as u64));
        results.push((method.label(), eval, state));
    }

    let (_, full_loss, full_state) = results[0];
    let (_, gal_loss, gal_state) = results[1];
    println!("\nGaLore vs Full-Rank:");
    println!("  eval loss: {gal_loss:.4} vs {full_loss:.4} (Δ {:+.4})", gal_loss - full_loss);
    println!(
        "  optimizer state: {} vs {} ({:.0}% smaller)",
        fmt_gib(gal_state as u64),
        fmt_gib(full_state as u64),
        100.0 * (1.0 - gal_state as f64 / full_state as f64)
    );
    Ok(())
}
