//! Memory-efficient fine-tuning (the Table 4 scenario, substituted per
//! DESIGN.md §4): pre-train a base model, then fine-tune it on three
//! synthetic downstream tasks with Full FT / GaLore / LoRA at rank 4 and
//! 8, reporting task loss (lower = better, the GLUE-score stand-in) and
//! optimizer memory.
//!
//!   cargo run --release --example finetune_glue

use galore::config::MethodKind;
use galore::exp::finetune::{finetune, pretrain_base, TASKS};
use galore::exp::scale::fast_mode;
use galore::memory::fmt_gib;
use galore::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::by_name("nano").unwrap();
    let (pre_steps, ft_steps) = if fast_mode() { (30, 20) } else { (150, 80) };
    println!("pre-training base {} for {pre_steps} steps...", model.name);
    let base = pretrain_base(model, pre_steps, 7)?;

    for rank in [4usize, 8] {
        println!("\n=== rank {rank} ===");
        println!("{:<14} {:>10} {:>10} {:>10} {:>12}", "method", TASKS[0].name, TASKS[1].name, TASKS[2].name, "optim mem");
        for method in [MethodKind::FullRank, MethodKind::GaLore, MethodKind::Lora] {
            let mut losses = Vec::new();
            let mut mem = 0usize;
            for task in TASKS {
                let (loss, state) = finetune(&base, *task, method, rank, ft_steps)?;
                losses.push(loss);
                mem = mem.max(state);
            }
            println!(
                "{:<14} {:>10.4} {:>10.4} {:>10.4} {:>12}",
                method.label(),
                losses[0],
                losses[1],
                losses[2],
                fmt_gib(mem as u64)
            );
        }
    }
    println!("\npaper shape: GaLore ≈ Full FT ≥ LoRA at equal rank, with less optimizer memory (Table 4).");
    Ok(())
}
