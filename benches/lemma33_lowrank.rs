//! Lemma 3.3 / Corollary 3.4 verification: stable rank of the gradient
//! decays under vanilla SGD in the reversible-network gradient form, and
//! the final rank is governed by the input rank N'.

use galore::bench::Table;
use galore::exp::lowrank_theory::{stable_rank_trajectory, LowRankDynamics};

fn main() {
    let mut t = Table::new(&["input rank N'", "sr(G_0)", "sr(G_mid)", "sr(G_late)", "bound n-N'|N'"]);
    for input_rank in [2usize, 4, 8, 16, 32, 48] {
        let cfg = LowRankDynamics { input_rank, ..Default::default() };
        let traj = stable_rank_trajectory(&cfg, 100, 0);
        let g0 = traj[0].1;
        let valid: Vec<f64> =
            traj.iter().filter(|(_, n)| *n > 1e-3 * g0).map(|(s, _)| *s).collect();
        let mid = valid[valid.len() / 2];
        let late = *valid.last().unwrap();
        let bound = input_rank.min(cfg.n - input_rank.min(cfg.n));
        t.row(&[
            input_rank.to_string(),
            format!("{:.2}", valid[0]),
            format!("{mid:.2}"),
            format!("{late:.2}"),
            bound.to_string(),
        ]);
    }
    t.print("Lemma 3.3 (stable-rank decay of G_t under SGD; m=32, n=48)");
    println!("\nexpected shape: sr decays over training; final sr tracks min(N', n-N') (Cor. 3.4).");
}
