//! Adaptive-rank roster: fixed vs decay vs spectral schedules, the
//! dynamic-int8 projector store, and the cosine lazy-refresh gate
//! (EXPERIMENTS.md §Perf, "layer-adaptive rank"). Reports eval perplexity,
//! measured optimizer-state bytes, and the per-layer rank spread for each
//! run; the closed-form state envelope prints even without artifacts.

use galore::bench::Table;
use galore::coordinator::Trainer;
use galore::exp::adaptive::{adaptive_runs, state_envelope};
use galore::memory::fmt_gib;
use galore::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let runs = adaptive_runs();
    let mut table = Table::new(&["run", "eval ppl", "opt state", "ranks min..max", "allocs/step"]);
    let mut trained = 0;
    for run in &runs {
        eprintln!("[adaptive] {} ({} steps)...", run.name, run.cfg.steps);
        let mut trainer = match Trainer::from_config(run.cfg.clone()) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("[adaptive] SKIP {}: {e:#} (run `make artifacts`)", run.name);
                continue;
            }
        };
        if let Err(e) = trainer.run() {
            eprintln!("[adaptive] SKIP {}: {e:#}", run.name);
            continue;
        }
        trained += 1;
        let eval = trainer.metrics.final_eval_loss().unwrap();
        let profile = trainer.opt.rank_profile();
        let (rmin, rmax) = profile
            .iter()
            .fold((usize::MAX, 0), |(lo, hi), &(_, r)| (lo.min(r), hi.max(r)));
        let ranks = if profile.is_empty() {
            "-".to_string()
        } else {
            format!("{rmin}..{rmax} ({} layers)", profile.len())
        };
        table.row(&[
            run.name.into(),
            format!("{:.2}", eval.exp()),
            fmt_gib(trainer.optimizer_state_bytes() as u64),
            ranks,
            format!("{}", trainer.metrics.allocs_per_step()),
        ]);
    }
    if trained > 0 {
        table.print("Adaptive-rank roster (same model/steps/seed; policy is the variable)");
    }

    // Closed-form envelope: the measured adaptive state must land between
    // the floor and the fixed-rank bytes. Pure Rust, always available.
    let mut env = Table::new(&["model", "rank", "floor", "fixed-rank state", "floor state"]);
    for name in ["nano", "micro", "small", "7b"] {
        let Some(model) = ModelConfig::by_name(name) else { continue };
        let rank = model.dim / 4;
        let floor = (model.dim / 16).max(1);
        let (fixed, at_floor) = state_envelope(model, rank, floor);
        env.row(&[
            name.into(),
            format!("{rank}"),
            format!("{floor}"),
            fmt_gib(fixed),
            fmt_gib(at_floor),
        ]);
    }
    env.print("Adaptive-rank optimizer-state envelope (closed form, BF16)");
    Ok(())
}
