//! Fig. 4: memory usage vs model size (350M/1B/7B) for BF16 Adam, 8-bit
//! Adam, 8-bit GaLore with and without retaining gradients — analytic at
//! the true shapes, plus a *measured* RSS-style number for the proxy sizes
//! (actual optimizer-state bytes held by the trainer).

use galore::bench::Table;
use galore::config::{MethodKind, RunConfig};
use galore::coordinator::Trainer;
use galore::memory::{estimate, fmt_gib, Method, TrainOpts};
use galore::model::ModelConfig;
use galore::runtime::default_dir;

fn main() -> anyhow::Result<()> {
    let opts = TrainOpts { token_batch: 256, ..Default::default() };
    let lw = TrainOpts { layerwise_updates: true, ..opts };
    let mut t = Table::new(&["model", "BF16 Adam", "8-bit Adam", "8-bit GaLore (retain)", "8-bit GaLore"]);
    for name in ["350m", "1b", "7b"] {
        let c = ModelConfig::by_name(name).unwrap();
        let r = c.default_rank(); // d/4 — the paper's r=1024 at 7B
        t.row(&[
            name.into(),
            fmt_gib(estimate(c, Method::FullRank, opts).total()),
            fmt_gib(estimate(c, Method::Adam8bit, opts).total()),
            fmt_gib(estimate(c, Method::GaLore8bit { rank: r }, opts).total()),
            fmt_gib(estimate(c, Method::GaLore8bit { rank: r }, lw).total()),
        ]);
    }
    t.print("Fig. 4 (analytic, true shapes; paper 7B: ~58G / 46G / 29.9G / 21.3G)");

    // Measured column at proxy scale — only if artifacts exist.
    if default_dir().join("manifest.json").exists() {
        let model = ModelConfig::by_name("nano").unwrap();
        let mut t2 = Table::new(&["method", "measured optim state", "peak grad mem"]);
        for (method, layerwise) in [
            (MethodKind::FullRank, false),
            (MethodKind::Adam8bit, false),
            (MethodKind::GaLore8bit, false),
            (MethodKind::GaLore8bit, true),
        ] {
            let mut cfg = RunConfig::new(model, method);
            cfg.steps = 5;
            cfg.layerwise = layerwise;
            let mut trainer = Trainer::from_config(cfg)?;
            for _ in 0..5 {
                trainer.train_step()?;
            }
            t2.row(&[
                format!("{}{}", method.label(), if layerwise { " (layerwise)" } else { "" }),
                fmt_gib(trainer.optimizer_state_bytes() as u64),
                fmt_gib(trainer.peak_grad_bytes as u64),
            ]);
        }
        t2.print("Fig. 4 measured (nano proxy, real trainer state)");
    } else {
        eprintln!("(skipping measured column: run `make artifacts` first)");
    }
    Ok(())
}
