//! Hot-path micro-benchmarks (§Perf of EXPERIMENTS.md): the per-step
//! GaLore pieces on both the Rust path and the fused Pallas/HLO artifact
//! path, plus the substrates they sit on (matmul kernels, SVD refresh,
//! 8-bit quantization, ring all-reduce).

use galore::bench::{bench, report};
use galore::coordinator::{thread_alloc_stats, Ring};
use galore::linalg::{top_r_left_subspace, top_r_left_subspace_into, SvdWorkspace};
use galore::model::{init_params, ModelConfig, WeightPrecision};
use galore::optim::{Adam, AdamConfig, GaLore, GaLoreConfig, Optimizer, Projector, ProjectorQuant};
use galore::quant::{dequantize, quantize, DynQuantBuf};
use galore::rng::Rng;
use galore::runtime::{default_dir, pool, Engine, Input};
use galore::tensor::{matmul, matmul_at_b, Matrix};

/// Measure allocator traffic of `steps` repetitions of `f` on this thread
/// (the workspace refactor's acceptance metric: steady-state optimizer
/// steps must report 0 — EXPERIMENTS.md §Perf).
fn report_allocs(name: &str, steps: u64, mut f: impl FnMut()) {
    let s0 = thread_alloc_stats();
    for _ in 0..steps {
        f();
    }
    let s1 = thread_alloc_stats();
    println!(
        "{:<44} {:>12} allocs/step  {:>10} bytes/step",
        name,
        (s1.allocs - s0.allocs) / steps,
        (s1.bytes - s0.bytes) / steps,
    );
}

/// The retired spawn-per-call kernel shape: scoped threads over row
/// bands, serial inner loops — what `tensor::ops` did before the
/// persistent pool. Kept here only as the bench baseline.
fn matmul_spawn_per_call(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    let band = m.div_ceil(threads.max(1));
    std::thread::scope(|scope| {
        for (band_i, out) in c.data.chunks_mut(band * n).enumerate() {
            let r0 = band_i * band;
            scope.spawn(move || {
                for (ri, row) in out.chunks_mut(n).enumerate() {
                    let ar = &a.data[(r0 + ri) * k..(r0 + ri + 1) * k];
                    for (kk, &av) in ar.iter().enumerate() {
                        let brow = &b.data[kk * n..(kk + 1) * n];
                        for (cv, &bv) in row.iter_mut().zip(brow) {
                            *cv += av * bv;
                        }
                    }
                }
            });
        }
    });
    c
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);
    println!("== substrates ==");
    for &(m, k, n) in &[(128usize, 128usize, 128usize), (512, 512, 512), (512, 2048, 128)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let s = bench(&format!("matmul {m}x{k}x{n}"), || {
            std::hint::black_box(matmul(&a, &b));
        });
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        report(&s);
        println!("    -> {:.2} GFLOP/s", flops / s.median_secs() / 1e9);
    }

    let g = Matrix::randn(512, 1376, 1.0, &mut rng);
    report(&bench("projector refresh SVD 512x1376 r128", || {
        let mut r = Rng::new(1);
        std::hint::black_box(top_r_left_subspace(&g, 128, &mut r));
    }));
    let mut svd_ws = SvdWorkspace::new();
    let mut basis_buf = Matrix::zeros(0, 0);
    top_r_left_subspace_into(&g, 128, &mut Rng::new(1), &mut svd_ws, &mut basis_buf); // warm
    report(&bench("projector refresh SVD (workspace reuse)", || {
        let mut r = Rng::new(1);
        top_r_left_subspace_into(&g, 128, &mut r, &mut svd_ws, &mut basis_buf);
        std::hint::black_box(&basis_buf);
    }));
    let p = top_r_left_subspace(&g, 128, &mut rng);
    report(&bench("project P^T G 512x1376 r128", || {
        std::hint::black_box(matmul_at_b(&p, &g));
    }));

    let x: Vec<f32> = (0..1 << 20).map(|i| ((i * 37 % 1001) as f32 - 500.0) * 1e-3).collect();
    report(&bench("linear block8 quantize 1M f32", || {
        std::hint::black_box(quantize(&x));
    }));
    let qb = quantize(&x);
    report(&bench("linear block8 dequantize 1M f32", || {
        std::hint::black_box(dequantize(&qb));
    }));
    let mut dynb = DynQuantBuf::zeros(x.len(), true);
    report(&bench("dynamic-code quantize 1M f32", || {
        dynb.quantize_from(&x);
    }));

    println!("\n== optimizer step (512x1376 layer, r=128) ==");
    let mut w = Matrix::randn(512, 1376, 0.02, &mut rng);
    let grad = Matrix::randn(512, 1376, 0.02, &mut rng);
    let mut adam = Adam::new(AdamConfig::default());
    report(&bench("full-rank Adam step", || {
        adam.step(0, &mut w, &grad, 1e-4).unwrap();
    }));
    let mut gal = GaLore::new(GaLoreConfig { rank: 128, update_freq: 200, scale: 0.25, ..Default::default() }, Adam::new(AdamConfig::default()));
    gal.step(0, &mut w, &grad, 1e-4).unwrap(); // pay the first refresh outside timing
    report(&bench("GaLore-Adam step (rust, amortized)", || {
        gal.step(0, &mut w, &grad, 1e-4).unwrap();
    }));
    let proj = Projector::compute(&grad, 128, &mut rng);
    report(&bench("project+back only", || {
        let c = proj.project(&grad);
        std::hint::black_box(proj.project_back(&c));
    }));

    // Steady-state allocator traffic (workspace refactor acceptance): at
    // this 512x1376 size the matmuls cross the threading threshold, so the
    // counted allocations are the scoped-thread spawns, not optimizer
    // buffers. The sub-threshold shape isolates the optimizer itself and
    // must report 0 allocs/step.
    println!("\n== steady-state allocator traffic ==");
    report_allocs("full-rank Adam step allocs (512x1376)", 50, || {
        adam.step(0, &mut w, &grad, 1e-4).unwrap();
    });
    report_allocs("GaLore-Adam step allocs (512x1376, threaded)", 50, || {
        gal.step(0, &mut w, &grad, 1e-4).unwrap();
    });
    {
        let mut w_s = Matrix::randn(128, 344, 0.02, &mut rng);
        let grad_s = Matrix::randn(128, 344, 0.02, &mut rng);
        let mut gal_s = GaLore::new(
            GaLoreConfig { rank: 32, update_freq: 10_000, scale: 0.25, ..Default::default() },
            Adam::new(AdamConfig::default()),
        );
        for _ in 0..3 {
            gal_s.step(0, &mut w_s, &grad_s, 1e-4).unwrap(); // warm workspaces
        }
        report_allocs("GaLore-Adam step allocs (128x344, 1 thread)", 200, || {
            gal_s.step(0, &mut w_s, &grad_s, 1e-4).unwrap();
        });
    }

    // Persistent-pool comparison (EXPERIMENTS.md §Perf iteration 5): the
    // retired spawn-per-call kernel vs the pooled kernel, dispatch cost in
    // isolation, cross-layer `step_many` vs the sequential sweep, and the
    // bf16 weight-store commit.
    println!("\n== worker pool (iteration 5) ==");
    let threads = pool::num_threads();
    println!("pool width: {threads} threads");
    {
        let (m, k, n) = (512usize, 512usize, 512usize);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let s = bench("matmul 512^3, spawn-per-call (old kernel)", || {
            std::hint::black_box(matmul_spawn_per_call(&a, &b, threads));
        });
        report(&s);
        println!("    -> {:.2} GFLOP/s", flops / s.median_secs() / 1e9);
        let s = bench("matmul 512^3, persistent pool", || {
            std::hint::black_box(matmul(&a, &b));
        });
        report(&s);
        println!("    -> {:.2} GFLOP/s", flops / s.median_secs() / 1e9);
    }
    {
        // Dispatch overhead in isolation: near-empty tasks, so the round
        // trip (wake workers, claim tasks, quiesce) dominates.
        let sink: Vec<std::sync::atomic::AtomicU64> =
            (0..threads).map(|_| std::sync::atomic::AtomicU64::new(0)).collect();
        report(&bench("dispatch only: scoped spawn, N tasks", || {
            std::thread::scope(|scope| {
                for s in &sink {
                    scope.spawn(move || s.fetch_add(1, std::sync::atomic::Ordering::Relaxed));
                }
            });
        }));
        report(&bench("dispatch only: pool::run, N tasks", || {
            pool::run(sink.len(), |i| {
                sink[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            });
        }));
        report_allocs("pool::run dispatch allocs (warm)", 200, || {
            pool::run(sink.len(), |i| {
                sink[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            });
        });
    }
    {
        // Cross-layer stepping: 6 mid-size GaLore layers. The sequential
        // sweep threads each layer's matmuls individually; `step_many`
        // instead runs whole layers as pool tasks (nested matmuls inline).
        let shapes = [(256usize, 688usize); 6];
        let mk = || {
            GaLore::new(
                GaLoreConfig { rank: 64, update_freq: 10_000, scale: 0.25, ..Default::default() },
                Adam::new(AdamConfig::default()),
            )
            .with_targets(0..shapes.len())
            .with_seed(7)
        };
        let mut rng2 = Rng::new(17);
        let mut ws: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.02, &mut rng2)).collect();
        let gs: Vec<Matrix> =
            shapes.iter().map(|&(m, n)| Matrix::randn(m, n, 0.02, &mut rng2)).collect();
        let mut seq = mk();
        for (idx, (w, g)) in ws.iter_mut().zip(gs.iter()).enumerate() {
            seq.step(idx, w, g, 1e-4).unwrap(); // first refresh outside timing
        }
        report(&bench("6-layer sweep: sequential step loop", || {
            for (idx, (w, g)) in ws.iter_mut().zip(gs.iter()).enumerate() {
                seq.step(idx, w, g, 1e-4).unwrap();
            }
        }));
        let mut par = mk();
        par.step_many(&mut ws, &gs, 1e-4).unwrap(); // first refresh outside timing
        report(&bench("6-layer sweep: step_many (pool)", || {
            par.step_many(&mut ws, &gs, 1e-4).unwrap();
        }));
        report_allocs("step_many allocs/step (warm, 6 layers)", 50, || {
            par.step_many(&mut ws, &gs, 1e-4).unwrap();
        });
    }
    {
        let mut params = init_params(ModelConfig::by_name("nano").unwrap(), 0);
        let f32_bytes = params.weight_store_bytes();
        params.set_precision(WeightPrecision::Bf16);
        println!(
            "bf16 weight store (nano): {} -> {} bytes",
            f32_bytes,
            params.weight_store_bytes()
        );
        report(&bench("bf16 commit (nano, round through store)", || {
            params.commit();
        }));
        params.seed_rounding(0);
        params.set_precision(WeightPrecision::Int8);
        println!(
            "int8 weight store (nano): {} -> {} bytes",
            f32_bytes,
            params.weight_store_bytes()
        );
        report(&bench("int8 commit (nano, stochastic round through store)", || {
            params.commit();
        }));
    }
    {
        // Int4 packed projectors: the quantize/dequantize pair rides every
        // step (project down, project back), so the packed path must stay
        // within noise of the f32 store's step cost.
        let mut w4 = Matrix::randn(512, 1376, 0.02, &mut rng);
        let grad4 = Matrix::randn(512, 1376, 0.02, &mut rng);
        let mut gal4 = GaLore::new(
            GaLoreConfig {
                rank: 128,
                update_freq: 10_000,
                scale: 0.25,
                projector_quant: ProjectorQuant::Int4,
                ..Default::default()
            },
            Adam::new(AdamConfig::default()),
        );
        gal4.step(0, &mut w4, &grad4, 1e-4).unwrap(); // refresh outside timing
        report(&bench("GaLore-Adam step 512x1376 r=128 (int4 projector)", || {
            gal4.step(0, &mut w4, &grad4, 1e-4).unwrap();
        }));
    }

    println!("\n== ring all-reduce (4 workers, 1M f32) ==");
    report(&bench("ring all_reduce 4x1M", || {
        let handles = Ring::new(4).into_handles();
        std::thread::scope(|scope| {
            for mut h in handles {
                scope.spawn(move || {
                    let mut data = vec![1.0f32; 1 << 20];
                    h.all_reduce_sum(&mut data).expect("ring healthy");
                });
            }
        });
    }));

    if default_dir().join("manifest.json").exists() {
        println!("\n== fused HLO/Pallas artifacts ==");
        let mut engine = Engine::new(default_dir())?;
        let (m, n, r) = (64usize, 172usize, 16usize);
        let w = vec![0.01f32; m * n];
        let g = vec![0.02f32; m * n];
        let mm = vec![0.0f32; r * n];
        let vv = vec![0.0f32; r * n];
        let p = vec![0.05f32; m * r];
        engine.prepare(&format!("galore_step_{m}x{n}_r{r}"))?;
        report(&bench("fused galore_step 64x172 r16 (HLO)", || {
            engine
                .execute(
                    &format!("galore_step_{m}x{n}_r{r}"),
                    &[
                        Input::F32(&w),
                        Input::F32(&mm),
                        Input::F32(&vv),
                        Input::F32(&g),
                        Input::F32(&p),
                        Input::F32(&[1.0]),
                        Input::F32(&[0.001]),
                    ],
                )
                .unwrap();
        }));
        // Full train step timing (nano).
        if engine.manifest.train_for("nano").is_some() {
            use galore::config::{MethodKind, RunConfig};
            use galore::coordinator::Trainer;
            use galore::model::ModelConfig;
            let mut cfg = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
            cfg.steps = 3;
            let mut trainer = Trainer::from_config(cfg)?;
            trainer.train_step()?; // compile outside timing
            report(&bench("end-to-end train step (nano, galore)", || {
                trainer.train_step().unwrap();
            }));
        }
    } else {
        eprintln!("(artifact benches skipped: run `make artifacts`)");
    }
    Ok(())
}
