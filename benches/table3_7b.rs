//! Table 3: the 7B run — 8-bit GaLore vs 8-bit Adam, perplexity at
//! intermediate checkpoints plus the memory estimate. Scaled: the proxy
//! model stands in for 7B (DESIGN.md §4); the memory column uses the true
//! 7B shapes. Paper: 17.94/15.39/14.95/14.65 (18G) vs
//! 18.09/15.47/14.83/14.61 (26G).

use galore::bench::Table;
use galore::config::MethodKind;
use galore::coordinator::Trainer;
use galore::exp::scale::table3_runs;
use galore::memory::{estimate, fmt_gib, Method, TrainOpts};
use galore::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let (runs, checkpoints) = table3_runs();
    let m7b = ModelConfig::by_name("7b").unwrap();
    let mut table = Table::new(&["method", "7B mem", "ck1", "ck2", "ck3", "final", "paper final"]);
    for cfg in runs {
        eprintln!("[table3] {} ({} steps)...", cfg.method.label(), cfg.steps);
        let mut trainer = Trainer::from_config(cfg.clone())?;
        let mut ppls = Vec::new();
        for step in 0..cfg.steps {
            trainer.train_step()?;
            if checkpoints.contains(&(step + 1)) {
                let l = trainer.eval(cfg.eval_batches)?;
                ppls.push(l.exp());
            }
        }
        while ppls.len() < 4 {
            ppls.push(trainer.eval(cfg.eval_batches)?.exp());
        }
        let (mem, paper) = match cfg.method {
            MethodKind::GaLore8bit => (
                estimate(
                    m7b,
                    Method::GaLore8bit { rank: 1024 },
                    TrainOpts { layerwise_updates: true, ..Default::default() },
                ),
                "14.65 (18G)",
            ),
            _ => (
                estimate(m7b, Method::Adam8bit, TrainOpts { layerwise_updates: true, ..Default::default() }),
                "14.61 (26G)",
            ),
        };
        table.row(&[
            cfg.method.label().into(),
            fmt_gib(mem.total()),
            format!("{:.2}", ppls[0]),
            format!("{:.2}", ppls[1]),
            format!("{:.2}", ppls[2]),
            format!("{:.2}", ppls[3]),
            paper.into(),
        ]);
    }
    table.print("Table 3 (scaled 7B run: 8-bit GaLore vs 8-bit Adam)");
    println!("expected shape: both curves overlap (|Δppl| small), GaLore memory well below Adam's.");
    Ok(())
}
