//! Table 4 (+ Tables 8–10, folded): memory-efficient fine-tuning. Full FT
//! vs GaLore vs LoRA at ranks 4 and 8 on three synthetic downstream tasks
//! (GLUE substitute, DESIGN.md §4). Paper averages: Full 86.28 (747M),
//! GaLore r4 85.89 (253M), LoRA r4 85.61 (257M). Shape to reproduce:
//! GaLore ≈ Full ≥ LoRA at matched rank, with less optimizer memory.

use galore::bench::Table;
use galore::config::MethodKind;
use galore::exp::finetune::{finetune, pretrain_base, TASKS};
use galore::exp::scale::fast_mode;
use galore::model::ModelConfig;

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::by_name("nano").unwrap();
    let (pre_steps, ft_steps) = if fast_mode() { (25, 15) } else { (120, 60) };
    eprintln!("[table4] pre-training base ({pre_steps} steps)...");
    let base = pretrain_base(model, pre_steps, 7)?;

    for rank in [4usize, 8] {
        let mut table = Table::new(&[
            "method", TASKS[0].name, TASKS[1].name, TASKS[2].name, "avg loss", "optim mem (MB)", "paper avg",
        ]);
        let mut rows: Vec<(MethodKind, f32)> = Vec::new();
        for method in [MethodKind::FullRank, MethodKind::GaLore, MethodKind::Lora] {
            eprintln!("[table4] rank {rank} / {} ...", method.label());
            let mut losses = Vec::new();
            let mut mem = 0usize;
            for task in TASKS {
                let (loss, state) = finetune(&base, *task, method, rank, ft_steps)?;
                losses.push(loss);
                mem = mem.max(state);
            }
            let avg = losses.iter().sum::<f32>() / losses.len() as f32;
            let paper = match (method, rank) {
                (MethodKind::FullRank, _) => "86.28 (747M)",
                (MethodKind::GaLore, 4) => "85.89 (253M)",
                (MethodKind::GaLore, 8) => "85.94 (257M)",
                (MethodKind::Lora, 4) => "85.61 (257M)",
                (MethodKind::Lora, 8) => "85.93 (264M)",
                _ => "",
            };
            table.row(&[
                method.label().into(),
                format!("{:.4}", losses[0]),
                format!("{:.4}", losses[1]),
                format!("{:.4}", losses[2]),
                format!("{avg:.4}"),
                format!("{:.2}", mem as f64 / 1e6),
                paper.into(),
            ]);
            rows.push((method, avg));
        }
        table.print(&format!("Table 4 (fine-tuning, rank {rank}; loss lower = better)"));
        let get = |k: MethodKind| rows.iter().find(|(m, _)| *m == k).map(|(_, v)| *v).unwrap();
        println!(
            "rank {rank}: GaLore-vs-Full gap {:+.1}%, GaLore-vs-LoRA gap {:+.1}% (negative = GaLore better)",
            100.0 * (get(MethodKind::GaLore) - get(MethodKind::FullRank)) / get(MethodKind::FullRank),
            100.0 * (get(MethodKind::GaLore) - get(MethodKind::Lora)) / get(MethodKind::Lora),
        );
    }
    Ok(())
}
