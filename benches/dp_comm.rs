//! DP communication bench: full vs. compact gradient all-reduce
//! (`dp_compress`) over a real ring of worker threads at model schema
//! shapes — no artifacts needed, gradients are synthetic. Reproduces the
//! EXPERIMENTS.md §DP communication table: reduced f32s per step (full vs.
//! steady-state compact), the closed-form `min(m,n)/r` cut per targeted
//! layer, and end-to-end exchange+update throughput per mode.

use galore::bench::Table;
use galore::coordinator::{exchange_grads, Ring};
use galore::model::{schema, ModelConfig, ParamStore};
use galore::optim::{Adam, GaLore, GaLoreConfig, GradReduceMode, Optimizer};
use galore::rng::Rng;
use galore::tensor::Matrix;

const WORLD: usize = 4;
const STEPS: usize = 24;
const REFRESH_T: u64 = 8;

struct ModeStats {
    /// Payload of a steady-state (non-refresh) step, f32 elements.
    steady_f32s: u64,
    /// Payload of a refresh-boundary step.
    boundary_f32s: u64,
    /// Wall-clock steps/s for the exchange+update loop (all workers).
    steps_per_sec: f64,
}

fn run_mode(model: &'static ModelConfig, rank: usize, compress: bool) -> ModeStats {
    let handles = Ring::new(WORLD).into_handles();
    let t0 = std::time::Instant::now();
    let payload_sets: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let joins: Vec<_> = handles
            .into_iter()
            .map(|h| {
                scope.spawn(move || {
                    let store = ParamStore::zeros(model);
                    let targets = store.projection_targets();
                    let cfg = GaLoreConfig {
                        rank,
                        update_freq: REFRESH_T,
                        scale: 0.25,
                        ..Default::default()
                    };
                    let mut opt: Box<dyn Optimizer> = Box::new(
                        GaLore::new(cfg, Adam::default_paper())
                            .with_targets(targets.iter().copied())
                            .with_seed(3),
                    );
                    let mut rng = Rng::new(0xD1 ^ h.rank as u64);
                    let mut weights: Vec<Matrix> = store
                        .metas
                        .iter()
                        .map(|m| Matrix::zeros(m.rows, m.cols))
                        .collect();
                    // One synthetic gradient set per worker, reused every
                    // step — contents only shape the projector, not the
                    // traffic being measured.
                    let mut grads: Vec<Matrix> = store
                        .metas
                        .iter()
                        .map(|m| Matrix::randn(m.rows, m.cols, 1.0, &mut rng))
                        .collect();
                    let mut compact = Vec::new();
                    let mut plan = Vec::new();
                    let mut payloads = Vec::new();
                    for _ in 0..STEPS {
                        let p = exchange_grads(
                            &h,
                            opt.as_ref(),
                            &mut grads,
                            &mut compact,
                            &mut plan,
                            compress,
                        )
                        .expect("ring healthy");
                        payloads.push(p);
                        for idx in 0..grads.len() {
                            match plan[idx] {
                                GradReduceMode::Full => {
                                    opt.step(idx, &mut weights[idx], &grads[idx], 0.01).unwrap()
                                }
                                GradReduceMode::Compact { .. } => opt
                                    .step_compact(idx, &mut weights[idx], &compact[idx], 0.01)
                                    .unwrap(),
                            }
                        }
                    }
                    payloads
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let payloads = &payload_sets[0];
    ModeStats {
        steady_f32s: payloads[STEPS - 1], // STEPS-1 not divisible by REFRESH_T
        boundary_f32s: payloads[0],
        steps_per_sec: STEPS as f64 / elapsed.max(1e-9),
    }
}

fn fmt_mib(f32s: u64) -> String {
    format!("{:.2} MiB", 4.0 * f32s as f64 / (1024.0 * 1024.0))
}

fn main() {
    // The "steady" sample is the last step; it must not be a boundary.
    assert!((STEPS - 1) as u64 % REFRESH_T != 0);
    let mut table = Table::new(&[
        "model",
        "rank",
        "mode",
        "f32s/step (steady)",
        "bytes/step",
        "cut vs full",
        "steps/s (W=4)",
    ]);
    for name in ["nano", "micro"] {
        let model = ModelConfig::by_name(name).unwrap();
        let rank = model.default_rank();
        let full = run_mode(model, rank, false);
        let comp = run_mode(model, rank, true);
        assert_eq!(
            comp.boundary_f32s, full.steady_f32s,
            "refresh boundaries must exchange the full gradient"
        );
        // Closed-form steady-state compact payload from the schema.
        let mut want_compact = 0u64;
        for meta in schema(model) {
            if meta.is_projection_target() {
                let r = (rank as u64).min(meta.rows as u64).min(meta.cols as u64);
                want_compact += r * meta.rows.max(meta.cols) as u64;
            } else {
                want_compact += (meta.rows * meta.cols) as u64;
            }
        }
        assert_eq!(comp.steady_f32s, want_compact, "{name}: payload vs closed form");
        let cut = full.steady_f32s as f64 / comp.steady_f32s as f64;
        table.row(&[
            name.into(),
            format!("{rank}"),
            "full".into(),
            format!("{}", full.steady_f32s),
            fmt_mib(full.steady_f32s),
            "1.00x".into(),
            format!("{:.1}", full.steps_per_sec),
        ]);
        table.row(&[
            name.into(),
            format!("{rank}"),
            "compact".into(),
            format!("{}", comp.steady_f32s),
            fmt_mib(comp.steady_f32s),
            format!("{cut:.2}x"),
            format!("{:.1}", comp.steps_per_sec),
        ]);
    }
    table.print(&format!(
        "DP gradient exchange, W={WORLD}, T={REFRESH_T} (reduced payload per step; \
         ring wire traffic per worker = 2(W-1)/W of it)"
    ));
    println!(
        "\nNote: full gradients still flow at refresh boundaries (every T steps) and\n\
         for non-target parameters; between refreshes each targeted layer ships\n\
         r*max(m,n) instead of m*n f32s — a min(m,n)/r cut per layer."
    );
}
