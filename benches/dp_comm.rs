//! DP communication bench: full vs. compact gradient all-reduce
//! (`dp_compress`) over a real ring of worker threads at model schema
//! shapes — no artifacts needed, gradients are synthetic. Reproduces the
//! EXPERIMENTS.md §DP communication table: reduced f32s per step (full vs.
//! steady-state compact), the closed-form `min(m,n)/r` cut per targeted
//! layer, and end-to-end exchange+update throughput per mode.
//!
//! The second table measures **overlap efficiency** — the fraction of
//! collective time hidden behind the optimizer update when the exchange is
//! split into per-bucket reduces ([`exchange_grads_overlapped`]) instead
//! of one step barrier — on a 6-layer workload over both ring transports
//! (in-process channels and Unix sockets).

use galore::bench::Table;
use galore::coordinator::{
    exchange_grads, exchange_grads_overlapped, local_socket_ring, OverlapTimes, Ring, Transport,
};
use galore::model::{schema, ModelConfig, ParamStore};
use galore::optim::{Adam, GaLore, GaLoreConfig, GradReduceMode, Optimizer};
use galore::rng::Rng;
use galore::tensor::Matrix;

const WORLD: usize = 4;
const STEPS: usize = 24;
const REFRESH_T: u64 = 8;

struct ModeStats {
    /// Payload of a steady-state (non-refresh) step, f32 elements.
    steady_f32s: u64,
    /// Payload of a refresh-boundary step.
    boundary_f32s: u64,
    /// Wall-clock steps/s for the exchange+update loop (all workers).
    steps_per_sec: f64,
}

fn run_mode(model: &'static ModelConfig, rank: usize, compress: bool) -> ModeStats {
    let handles = Ring::new(WORLD).into_handles();
    let t0 = std::time::Instant::now();
    let payload_sets: Vec<Vec<u64>> = std::thread::scope(|scope| {
        let joins: Vec<_> = handles
            .into_iter()
            .map(|mut h| {
                scope.spawn(move || {
                    let store = ParamStore::zeros(model);
                    let targets = store.projection_targets();
                    let cfg = GaLoreConfig {
                        rank,
                        update_freq: REFRESH_T,
                        scale: 0.25,
                        ..Default::default()
                    };
                    let mut opt: Box<dyn Optimizer> = Box::new(
                        GaLore::new(cfg, Adam::default_paper())
                            .with_targets(targets.iter().copied())
                            .with_seed(3),
                    );
                    let mut rng = Rng::new(0xD1 ^ h.rank as u64);
                    let mut weights: Vec<Matrix> = store
                        .metas
                        .iter()
                        .map(|m| Matrix::zeros(m.rows, m.cols))
                        .collect();
                    // One synthetic gradient set per worker, reused every
                    // step — contents only shape the projector, not the
                    // traffic being measured.
                    let mut grads: Vec<Matrix> = store
                        .metas
                        .iter()
                        .map(|m| Matrix::randn(m.rows, m.cols, 1.0, &mut rng))
                        .collect();
                    let mut compact = Vec::new();
                    let mut plan = Vec::new();
                    let mut payloads = Vec::new();
                    for _ in 0..STEPS {
                        let p = exchange_grads(
                            &mut h,
                            opt.as_ref(),
                            &mut grads,
                            &mut compact,
                            &mut plan,
                            compress,
                        )
                        .expect("ring healthy");
                        payloads.push(p);
                        for idx in 0..grads.len() {
                            match plan[idx] {
                                GradReduceMode::Full => {
                                    opt.step(idx, &mut weights[idx], &grads[idx], 0.01).unwrap()
                                }
                                GradReduceMode::Compact { .. } => opt
                                    .step_compact(idx, &mut weights[idx], &compact[idx], 0.01)
                                    .unwrap(),
                            }
                        }
                    }
                    payloads
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let payloads = &payload_sets[0];
    ModeStats {
        steady_f32s: payloads[STEPS - 1], // STEPS-1 not divisible by REFRESH_T
        boundary_f32s: payloads[0],
        steps_per_sec: STEPS as f64 / elapsed.max(1e-9),
    }
}

fn fmt_mib(f32s: u64) -> String {
    format!("{:.2} MiB", 4.0 * f32s as f64 / (1024.0 * 1024.0))
}

// ---------------------------------------------------------------------------
// Overlap efficiency: bucketed reduce-while-update vs the step barrier.

const OVERLAP_LAYERS: usize = 6;
const OVERLAP_DIM: usize = 192;
const OVERLAP_STEPS: usize = 8;
/// Update-side work per layer: enough axpy passes that a reduced bucket
/// has real compute to hide the next bucket's collective behind.
const COMPUTE_PASSES: usize = 24;

/// Run `OVERLAP_STEPS` overlapped exchanges of a 6-layer full-gradient
/// workload over the given transports and return rank-0's accumulated
/// comm/wait split. `bucket_cap_f32s = usize::MAX` degenerates to one
/// bucket — the step-barrier baseline (all comm, then all update).
fn run_overlap<Tp: Transport>(transports: Vec<Tp>, bucket_cap_f32s: usize) -> OverlapTimes {
    let times: Vec<OverlapTimes> = std::thread::scope(|scope| {
        let joins: Vec<_> = transports
            .into_iter()
            .map(|mut tp| {
                scope.spawn(move || {
                    let mut rng = Rng::new(0xA5 ^ tp.rank() as u64);
                    let mut weights: Vec<Matrix> = (0..OVERLAP_LAYERS)
                        .map(|_| Matrix::zeros(OVERLAP_DIM, OVERLAP_DIM))
                        .collect();
                    let mut grads: Vec<Matrix> = (0..OVERLAP_LAYERS)
                        .map(|_| Matrix::randn(OVERLAP_DIM, OVERLAP_DIM, 1.0, &mut rng))
                        .collect();
                    let mut compact: Vec<Matrix> =
                        (0..OVERLAP_LAYERS).map(|_| Matrix::zeros(0, 0)).collect();
                    let plan = vec![GradReduceMode::Full; OVERLAP_LAYERS];
                    let mut total = OverlapTimes::default();
                    for s in 0..OVERLAP_STEPS {
                        let weights = &mut weights;
                        let mut apply =
                            |start: usize, gs: &[Matrix], _cs: &[Matrix]| -> anyhow::Result<()> {
                                for (i, g) in gs.iter().enumerate() {
                                    let w = &mut weights[start + i];
                                    for _ in 0..COMPUTE_PASSES {
                                        w.axpy(-1e-3, g);
                                    }
                                }
                                Ok(())
                            };
                        let (_loss, t) = exchange_grads_overlapped(
                            &mut tp,
                            &mut grads,
                            &mut compact,
                            &plan,
                            bucket_cap_f32s,
                            s as f32,
                            &mut apply,
                        )
                        .expect("ring healthy");
                        total.comm += t.comm;
                        total.wait += t.wait;
                    }
                    total
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    times[0]
}

fn fmt_ms_per_step(d: std::time::Duration) -> String {
    format!("{:.3}", d.as_secs_f64() * 1e3 / OVERLAP_STEPS as f64)
}

fn main() {
    // The "steady" sample is the last step; it must not be a boundary.
    assert!((STEPS - 1) as u64 % REFRESH_T != 0);
    let mut table = Table::new(&[
        "model",
        "rank",
        "mode",
        "f32s/step (steady)",
        "bytes/step",
        "cut vs full",
        "steps/s (W=4)",
    ]);
    for name in ["nano", "micro"] {
        let model = ModelConfig::by_name(name).unwrap();
        let rank = model.default_rank();
        let full = run_mode(model, rank, false);
        let comp = run_mode(model, rank, true);
        assert_eq!(
            comp.boundary_f32s, full.steady_f32s,
            "refresh boundaries must exchange the full gradient"
        );
        // Closed-form steady-state compact payload from the schema.
        let mut want_compact = 0u64;
        for meta in schema(model) {
            if meta.is_projection_target() {
                let r = (rank as u64).min(meta.rows as u64).min(meta.cols as u64);
                want_compact += r * meta.rows.max(meta.cols) as u64;
            } else {
                want_compact += (meta.rows * meta.cols) as u64;
            }
        }
        assert_eq!(comp.steady_f32s, want_compact, "{name}: payload vs closed form");
        let cut = full.steady_f32s as f64 / comp.steady_f32s as f64;
        table.row(&[
            name.into(),
            format!("{rank}"),
            "full".into(),
            format!("{}", full.steady_f32s),
            fmt_mib(full.steady_f32s),
            "1.00x".into(),
            format!("{:.1}", full.steps_per_sec),
        ]);
        table.row(&[
            name.into(),
            format!("{rank}"),
            "compact".into(),
            format!("{}", comp.steady_f32s),
            fmt_mib(comp.steady_f32s),
            format!("{cut:.2}x"),
            format!("{:.1}", comp.steps_per_sec),
        ]);
    }
    table.print(&format!(
        "DP gradient exchange, W={WORLD}, T={REFRESH_T} (reduced payload per step; \
         ring wire traffic per worker = 2(W-1)/W of it)"
    ));
    println!(
        "\nNote: full gradients still flow at refresh boundaries (every T steps) and\n\
         for non-target parameters; between refreshes each targeted layer ships\n\
         r*max(m,n) instead of m*n f32s — a min(m,n)/r cut per layer."
    );

    // Overlap efficiency, both transports. Cap 1 forces one bucket per
    // layer (every parameter is larger than the cap); usize::MAX is the
    // single-bucket step barrier.
    let mut overlap = Table::new(&[
        "transport",
        "mode",
        "comm ms/step",
        "wait ms/step",
        "hidden ms/step",
        "efficiency",
    ]);
    let mut bucketed_effs = Vec::new();
    for (transport, cap, mode) in [
        ("channel", usize::MAX, "barrier"),
        ("channel", 1usize, "bucketed"),
        ("socket", usize::MAX, "barrier"),
        ("socket", 1usize, "bucketed"),
    ] {
        let t = match transport {
            "channel" => run_overlap(Ring::new(WORLD).into_handles(), cap),
            _ => run_overlap(local_socket_ring(WORLD).expect("socketpair ring"), cap),
        };
        if mode == "bucketed" {
            bucketed_effs.push((transport, t.efficiency()));
        }
        overlap.row(&[
            transport.into(),
            mode.into(),
            fmt_ms_per_step(t.comm),
            fmt_ms_per_step(t.wait),
            fmt_ms_per_step(t.hidden()),
            format!("{:.2}", t.efficiency()),
        ]);
    }
    overlap.print(&format!(
        "Overlapped bucketed all-reduce, W={WORLD}, {OVERLAP_LAYERS} layers of \
         {OVERLAP_DIM}x{OVERLAP_DIM} (efficiency = comm hidden behind update / total comm)"
    ));
    for (transport, eff) in bucketed_effs {
        assert!(
            eff > 0.0,
            "bucketed path hid no communication on the {transport} ring"
        );
    }
    println!(
        "\nNote: bucketing changes only *when* each reduce runs (per bucket, while\n\
         earlier buckets' updates execute) — the collective sequence and every\n\
         reduced bit are identical to the barrier exchange."
    );
}
