//! Fig. 5 ablations: (left) subspace change frequency T has a sweet spot —
//! both very frequent and "never" underperform; (right) smaller rank with
//! proportionally more steps reaches comparable loss (memory-compute
//! trade-off).

use galore::bench::Table;
use galore::coordinator::Trainer;
use galore::exp::scale::{fig5_freq_sweep, fig5_rank_sweep};

fn main() -> anyhow::Result<()> {
    let (base, freqs) = fig5_freq_sweep();
    let mut t = Table::new(&["T", "eval loss", "eval ppl"]);
    let mut results = Vec::new();
    for f in freqs {
        let mut cfg = base.clone();
        cfg.galore.update_freq = f;
        eprintln!("[fig5-left] T = {f} ...");
        let mut trainer = Trainer::from_config(cfg.clone())?;
        for _ in 0..cfg.steps {
            trainer.train_step()?;
        }
        let loss = trainer.eval(cfg.eval_batches)?;
        let label = if f >= 1_000_000 { "never".into() } else { f.to_string() };
        t.row(&[label, format!("{loss:.4}"), format!("{:.2}", loss.exp())]);
        results.push((f, loss));
    }
    t.print("Fig. 5 left (subspace frequency sweep)");
    let best = results.iter().cloned().fold((0, f32::MAX), |a, b| if b.1 < a.1 { b } else { a });
    println!(
        "best T = {} — paper reports the sweet spot in 50..1000, extremes worse (U-shape).",
        best.0
    );

    let (base, sweep) = fig5_rank_sweep();
    let mut t2 = Table::new(&["rank", "steps", "eval loss", "eval ppl"]);
    for (rank, steps) in sweep {
        let mut cfg = base.clone();
        cfg.galore.rank = rank;
        cfg.lowrank_rank = rank;
        cfg.steps = steps;
        eprintln!("[fig5-right] rank {rank} x {steps} steps ...");
        let mut trainer = Trainer::from_config(cfg.clone())?;
        for _ in 0..cfg.steps {
            trainer.train_step()?;
        }
        let loss = trainer.eval(cfg.eval_batches)?;
        t2.row(&[rank.to_string(), steps.to_string(), format!("{loss:.4}"), format!("{:.2}", loss.exp())]);
    }
    t2.print("Fig. 5 right (rank x steps trade-off; paper: rank 128 x 80K beats rank 512 x 20K)");
    Ok(())
}
