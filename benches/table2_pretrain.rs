//! Table 2: pre-training comparison Full-Rank / GaLore / Low-Rank / LoRA /
//! ReLoRA at scaled sizes. Reports validation perplexity plus the memory
//! estimate (weights + optimizer states, BF16), and writes per-run loss
//! curves (Fig. 6) to runs/table2_*.csv.
//!
//! Paper (60M column): Full-Rank 34.06 (0.36G), GaLore 34.88 (0.24G),
//! Low-Rank 78.18, LoRA 34.99, ReLoRA 37.04. Expected shape here:
//! GaLore ≈ Full-Rank, Low-Rank far worse, LoRA/ReLoRA in between.

use galore::bench::Table;
use galore::config::MethodKind;
use galore::coordinator::Trainer;
use galore::exp::scale::table2_runs;
use galore::memory::{estimate, fmt_gib, Method, TrainOpts};

fn main() -> anyhow::Result<()> {
    let runs = table2_runs();
    let mut table = Table::new(&["model", "method", "eval ppl", "mem (wt+opt)", "paper 60M ppl"]);
    let paper: &[(MethodKind, &str)] = &[
        (MethodKind::FullRank, "34.06 (0.36G)"),
        (MethodKind::GaLore, "34.88 (0.24G)"),
        (MethodKind::LowRank, "78.18 (0.26G)"),
        (MethodKind::Lora, "34.99 (0.36G)"),
        (MethodKind::ReLora, "37.04 (0.36G)"),
    ];
    let mut summary: Vec<(String, MethodKind, f32)> = Vec::new();
    for cfg in runs {
        eprintln!("[table2] {} / {} ({} steps)...", cfg.model.name, cfg.method.label(), cfg.steps);
        let mut trainer = Trainer::from_config(cfg.clone())?;
        trainer.run()?;
        let eval = trainer.metrics.final_eval_loss().unwrap();
        let ppl = eval.exp();
        trainer
            .metrics
            .write_csv(format!("runs/table2_{}_{}.csv", cfg.model.name, cfg.method.label()))?;
        // One mapping for trainer-method -> memory-model (no local drift).
        let m = Method::for_kind(cfg.method, cfg.galore.rank);
        let b = estimate(cfg.model, m, TrainOpts::default());
        let paper_cell = paper
            .iter()
            .find(|(k, _)| *k == cfg.method)
            .map(|(_, s)| s.to_string())
            .unwrap_or_default();
        table.row(&[
            cfg.model.name.into(),
            cfg.method.label().into(),
            format!("{ppl:.2}"),
            fmt_gib(b.weights + b.optim_states),
            paper_cell,
        ]);
        summary.push((cfg.model.name.to_string(), cfg.method, ppl));
    }
    table.print("Table 2 (scaled reproduction; Fig. 6 curves in runs/table2_*.csv)");

    // Shape checks, printed as a verdict block.
    for model in summary.iter().map(|(m, _, _)| m.clone()).collect::<std::collections::BTreeSet<_>>() {
        let get = |k: MethodKind| summary.iter().find(|(m, kk, _)| *m == model && *kk == k).map(|(_, _, p)| *p);
        let (full, gal, low) = (get(MethodKind::FullRank), get(MethodKind::GaLore), get(MethodKind::LowRank));
        if let (Some(full), Some(gal), Some(low)) = (full, gal, low) {
            println!(
                "[{model}] GaLore within {:.1}% of Full-Rank (paper: 2.4%); Low-Rank {:.1}x worse (paper: 2.3x)",
                100.0 * (gal - full) / full,
                low / full
            );
        }
    }
    Ok(())
}
