//! Table 6: weight-parameter and optimizer-state memory estimates per
//! method per size (BF16). Exact analytic reproduction.
//! Paper 6a weights: Full/GaLore 0.12/0.25/0.68/2.60G; LoRA/ReLoRA
//! 0.20/0.44/1.04/3.79G. Paper 6b optim states (Full-Rank):
//! 0.23/0.51/1.37/5.20G.

use galore::bench::Table;
use galore::memory::{estimate, fmt_gib, Method, TrainOpts};
use galore::model::ModelConfig;

fn main() {
    let sizes = ["60m", "130m", "350m", "1b"];
    let methods: Vec<(&str, fn(usize) -> Method)> = vec![
        ("Full-Rank", |_| Method::FullRank),
        ("GaLore", |r| Method::GaLore { rank: r }),
        ("Low-Rank", |r| Method::LowRank { rank: r }),
        ("LoRA", |r| Method::Lora { rank: r }),
        ("ReLoRA", |r| Method::ReLora { rank: r }),
    ];
    // Table 2's rank row: 128/256/256/512.
    let ranks = [128usize, 256, 256, 512];

    let mut tw = Table::new(&["method", "60M", "130M", "350M", "1B"]);
    let mut ts = Table::new(&["method", "60M", "130M", "350M", "1B"]);
    for (name, mk) in &methods {
        let mut wrow = vec![name.to_string()];
        let mut srow = vec![name.to_string()];
        for (size, rank) in sizes.iter().zip(ranks.iter()) {
            let cfg = ModelConfig::by_name(size).unwrap();
            let b = estimate(cfg, mk(*rank), TrainOpts::default());
            wrow.push(fmt_gib(b.weights));
            srow.push(fmt_gib(b.optim_states));
        }
        tw.row(&wrow);
        ts.row(&srow);
    }
    tw.print("Table 6a: weight-parameter memory (paper Full-Rank row: 0.12/0.25/0.68/2.60G)");
    ts.print("Table 6b: optimizer-state memory (paper Full-Rank row: 0.23/0.51/1.37/5.20G)");
    println!("\nordering to verify: GaLore < Full-Rank states at every size; LoRA weights > Full-Rank weights.");
}
