//! Table 1: GaLore vs LoRA memory formulas + feature matrix. Exact
//! closed-form reproduction (no training, no artifacts).

use galore::bench::Table;
use galore::memory::formulas;
use galore::model::{schema, ModelConfig};

fn main() {
    // The paper's symbolic table, instantiated at each model size by
    // summing over the actual target matrices with r = d/4.
    let mut t = Table::new(&["", "GaLore", "LoRA"]);
    t.row(&["Weights".into(), "mn".into(), "mn + mr + nr".into()]);
    t.row(&["Optim States".into(), "mr + 2nr".into(), "2mr + 2nr".into()]);
    t.row(&["Multi-Subspace".into(), "yes".into(), "no".into()]);
    t.row(&["Pre-Training".into(), "yes".into(), "no".into()]);
    t.row(&["Fine-Tuning".into(), "yes".into(), "yes".into()]);
    t.print("Table 1 (symbolic, paper-verbatim)");

    let mut t2 = Table::new(&["model", "rank", "GaLore wt", "LoRA wt", "GaLore st", "LoRA st", "st ratio"]);
    for name in ["60m", "130m", "350m", "1b", "7b"] {
        let cfg = ModelConfig::by_name(name).unwrap();
        let r = cfg.default_rank() as u64;
        let (mut gw, mut lw, mut gs, mut ls) = (0u64, 0u64, 0u64, 0u64);
        for meta in schema(cfg) {
            if !meta.is_projection_target() {
                continue;
            }
            let (m, n) = (meta.rows as u64, meta.cols as u64);
            let g = formulas::galore(m, n, r);
            let l = formulas::lora(m, n, r);
            gw += g.weights;
            lw += l.weights;
            gs += g.optim_states;
            ls += l.optim_states;
        }
        t2.row(&[
            name.into(),
            r.to_string(),
            fmt_m(gw),
            fmt_m(lw),
            fmt_m(gs),
            fmt_m(ls),
            format!("{:.2}x", ls as f64 / gs as f64),
        ]);
    }
    t2.print("Table 1 instantiated over the real target matrices (elements)");
    println!("\npaper claim: GaLore < LoRA in both weights and optimizer states — holds at every size.");
}

fn fmt_m(v: u64) -> String {
    format!("{:.1}M", v as f64 / 1e6)
}
