//! Table 11: measured memory and throughput, layerwise on/off ×
//! {AdamW, Adafactor, Adam8bit, 8-bit GaLore}. Paper (1B, A100):
//! AdamW 1354 tok/s / 8-bit GaLore 1019 tok/s layerwise (17% overhead vs
//! 8-bit Adam), and +8.8% when layerwise is disabled. Shape to reproduce:
//! GaLore's throughput overhead is bounded (SVD amortized) and layerwise
//! trades a little throughput for grad memory.

use galore::bench::Table;
use galore::coordinator::Trainer;
use galore::exp::scale::table11_runs;
use galore::memory::fmt_gib;

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(&[
        "layerwise", "method", "tok/s", "exec %", "optim state", "peak grad", "eval loss",
    ]);
    let mut rows = Vec::new();
    for cfg in table11_runs() {
        eprintln!("[table11] {} layerwise={} ...", cfg.method.label(), cfg.layerwise);
        let mut trainer = Trainer::from_config(cfg.clone())?;
        let t0 = std::time::Instant::now();
        for _ in 0..cfg.steps {
            trainer.train_step()?;
        }
        let wall = t0.elapsed();
        let loss = trainer.eval(cfg.eval_batches)?;
        let tps = trainer.metrics.total_tokens() as f64 / wall.as_secs_f64();
        let exec_frac = 100.0 * trainer.metrics.exec_time.as_secs_f64() / wall.as_secs_f64();
        t.row(&[
            cfg.layerwise.to_string(),
            cfg.method.label().into(),
            format!("{tps:.0}"),
            format!("{exec_frac:.0}%"),
            fmt_gib(trainer.optimizer_state_bytes() as u64),
            fmt_gib(trainer.peak_grad_bytes as u64),
            format!("{loss:.3}"),
        ]);
        rows.push((cfg.method, cfg.layerwise, tps));
    }
    t.print("Table 11 (measured on this machine; paper numbers are A100 @ 1B)");
    use galore::config::MethodKind::*;
    let get = |m, lw| rows.iter().find(|(mm, l, _)| *mm == m && *l == lw).map(|(_, _, t)| *t);
    if let (Some(adam8), Some(gal8)) = (get(Adam8bit, true), get(GaLore8bit, true)) {
        println!("8-bit GaLore overhead vs 8-bit Adam (layerwise): {:.0}% (paper: 17%)", 100.0 * (1.0 - gal8 / adam8));
    }
    if let (Some(lw), Some(no)) = (get(GaLore8bit, true), get(GaLore8bit, false)) {
        println!("disabling layerwise changes GaLore throughput by {:+.1}% (paper: +8.8%)", 100.0 * (no / lw - 1.0));
    }
    Ok(())
}
