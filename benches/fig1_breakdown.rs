//! Fig. 1: memory breakdown of pre-training LLaMA 7B (token batch 256) —
//! exact analytic reproduction at the true shapes. Paper: BF16 Adam needs
//! ~58G; 8-bit GaLore (layerwise) 21.3G total, fitting an RTX 4090;
//! optimizer-state cut vs 8-bit Adam = 65.5%; total cut vs BF16 = 63.3%.

use galore::bench::Table;
use galore::memory::{estimate, fmt_gib, Method, TrainOpts};
use galore::model::ModelConfig;

fn main() {
    let m7b = ModelConfig::by_name("7b").unwrap();
    let opts = TrainOpts { token_batch: 256, ..Default::default() };
    let lw = TrainOpts { layerwise_updates: true, ..opts };
    let mut t = Table::new(&["method", "weights", "optim", "grads", "activ", "TOTAL", "<24G"]);
    let rows: Vec<(&str, Method, TrainOpts)> = vec![
        ("BF16 Adam", Method::FullRank, opts),
        ("8-bit Adam", Method::Adam8bit, opts),
        ("8-bit GaLore (retain grad)", Method::GaLore8bit { rank: 1024 }, opts),
        ("8-bit GaLore (layerwise)", Method::GaLore8bit { rank: 1024 }, lw),
    ];
    let mut totals = Vec::new();
    let mut optims = Vec::new();
    for (name, method, o) in &rows {
        let b = estimate(m7b, *method, *o);
        t.row(&[
            (*name).into(),
            fmt_gib(b.weights),
            fmt_gib(b.optim_states),
            fmt_gib(b.gradients),
            fmt_gib(b.activations),
            fmt_gib(b.total()),
            (b.total() < 24_000_000_000).to_string(),
        ]);
        totals.push(b.total());
        optims.push(b.optim_states);
    }
    t.print("Fig. 1 (LLaMA 7B, token batch 256)");
    println!(
        "\noptimizer-state cut vs 8-bit Adam: {:.1}% (paper: 65.5%)",
        100.0 * (1.0 - optims[3] as f64 / optims[1] as f64)
    );
    println!(
        "total cut vs BF16 Adam: {:.1}% (paper: 63.3%)   vs 8-bit Adam: {:.1}% (paper: 52.3%)",
        100.0 * (1.0 - totals[3] as f64 / totals[0] as f64),
        100.0 * (1.0 - totals[3] as f64 / totals[1] as f64)
    );
}
