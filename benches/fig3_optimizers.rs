//! Fig. 3: GaLore applied to different optimizers (AdamW, 8-bit Adam,
//! Adafactor). Paper: applying GaLore does not significantly affect
//! convergence while cutting optimizer memory ~62.5% at r=d/4.

use galore::bench::Table;
use galore::coordinator::Trainer;
use galore::exp::scale::fig3_runs;
use galore::memory::fmt_gib;

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&["optimizer", "eval ppl", "optim state", "curve"]);
    let mut pairs: Vec<(String, f32)> = Vec::new();
    for cfg in fig3_runs() {
        eprintln!("[fig3] {} ({} steps)...", cfg.method.label(), cfg.steps);
        let mut trainer = Trainer::from_config(cfg.clone())?;
        trainer.run()?;
        let eval = trainer.metrics.final_eval_loss().unwrap();
        let csv = trainer
            .metrics
            .write_csv(format!("runs/fig3_{}.csv", cfg.method.label()))?;
        table.row(&[
            cfg.method.label().into(),
            format!("{:.2}", eval.exp()),
            fmt_gib(trainer.optimizer_state_bytes() as u64),
            csv.display().to_string(),
        ]);
        pairs.push((cfg.method.label().to_string(), eval.exp()));
    }
    table.print("Fig. 3 (GaLore across optimizers)");
    let get = |n: &str| pairs.iter().find(|(m, _)| m == n).map(|(_, p)| *p);
    if let (Some(a), Some(g)) = (get("adamw"), get("galore")) {
        println!("GaLore vs AdamW ppl gap: {:+.1}% (paper: indistinguishable curves)", 100.0 * (g - a) / a);
    }
    if let (Some(a), Some(g)) = (get("adam8bit"), get("galore8bit")) {
        println!("8-bit GaLore vs 8-bit Adam ppl gap: {:+.1}%", 100.0 * (g - a) / a);
    }
    if let (Some(a), Some(g)) = (get("adafactor"), get("galore-adafactor")) {
        println!("GaLore-Adafactor vs Adafactor ppl gap: {:+.1}%", 100.0 * (g - a) / a);
    }
    Ok(())
}
