//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access (DESIGN.md §4), so this
//! vendored crate provides the API subset the framework uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait. Errors carry a message string (context is folded in as
//! `"context: cause"` prefixes rather than a source chain).

use std::fmt;

/// A string-backed error value, convertible from any `std::error::Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error directly from anything displayable.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion coherent
// (`?` works on io/xla/... errors inside `anyhow::Result` functions).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazily-computed context to an error, mirroring anyhow's trait.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display,
    {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_and_context() {
        let name = "artifact";
        let e = anyhow!("missing '{name}'");
        assert_eq!(e.to_string(), "missing 'artifact'");
        let e = anyhow!("got {} of {}", 1, 2);
        assert_eq!(e.to_string(), "got 1 of 2");
        let e = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");

        let r: std::result::Result<(), String> = Err("cause".into());
        let e = r.with_context(|| format!("loading {name}")).unwrap_err();
        assert_eq!(e.to_string(), "loading artifact: cause");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }
}
