//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links `xla_extension` (PJRT CPU client, HLO-proto
//! compilation, literal marshalling). That native library is not available
//! in this build, so this stub provides the exact API surface
//! `runtime::engine` compiles against and returns a clear "runtime
//! unavailable" error the moment anything would touch the device. The
//! artifact-gated integration tests self-skip before reaching it, and
//! `Engine::new` fails on the missing manifest first in fresh checkouts —
//! so the stub only ever reports itself when someone has artifacts but no
//! real PJRT build.

use std::fmt;
use std::path::Path;

/// Error type matching the real bindings' `Result` shape.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA/PJRT runtime is not available in this offline build \
         (link the real `xla` crate to execute HLO artifacts)"
    ))
}

/// PJRT CPU client (stub: construction always fails).
pub struct PjRtClient {}

/// A compiled executable resident on the client (stub).
pub struct PjRtLoadedExecutable {}

/// A device-side buffer (stub).
pub struct PjRtBuffer {}

/// A device handle (stub).
pub struct PjRtDevice {}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto {}

/// An XLA computation wrapping an HLO module (stub).
pub struct XlaComputation {}

/// A host-side literal (stub).
pub struct Literal {}

/// Array shape of a literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn devices(&self) -> Vec<PjRtDevice> {
        Vec::new()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({:?})",
            path.as_ref()
        )))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
