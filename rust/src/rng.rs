//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is unavailable in this offline build, so the framework
//! carries its own small, well-tested RNG stack: `splitmix64` for seeding,
//! `xoshiro256**` as the workhorse generator, Box–Muller for normals and a
//! rejection-free Zipf sampler for the synthetic corpus. Everything is
//! seeded explicitly — a training run is fully reproducible from its config.

/// splitmix64: used to expand a single u64 seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Snapshot the generator's full internal state — the xoshiro words
    /// plus the cached Box–Muller spare — for checkpointing. A generator
    /// rebuilt via [`Rng::from_state`] continues the exact same stream.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare_normal)
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4], spare_normal: Option<f64>) -> Rng {
        Rng { s, spare_normal }
    }

    /// Derive an independent child generator (for per-worker / per-layer
    /// streams). Deterministic in (self seed, tag).
    pub fn child(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits for a uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift bounded sampler (no modulo bias worth
        // caring about at our n << 2^64).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the spare value).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, std^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }

    /// Fill with uniform samples in [lo, hi).
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf(α) sampler over {0, .., n-1} via inverse-CDF on a precomputed
/// cumulative table. Used by the synthetic-C4 corpus generator to get the
/// heavy-tailed token frequencies of web text.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // Binary search the first index with cdf >= u.
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn child_streams_independent() {
        let root = Rng::new(7);
        let mut c1 = root.child(0);
        let mut c2 = root.child(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_is_heavy_tailed() {
        let zipf = Zipf::new(1000, 1.1);
        let mut rng = Rng::new(13);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        // Token 0 must dominate, and the tail must still be visited.
        assert!(counts[0] > counts[10] && counts[10] > counts[200]);
        assert!(counts.iter().filter(|&&c| c > 0).count() > 500);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
