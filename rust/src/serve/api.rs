//! Wire protocol of the `galore serve` control socket.
//!
//! Requests and responses are single u32-length-prefixed frames (the same
//! framing as the DP rendezvous, `coordinator::transport::{write_frame,
//! read_frame}`), with `ser`-encoded bodies: a one-byte verb/variant tag
//! followed by the variant's fields. A submit payload is a config
//! document in the repo's TOML subset — the ordinary `RunConfig` keys
//! plus a `[job]` section (`name`, `workload`, `p_bigram`); see
//! `config::toml`.

use crate::config::{RunConfig, TomlDoc};
use crate::coordinator::{JobInfo, JobSpec, JobState, WorkloadKind};
use crate::ser::{self, Reader};

/// Client → daemon verbs.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Submit a job; `payload` is a TOML-subset config document.
    Submit { payload: String },
    Status { id: u64 },
    Pause { id: u64 },
    Resume { id: u64 },
    Cancel { id: u64 },
    List,
    /// Evict all resident jobs to their checkpoints and exit the daemon.
    Shutdown,
}

/// Daemon → client replies.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Err(String),
    Submitted { id: u64 },
    Job(JobInfo),
    List { budget_bytes: u64, resident_bytes: u64, jobs: Vec<JobInfo> },
    Ok,
}

const REQ_SUBMIT: u8 = 1;
const REQ_STATUS: u8 = 2;
const REQ_PAUSE: u8 = 3;
const REQ_RESUME: u8 = 4;
const REQ_CANCEL: u8 = 5;
const REQ_LIST: u8 = 6;
const REQ_SHUTDOWN: u8 = 7;

const RESP_ERR: u8 = 1;
const RESP_SUBMITTED: u8 = 2;
const RESP_JOB: u8 = 3;
const RESP_LIST: u8 = 4;
const RESP_OK: u8 = 5;

pub fn encode_request(req: &Request, out: &mut Vec<u8>) {
    match req {
        Request::Submit { payload } => {
            ser::put_u8(out, REQ_SUBMIT);
            ser::put_str(out, payload);
        }
        Request::Status { id } => {
            ser::put_u8(out, REQ_STATUS);
            ser::put_u64(out, *id);
        }
        Request::Pause { id } => {
            ser::put_u8(out, REQ_PAUSE);
            ser::put_u64(out, *id);
        }
        Request::Resume { id } => {
            ser::put_u8(out, REQ_RESUME);
            ser::put_u64(out, *id);
        }
        Request::Cancel { id } => {
            ser::put_u8(out, REQ_CANCEL);
            ser::put_u64(out, *id);
        }
        Request::List => ser::put_u8(out, REQ_LIST),
        Request::Shutdown => ser::put_u8(out, REQ_SHUTDOWN),
    }
}

pub fn decode_request(bytes: &[u8]) -> Result<Request, String> {
    let mut r = Reader::new(bytes);
    let req = match r.u8()? {
        REQ_SUBMIT => Request::Submit { payload: r.str()? },
        REQ_STATUS => Request::Status { id: r.u64()? },
        REQ_PAUSE => Request::Pause { id: r.u64()? },
        REQ_RESUME => Request::Resume { id: r.u64()? },
        REQ_CANCEL => Request::Cancel { id: r.u64()? },
        REQ_LIST => Request::List,
        REQ_SHUTDOWN => Request::Shutdown,
        tag => return Err(format!("unknown request tag {tag}")),
    };
    r.expect_end()?;
    Ok(req)
}

fn put_info(out: &mut Vec<u8>, info: &JobInfo) {
    ser::put_u64(out, info.id);
    ser::put_str(out, &info.name);
    ser::put_str(out, info.state.label());
    ser::put_usize(out, info.step);
    ser::put_usize(out, info.steps_total);
    match info.tail_loss {
        Some(l) => {
            ser::put_bool(out, true);
            ser::put_f32(out, l);
        }
        None => ser::put_bool(out, false),
    }
    ser::put_u64(out, info.tokens);
    ser::put_u64(out, info.est_bytes);
    ser::put_bool(out, info.resident);
    match &info.error {
        Some(e) => {
            ser::put_bool(out, true);
            ser::put_str(out, e);
        }
        None => ser::put_bool(out, false),
    }
}

fn read_info(r: &mut Reader<'_>) -> Result<JobInfo, String> {
    Ok(JobInfo {
        id: r.u64()?,
        name: r.str()?,
        state: JobState::parse(&r.str()?)?,
        step: r.usize()?,
        steps_total: r.usize()?,
        tail_loss: if r.bool()? { Some(r.f32()?) } else { None },
        tokens: r.u64()?,
        est_bytes: r.u64()?,
        resident: r.bool()?,
        error: if r.bool()? { Some(r.str()?) } else { None },
    })
}

pub fn encode_response(resp: &Response, out: &mut Vec<u8>) {
    match resp {
        Response::Err(e) => {
            ser::put_u8(out, RESP_ERR);
            ser::put_str(out, e);
        }
        Response::Submitted { id } => {
            ser::put_u8(out, RESP_SUBMITTED);
            ser::put_u64(out, *id);
        }
        Response::Job(info) => {
            ser::put_u8(out, RESP_JOB);
            put_info(out, info);
        }
        Response::List { budget_bytes, resident_bytes, jobs } => {
            ser::put_u8(out, RESP_LIST);
            ser::put_u64(out, *budget_bytes);
            ser::put_u64(out, *resident_bytes);
            ser::put_usize(out, jobs.len());
            for info in jobs {
                put_info(out, info);
            }
        }
        Response::Ok => ser::put_u8(out, RESP_OK),
    }
}

pub fn decode_response(bytes: &[u8]) -> Result<Response, String> {
    let mut r = Reader::new(bytes);
    let resp = match r.u8()? {
        RESP_ERR => Response::Err(r.str()?),
        RESP_SUBMITTED => Response::Submitted { id: r.u64()? },
        RESP_JOB => Response::Job(read_info(&mut r)?),
        RESP_LIST => {
            let budget_bytes = r.u64()?;
            let resident_bytes = r.u64()?;
            let n = r.usize()?;
            let mut jobs = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                jobs.push(read_info(&mut r)?);
            }
            Response::List { budget_bytes, resident_bytes, jobs }
        }
        RESP_OK => Response::Ok,
        tag => return Err(format!("unknown response tag {tag}")),
    };
    r.expect_end()?;
    Ok(resp)
}

/// Parse a submit payload into a [`JobSpec`]: the ordinary `RunConfig`
/// document plus the `[job]` section. Defaults: workload `synthetic`,
/// name `{model}-{method}`.
pub fn parse_submit_payload(text: &str) -> Result<JobSpec, String> {
    let doc = TomlDoc::parse(text)?;
    let cfg = RunConfig::from_toml(&doc)?;
    cfg.validate()?;
    let workload = WorkloadKind::parse(
        doc.get("job", "workload").unwrap_or("synthetic"),
        doc.get_parse("job", "p_bigram"),
    )?;
    let name = doc
        .get("job", "name")
        .map(str::to_string)
        .unwrap_or_else(|| format!("{}-{}", cfg.model.name, cfg.method.label()));
    Ok(JobSpec { name, workload, cfg })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_roundtrip() {
        for req in [
            Request::Submit { payload: "model = \"nano\"".into() },
            Request::Status { id: 3 },
            Request::Pause { id: 1 },
            Request::Resume { id: 2 },
            Request::Cancel { id: 9 },
            Request::List,
            Request::Shutdown,
        ] {
            let mut buf = Vec::new();
            encode_request(&req, &mut buf);
            assert_eq!(decode_request(&buf).unwrap(), req);
        }
        assert!(decode_request(&[99]).is_err());
    }

    #[test]
    fn responses_roundtrip() {
        let info = JobInfo {
            id: 4,
            name: "syn-cola".into(),
            state: JobState::Paused,
            step: 120,
            steps_total: 400,
            tail_loss: Some(2.25),
            tokens: 61_440,
            est_bytes: 123_456,
            resident: false,
            error: None,
        };
        for resp in [
            Response::Err("boom".into()),
            Response::Submitted { id: 7 },
            Response::Job(info.clone()),
            Response::List {
                budget_bytes: 1 << 30,
                resident_bytes: 1 << 20,
                jobs: vec![info.clone(), JobInfo { tail_loss: None, error: Some("x".into()), ..info }],
            },
            Response::Ok,
        ] {
            let mut buf = Vec::new();
            encode_response(&resp, &mut buf);
            assert_eq!(decode_response(&buf).unwrap(), resp);
        }
    }

    #[test]
    fn submit_payload_parses_job_section() {
        let spec = parse_submit_payload(
            "model = \"nano\"\nmethod = \"galore\"\nsteps = 12\n\n[job]\nname = \"demo\"\nworkload = \"finetune\"\np_bigram = 0.8\n",
        )
        .unwrap();
        assert_eq!(spec.name, "demo");
        assert_eq!(spec.workload, WorkloadKind::Finetune { p_bigram: 0.8 });
        assert_eq!(spec.cfg.steps, 12);

        let spec = parse_submit_payload("model = \"nano\"\n").unwrap();
        assert_eq!(spec.workload, WorkloadKind::Synthetic);
        assert_eq!(spec.name, "nano-galore");

        assert!(parse_submit_payload("model = \"nope\"").is_err());
        assert!(parse_submit_payload("model = \"nano\"\n[job]\nworkload = \"x\"\n").is_err());
    }
}
