//! The serve scheduler: memory-budgeted admission + round-robin step
//! slices over the job table.
//!
//! Admission control is strict-FIFO over the queue: a queued job is
//! admitted when (a) fewer than `max_jobs` jobs are resident and (b) its
//! `memory::breakdown` estimate fits in what remains of
//! `mem_budget_mb`. A job whose estimate exceeds the *whole* budget can
//! never run and fails immediately with the admission math in its error;
//! a job that merely doesn't fit *right now* stays `Queued` until
//! completions/pauses free capacity — the budget throttles, it never
//! OOM-admits. FIFO means a large queued job also blocks later small
//! ones (no starvation of big jobs by a stream of small ones).
//!
//! Execution is cooperative: each [`Scheduler::tick`] advances one
//! resident job by `slice_steps` steps, cycling round-robin, so K
//! concurrent jobs progress at the same step cadence a single run would.
//! Jobs with identical artifact directories share one [`Engine`] handle
//! (`Engine::share`), hence one compiled-executable cache — layer shapes
//! shared across jobs compile once.

use crate::config::ServeConfig;
use crate::coordinator::{Job, JobInfo, JobSpec, JobState, WorkloadKind};
use crate::memory::fmt_gib;
use crate::runtime::Engine;
use crate::serve::api::{parse_submit_payload, Request, Response};
use std::collections::HashMap;
use std::io::Write;
use std::path::PathBuf;

pub struct Scheduler {
    pub cfg: ServeConfig,
    jobs: Vec<Job>,
    next_id: u64,
    /// Round-robin cursor over job ids (not indices — stable across
    /// submissions).
    rr: usize,
    /// Shared engine handles, one per artifact directory. Lazily built;
    /// every job on the same directory gets a `share()` of the same
    /// compiled cache.
    engines: HashMap<PathBuf, Engine>,
    /// Per-job count of step records already flushed to the JSONL log
    /// (restored history is not re-flushed).
    logged: HashMap<u64, usize>,
}

impl Scheduler {
    pub fn new(cfg: ServeConfig) -> Result<Scheduler, String> {
        cfg.validate()?;
        std::fs::create_dir_all(&cfg.job_dir)
            .map_err(|e| format!("cannot create job dir {:?}: {e}", cfg.job_dir))?;
        Ok(Scheduler {
            cfg,
            jobs: Vec::new(),
            next_id: 1,
            rr: 0,
            engines: HashMap::new(),
            logged: HashMap::new(),
        })
    }

    /// Total estimated bytes of currently-resident jobs — the quantity
    /// admission charges against the budget.
    pub fn resident_bytes(&self) -> u64 {
        self.jobs.iter().filter(|j| j.is_resident()).map(|j| j.estimated_bytes()).sum()
    }

    pub fn resident_count(&self) -> usize {
        self.jobs.iter().filter(|j| j.is_resident()).count()
    }

    pub fn submit(&mut self, spec: JobSpec) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.push(Job::new(id, spec, std::path::Path::new(&self.cfg.job_dir)));
        id
    }

    fn job_mut(&mut self, id: u64) -> Result<&mut Job, String> {
        self.jobs.iter_mut().find(|j| j.id == id).ok_or_else(|| format!("no job with id {id}"))
    }

    pub fn status(&self, id: u64) -> Result<JobInfo, String> {
        self.jobs
            .iter()
            .find(|j| j.id == id)
            .map(Job::info)
            .ok_or_else(|| format!("no job with id {id}"))
    }

    pub fn pause(&mut self, id: u64) -> Result<(), String> {
        let job = self.job_mut(id)?;
        match job.state {
            // Not yet resident: parking a queued job is just marking it
            // paused so admission skips it.
            JobState::Queued => {
                job.state = JobState::Paused;
                Ok(())
            }
            _ => job.pause_evict().map_err(|e| format!("{e:#}")),
        }
    }

    pub fn resume(&mut self, id: u64) -> Result<(), String> {
        self.job_mut(id)?.resume_to_queue().map_err(|e| format!("{e:#}"))
    }

    pub fn cancel(&mut self, id: u64) -> Result<(), String> {
        let job = self.job_mut(id)?;
        job.cancel().map_err(|e| format!("{e:#}"))
    }

    pub fn list(&self) -> (u64, u64, Vec<JobInfo>) {
        (self.cfg.budget_bytes(), self.resident_bytes(), self.jobs.iter().map(Job::info).collect())
    }

    /// Shared engine handle for `dir`, built on first use. `None` when
    /// the engine cannot be constructed — the job's own admission then
    /// reports the root cause.
    fn shared_engine(&mut self, dir: PathBuf) -> Option<&Engine> {
        if !self.engines.contains_key(&dir) {
            match Engine::new(&dir) {
                Ok(e) => {
                    self.engines.insert(dir.clone(), e);
                }
                Err(_) => return None,
            }
        }
        self.engines.get(&dir)
    }

    /// Strict-FIFO admission against `max_jobs` and the byte budget.
    fn try_admit(&mut self) {
        let budget = self.cfg.budget_bytes();
        loop {
            if self.resident_count() >= self.cfg.max_jobs {
                return;
            }
            let resident = self.resident_bytes();
            let Some(idx) = self.jobs.iter().position(|j| j.state == JobState::Queued) else {
                return;
            };
            let est = self.jobs[idx].estimated_bytes();
            if budget > 0 && est > budget {
                let job = &mut self.jobs[idx];
                job.state = JobState::Failed;
                job.error = Some(format!(
                    "estimated footprint {} exceeds the total memory budget {} — \
                     this job can never be admitted (raise serve.mem_budget_mb or \
                     shrink the job)",
                    fmt_gib(est),
                    fmt_gib(budget)
                ));
                continue;
            }
            if budget > 0 && resident + est > budget {
                // Head-of-queue doesn't fit *yet*: wait for capacity.
                // FIFO — later (smaller) jobs do not jump the queue.
                return;
            }
            let needs_engine = !matches!(self.jobs[idx].spec.workload, WorkloadKind::Synthetic);
            let engine = if needs_engine {
                let dir = self.jobs[idx].spec.cfg.artifacts_dir();
                self.shared_engine(dir).map(Engine::share)
            } else {
                None
            };
            let job = &mut self.jobs[idx];
            let id = job.id;
            if let Err(e) = job.admit(engine.as_ref()) {
                job.error = Some(format!("{e:#}"));
                job.state = JobState::Failed;
                continue;
            }
            // Restored history was flushed by whoever ran it before the
            // eviction; only new records go to the log.
            let already = job.records().map_or(0, <[_]>::len);
            self.logged.insert(id, already);
        }
    }

    /// One cooperative scheduling turn: admit what fits, then advance the
    /// next resident job by `slice_steps`. Returns `true` if any job ran
    /// (the daemon sleeps when a tick does nothing).
    pub fn tick(&mut self) -> bool {
        self.try_admit();
        let resident: Vec<usize> = (0..self.jobs.len())
            .filter(|&i| self.jobs[i].is_resident() && !self.jobs[i].state.is_terminal())
            .collect();
        if resident.is_empty() {
            return false;
        }
        let idx = resident[self.rr % resident.len()];
        self.rr = self.rr.wrapping_add(1);
        let ran = self.jobs[idx].run_slice(self.cfg.slice_steps);
        if self.cfg.step_log {
            self.flush_step_log(idx);
        }
        // A completion/failure may have freed budget for the queue head.
        self.try_admit();
        ran > 0
    }

    /// Append the job's newly-logged step records to the shared JSONL log
    /// (one object per line, `job` field first — the per-job namespacing
    /// the CSV sink gets from `Metrics::job_id`).
    fn flush_step_log(&mut self, idx: usize) {
        let job = &self.jobs[idx];
        let id = job.id;
        let name = job.spec.name.clone();
        let Some(records) = job.records() else { return };
        let from = *self.logged.get(&id).unwrap_or(&0);
        if from >= records.len() {
            return;
        }
        let path = std::path::Path::new(&self.cfg.job_dir).join("steps.jsonl");
        let mut lines = String::new();
        for r in &records[from..] {
            lines.push_str(&format!(
                "{{\"job\":{id},\"name\":\"{name}\",\"step\":{},\"loss\":{},\"lr\":{},\"tokens\":{}}}\n",
                r.step, r.loss, r.lr, r.tokens
            ));
        }
        let n = records.len();
        self.logged.insert(id, n);
        let res = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .and_then(|mut f| f.write_all(lines.as_bytes()));
        if let Err(e) = res {
            eprintln!("galore serve: cannot append step log {path:?}: {e}");
        }
    }

    /// Evict every resident job to its checkpoint (daemon shutdown: all
    /// in-flight work survives to the next start).
    pub fn evict_all(&mut self) {
        for job in &mut self.jobs {
            if job.is_resident() {
                if let Err(e) = job.pause_evict() {
                    eprintln!("galore serve: evicting job {} failed: {e:#}", job.id);
                }
            }
        }
    }

    /// Central verb dispatch, shared by the socket daemon and in-process
    /// tests.
    pub fn handle(&mut self, req: &Request) -> Response {
        match req {
            Request::Submit { payload } => match parse_submit_payload(payload) {
                Ok(spec) => Response::Submitted { id: self.submit(spec) },
                Err(e) => Response::Err(e),
            },
            Request::Status { id } => match self.status(*id) {
                Ok(info) => Response::Job(info),
                Err(e) => Response::Err(e),
            },
            Request::Pause { id } => match self.pause(*id) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Err(e),
            },
            Request::Resume { id } => match self.resume(*id) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Err(e),
            },
            Request::Cancel { id } => match self.cancel(*id) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Err(e),
            },
            Request::List => {
                let (budget_bytes, resident_bytes, jobs) = self.list();
                Response::List { budget_bytes, resident_bytes, jobs }
            }
            Request::Shutdown => {
                self.evict_all();
                Response::Ok
            }
        }
    }
}
