//! `galore serve` — the resident multi-job training service.
//!
//! One daemon process owns a job table ([`coordinator::job`]), admits
//! jobs against a memory budget, and round-robins step slices across the
//! resident set ([`scheduler`]) while answering control requests on a
//! Unix-domain socket ([`api`]): `submit` / `status` / `pause` /
//! `resume` / `cancel` / `list` / `shutdown`. `galore client` speaks the
//! same protocol for scripting.
//!
//! The daemon is deliberately single-threaded: job slices and socket
//! requests interleave on one loop, so every verb observes a consistent
//! job table and no locking is needed. A `pause` lands between slices —
//! at most `slice_steps` steps of latency — and shutdown evicts every
//! resident job to its suspend checkpoint first, so in-flight work
//! survives a daemon restart.

pub mod api;
pub mod scheduler;

pub use api::{parse_submit_payload, Request, Response};
pub use scheduler::Scheduler;

use crate::config::ServeConfig;
use crate::coordinator::transport::{read_frame, write_frame};
use anyhow::{anyhow, Context, Result};
use std::io;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::time::Duration;

/// Run the daemon until a `shutdown` request arrives. Binds
/// `cfg.socket_path` (replacing a stale socket file from a previous
/// run), then alternates between draining pending control connections
/// and ticking the scheduler; sleeps briefly when both are idle.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    cfg.validate().map_err(|e| anyhow!(e))?;
    let sock = Path::new(&cfg.socket_path).to_path_buf();
    if sock.exists() {
        std::fs::remove_file(&sock)
            .with_context(|| format!("cannot replace stale socket {sock:?}"))?;
    }
    if let Some(dir) = sock.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let listener =
        UnixListener::bind(&sock).with_context(|| format!("cannot bind {sock:?}"))?;
    listener.set_nonblocking(true)?;
    let mut sched = Scheduler::new(cfg).map_err(|e| anyhow!(e))?;
    eprintln!("galore serve: listening on {sock:?}");
    loop {
        let mut accepted = false;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    accepted = true;
                    match handle_conn(stream, &mut sched) {
                        Ok(true) => {
                            // Shutdown: `handle` already evicted all
                            // resident jobs to their checkpoints.
                            let _ = std::fs::remove_file(&sock);
                            eprintln!("galore serve: shut down");
                            return Ok(());
                        }
                        Ok(false) => {}
                        Err(e) => eprintln!("galore serve: connection error: {e:#}"),
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(e).context("accepting on the serve socket"),
            }
        }
        let worked = sched.tick();
        if !worked && !accepted {
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

/// Serve one request/response exchange; returns whether it was
/// `shutdown`.
fn handle_conn(mut stream: UnixStream, sched: &mut Scheduler) -> Result<bool> {
    stream.set_nonblocking(false)?;
    // A stalled client must not wedge the daemon.
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let bytes = read_frame(&mut stream)?;
    let req = api::decode_request(&bytes).map_err(|e| anyhow!("bad request: {e}"))?;
    let shutdown = matches!(req, Request::Shutdown);
    let resp = sched.handle(&req);
    let mut out = Vec::new();
    api::encode_response(&resp, &mut out);
    write_frame(&mut stream, &out)?;
    Ok(shutdown)
}

/// Client side: one request/response round-trip against a running
/// daemon's socket.
pub fn request(socket: impl AsRef<Path>, req: &Request) -> Result<Response> {
    let socket = socket.as_ref();
    let mut stream = UnixStream::connect(socket).with_context(|| {
        format!("cannot reach the serve daemon at {socket:?} (is `galore serve` running?)")
    })?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    let mut out = Vec::new();
    api::encode_request(req, &mut out);
    write_frame(&mut stream, &out)?;
    let bytes = read_frame(&mut stream)?;
    api::decode_response(&bytes).map_err(|e| anyhow!("bad response: {e}"))
}
