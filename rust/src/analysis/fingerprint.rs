//! Lint pass 3: fingerprint coverage of `RunConfig`.
//!
//! `RunConfig::fingerprint()` is the resume gate: a checkpoint resumed
//! under a different fingerprint could silently diverge from the
//! uninterrupted trajectory, so every config field must either feed the
//! fingerprint or be *deliberately* exempted. This pass parses the
//! `RunConfig` struct, the `fingerprint()` body, and the
//! `FINGERPRINT_EXEMPT` const out of `config/run.rs` and enforces:
//!
//! - every `RunConfig` field is mentioned as `self.<field>` inside
//!   `fingerprint()` or listed in `FINGERPRINT_EXEMPT`;
//! - every `GaLoreConfig` field (from `optim/galore.rs`, reached via
//!   `let g = &self.galore;`) is mentioned as `g.<field>` or listed as
//!   `galore.<field>`;
//! - every exemption carries a non-empty justification and names a
//!   field that actually exists (no stale entries);
//! - no field is both fingerprinted *and* exempted (a contradictory
//!   entry would stop documenting reality).
//!
//! The net effect: adding a config knob without deciding its resume
//! semantics is a lint failure, not a latent divergence bug.

use super::scan::SourceFile;
use super::Diagnostic;

pub const RULE: &str = "fingerprint-covers-config";

/// Path suffix of the file holding `RunConfig` + `fingerprint()`.
pub const RUN_CONFIG_PATH: &str = "config/run.rs";
/// Path suffix of the file holding `GaLoreConfig`.
pub const GALORE_CONFIG_PATH: &str = "optim/galore.rs";

pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let Some(run) = files.iter().find(|f| f.path.ends_with(RUN_CONFIG_PATH)) else {
        // Fixture trees without the anchor file skip the pass; `run_lint`
        // separately asserts the anchor exists in the real tree.
        return Vec::new();
    };
    let mut out = Vec::new();
    let fields = struct_fields(run, "RunConfig");
    let body = fingerprint_body(run);
    let exempt = exempt_entries(run);

    if fields.is_empty() {
        out.push(diag(run, 1, "could not parse `struct RunConfig` fields".into()));
        return out;
    }
    let Some(body) = body else {
        out.push(diag(run, 1, "could not find `fn fingerprint` in config/run.rs".into()));
        return out;
    };

    for (name, line) in &fields {
        let used = mentions(&body, &format!("self.{name}"));
        let exempted = exempt.iter().any(|e| e.name == *name);
        if !used && !exempted {
            out.push(diag(
                run,
                *line,
                format!(
                    "RunConfig field `{name}` is neither in fingerprint() nor in \
                     FINGERPRINT_EXEMPT — decide its resume semantics"
                ),
            ));
        }
        if used && exempted {
            out.push(diag(
                run,
                *line,
                format!("RunConfig field `{name}` is fingerprinted AND exempted — drop the stale exemption"),
            ));
        }
    }

    // GaLoreConfig fields flow in via `let g = &self.galore;`.
    let galore_fields = files
        .iter()
        .find(|f| f.path.ends_with(GALORE_CONFIG_PATH))
        .map(|f| struct_fields(f, "GaLoreConfig"))
        .unwrap_or_default();
    for (name, _line) in &galore_fields {
        let used = mentions(&body, &format!("g.{name}"))
            || mentions(&body, &format!("self.galore.{name}"));
        let exempted = exempt.iter().any(|e| e.name == format!("galore.{name}"));
        if !used && !exempted {
            out.push(diag(
                run,
                1,
                format!(
                    "GaLoreConfig field `{name}` is neither in fingerprint() (as `g.{name}`) \
                     nor exempted as `galore.{name}`"
                ),
            ));
        }
    }

    for e in &exempt {
        if e.reason.trim().is_empty() {
            out.push(diag(
                run,
                e.line,
                format!("FINGERPRINT_EXEMPT entry `{}` has an empty justification", e.name),
            ));
        }
        let bare = e.name.strip_prefix("galore.").unwrap_or(&e.name);
        let known = if e.name.starts_with("galore.") {
            galore_fields.is_empty() || galore_fields.iter().any(|(n, _)| n == bare)
        } else {
            fields.iter().any(|(n, _)| n == bare)
        };
        if !known {
            out.push(diag(
                run,
                e.line,
                format!("FINGERPRINT_EXEMPT names unknown field `{}` — stale entry?", e.name),
            ));
        }
    }
    out
}

fn diag(f: &SourceFile, line: usize, message: String) -> Diagnostic {
    Diagnostic { file: f.path.clone(), line, rule: RULE, message }
}

/// `token` present with a word boundary after it (`self.model` must not
/// match inside `self.model_name`).
fn mentions(body: &str, token: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = body[start..].find(token) {
        let at = start + pos;
        start = at + token.len();
        let after_ok = body[at + token.len()..]
            .chars()
            .next()
            .map(|c| !(c.is_alphanumeric() || c == '_'))
            .unwrap_or(true);
        if after_ok {
            return true;
        }
    }
    false
}

/// Field names (with 1-indexed declaration lines) of `struct <name>`,
/// parsed from the masked text: lines at brace depth 1 of the struct
/// body shaped like `[pub] ident:`.
fn struct_fields(f: &SourceFile, name: &str) -> Vec<(String, usize)> {
    let needle = format!("struct {name}");
    let Some(start_idx) = f.masked.iter().position(|l| {
        l.find(&needle).map(|p| {
            let after = l[p + needle.len()..].chars().next();
            matches!(after, None | Some(' ') | Some('{') | Some('<') | Some('('))
        }) == Some(true)
    }) else {
        return Vec::new();
    };
    let mut fields = Vec::new();
    let mut depth = 0i32;
    let mut opened = false;
    for (idx, line) in f.masked.iter().enumerate().skip(start_idx) {
        if opened && depth == 1 {
            let t = line.trim();
            let t = t.strip_prefix("pub ").unwrap_or(t);
            let ident: String =
                t.chars().take_while(|c| c.is_alphanumeric() || *c == '_').collect();
            if !ident.is_empty() && t[ident.len()..].starts_with(':') {
                fields.push((ident, idx + 1));
            }
        }
        for c in line.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth == 0 {
            break;
        }
    }
    fields
}

/// The masked text of `fn fingerprint`'s span.
fn fingerprint_body(f: &SourceFile) -> Option<String> {
    let span = f.fns.iter().find(|s| s.name == "fingerprint")?;
    Some(f.masked[span.start_line - 1..span.end_line].join("\n"))
}

struct Exempt {
    name: String,
    reason: String,
    line: usize,
}

/// Entries of `FINGERPRINT_EXEMPT: &[(&str, &str)]`, read from the RAW
/// lines (the masked text blanks string literals). String literals are
/// collected in order across the const's lines and paired up.
fn exempt_entries(f: &SourceFile) -> Vec<Exempt> {
    let Some(start) = f.masked.iter().position(|l| l.contains("FINGERPRINT_EXEMPT")) else {
        return Vec::new();
    };
    let mut strings: Vec<(String, usize)> = Vec::new();
    for (idx, raw) in f.lines.iter().enumerate().skip(start) {
        let mut rest = raw.as_str();
        let mut consumed = 0usize;
        while let Some(open) = rest.find('"') {
            let Some(close_rel) = rest[open + 1..].find('"') else { break };
            let lit = &rest[open + 1..open + 1 + close_rel];
            strings.push((lit.to_string(), idx + 1));
            consumed += open + close_rel + 2;
            rest = &raw[consumed..];
        }
        // The masked line still shows structure; `];` outside a literal
        // ends the const.
        if f.masked[idx].contains("];") {
            break;
        }
    }
    strings
        .chunks(2)
        .filter_map(|pair| match pair {
            [(name, line), (reason, _)] => {
                Some(Exempt { name: name.clone(), reason: reason.clone(), line: *line })
            }
            _ => None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::SourceFile;

    const COVERED: &str = r#"
pub struct RunConfig {
    pub steps: usize,
    pub lr: f32,
    pub threads: usize,
}

pub const FINGERPRINT_EXEMPT: &[(&str, &str)] = &[
    ("threads", "bit-identical at any pool width"),
];

impl RunConfig {
    pub fn fingerprint(&self) -> String {
        format!("steps={} lr={}", self.steps, self.lr)
    }
}
"#;

    fn lint(src: &str) -> Vec<Diagnostic> {
        check(&[SourceFile::parse("config/run.rs", src)])
    }

    #[test]
    fn covered_config_is_clean() {
        assert!(lint(COVERED).is_empty(), "{:?}", lint(COVERED));
    }

    #[test]
    fn unfingerprinted_field_flagged() {
        let src = COVERED.replace("pub lr: f32,", "pub lr: f32,\n    pub new_knob: bool,");
        let d = lint(&src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("new_knob"));
        assert_eq!(d[0].rule, RULE);
    }

    #[test]
    fn exempting_the_new_field_clears_it() {
        let src = COVERED
            .replace("pub lr: f32,", "pub lr: f32,\n    pub new_knob: bool,")
            .replace(
                "(\"threads\",",
                "(\"new_knob\", \"observation only\"),\n    (\"threads\",",
            );
        assert!(lint(&src).is_empty(), "{:?}", lint(&src));
    }

    #[test]
    fn empty_justification_flagged() {
        let src = COVERED.replace("\"bit-identical at any pool width\"", "\"  \"");
        let d = lint(&src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("empty justification"));
    }

    #[test]
    fn stale_exemption_flagged() {
        let src = COVERED.replace("(\"threads\"", "(\"gone_field\"");
        let d = lint(&src);
        // gone_field is stale AND threads is now uncovered.
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(d.iter().any(|x| x.message.contains("unknown field `gone_field`")));
        assert!(d.iter().any(|x| x.message.contains("`threads`")));
    }

    #[test]
    fn fingerprinted_and_exempted_is_contradictory() {
        let src = COVERED.replace(
            "self.steps, self.lr",
            "self.steps, self.lr, self.threads",
        );
        let d = lint(&src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("AND exempted"));
    }

    #[test]
    fn prefix_field_name_does_not_count_as_coverage() {
        // `self.lr_max` in the body must not cover a field named `lr`.
        let src = COVERED.replace("self.steps, self.lr", "self.steps, self.lr_max");
        let d = lint(&src);
        assert!(d.iter().any(|x| x.message.contains("`lr`")), "{d:?}");
    }

    #[test]
    fn galore_fields_checked_via_g_alias() {
        let galore = "pub struct GaLoreConfig {\n    pub rank: usize,\n    pub scale: f32,\n}\n";
        let run = COVERED.replace(
            "format!(\"steps={} lr={}\", self.steps, self.lr)",
            "let g = &self.galore;\n        format!(\"steps={} lr={} rank={}\", self.steps, self.lr, g.rank)",
        );
        let files = [
            SourceFile::parse("config/run.rs", &run),
            SourceFile::parse("optim/galore.rs", galore),
        ];
        let d = check(&files);
        // `scale` is neither `g.scale` in the body nor exempted.
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("`scale`"));
    }

    #[test]
    fn missing_anchor_file_skips_pass() {
        let files = [SourceFile::parse("other.rs", "fn x() {}")];
        assert!(check(&files).is_empty());
    }
}
