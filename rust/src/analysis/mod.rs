//! `galore lint`: a zero-dependency invariant analyzer for this tree.
//!
//! The fast paths bought in earlier PRs rest on invariants that a
//! general-purpose linter cannot know: raw-pointer task dispatch is
//! sound only because per-parameter state is disjoint; resume is sound
//! only because `fingerprint()` covers every trajectory-shaping knob;
//! checkpoints round-trip only because every section tag has both a
//! writer and a reader. Those contracts used to live in comments and
//! reviewer memory. This module machine-checks them on every CI run.
//!
//! ## The passes
//!
//! | rule | invariant |
//! |------|-----------|
//! | [`safety`] `unsafe-needs-safety-comment` | every `unsafe` block / `unsafe impl` / `unsafe fn` carries a `// SAFETY:` comment nearby |
//! | [`panics`] `no-panic-on-hot-paths` | no `.unwrap()` / `.expect()` / `panic!` in non-test code under `coordinator/`, `serve/`, `optim/`, `runtime/` without a justified `// PANIC-OK:` allowlist comment |
//! | [`fingerprint`] `fingerprint-covers-config` | every `RunConfig` / `GaLoreConfig` field feeds `fingerprint()` or sits in `FINGERPRINT_EXEMPT` with a justification |
//! | [`sections`] `checkpoint-section-symmetry` | every checkpoint `SEC_*` tag written by a save path is read by a load/restore path, and vice versa (legacy tags: read-only) |
//!
//! ## Why a hand-rolled scanner
//!
//! The build is vendored-offline (no external crates), so [`scan`] is a
//! small lexical front end: it masks comments and string/char literals,
//! tracks `#[cfg(test)]` / `#[test]` regions, and records function
//! spans. That is enough signal for line-oriented invariant checks
//! without a real parser — each pass works on the masked text, so
//! `unsafe` in a doc comment or `"panic!"` in a log string never
//! false-positives.
//!
//! ## Running it
//!
//! ```text
//! cargo run --release -- lint          # exits 0 clean, 1 with file:line diagnostics
//! cargo run --release -- lint path/to/src
//! ```
//!
//! The static passes are paired with a dynamic check: a
//! `debug_assertions`-gated aliasing sanitizer in `runtime::pool` that
//! records each submitted task's claimed `[ptr, ptr+len)` ranges and
//! panics on overlap, turning the "disjoint per-param state" argument
//! into an executed assertion under the debug test matrix.

pub mod fingerprint;
pub mod panics;
pub mod safety;
pub mod scan;
pub mod sections;

use scan::SourceFile;
use std::path::{Path, PathBuf};

/// One lint finding, printable as `file:line [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Repo-relative path label (e.g. `coordinator/trainer.rs`).
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Run every pass over in-memory `(path-label, source)` pairs. The unit
/// of testability: fixtures call this directly; [`run_lint`] feeds it
/// the real tree.
pub fn lint_sources(sources: &[(String, String)]) -> Vec<Diagnostic> {
    let files: Vec<SourceFile> =
        sources.iter().map(|(p, s)| SourceFile::parse(p, s)).collect();
    let mut out = Vec::new();
    out.extend(safety::check(&files));
    out.extend(panics::check(&files));
    out.extend(fingerprint::check(&files));
    out.extend(sections::check(&files));
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    out
}

/// Files the passes anchor on; their absence in a real tree means the
/// lint is looking at the wrong directory, which must be an error
/// rather than a silently-green run.
const ANCHOR_FILES: &[&str] =
    &["config/run.rs", "optim/galore.rs", "coordinator/checkpoint.rs", "runtime/pool.rs"];

/// Lint every `.rs` file under `root` (normally `rust/src`). Path
/// labels in diagnostics are relative to `root`.
pub fn run_lint(root: &Path) -> Result<Vec<Diagnostic>, String> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)
        .map_err(|e| format!("walking {}: {e}", root.display()))?;
    paths.sort();
    let mut sources = Vec::with_capacity(paths.len());
    for p in &paths {
        let text = std::fs::read_to_string(p)
            .map_err(|e| format!("reading {}: {e}", p.display()))?;
        let label = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        sources.push((label, text));
    }
    for anchor in ANCHOR_FILES {
        if !sources.iter().any(|(p, _)| p.ends_with(anchor)) {
            return Err(format!(
                "lint root {} does not contain {anchor} — wrong directory?",
                root.display()
            ));
        }
    }
    Ok(lint_sources(&sources))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if entry.file_type()?.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagnostics_print_file_line_rule() {
        let d = Diagnostic {
            file: "optim/galore.rs".into(),
            line: 42,
            rule: "no-panic-on-hot-paths",
            message: "boom".into(),
        };
        assert_eq!(d.to_string(), "optim/galore.rs:42 [no-panic-on-hot-paths] boom");
    }

    #[test]
    fn lint_sources_runs_all_passes_and_sorts() {
        let sources = vec![
            (
                "runtime/b.rs".to_string(),
                "fn f() { y().unwrap(); }\nfn g() { let s = unsafe { raw(p) }; }\n".to_string(),
            ),
            ("coordinator/a.rs".to_string(), "fn f() { panic!(\"x\"); }\n".to_string()),
        ];
        let d = lint_sources(&sources);
        assert_eq!(d.len(), 3, "{d:?}");
        // Sorted by (file, line): coordinator first, then runtime 1, 2.
        assert_eq!(d[0].file, "coordinator/a.rs");
        assert_eq!(d[1].file, "runtime/b.rs");
        assert_eq!((d[1].line, d[2].line), (1, 2));
        assert!(d.iter().any(|x| x.rule == safety::RULE));
        assert!(d.iter().any(|x| x.rule == panics::RULE));
    }

    #[test]
    fn run_lint_rejects_wrong_root() {
        let dir = std::env::temp_dir().join("galore-lint-wrong-root");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("lone.rs"), "fn x() {}\n").unwrap();
        let err = run_lint(&dir).unwrap_err();
        assert!(err.contains("wrong directory"), "{err}");
    }
}
