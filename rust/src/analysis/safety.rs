//! Lint pass 1: every `unsafe` site carries a `// SAFETY:` comment.
//!
//! A *site* is an `unsafe` keyword that introduces an obligation: an
//! `unsafe { … }` block, an `unsafe impl`, or an `unsafe fn` item
//! declaration. `unsafe fn(...)` *types* (function pointers, like the
//! worker-pool trampoline slot) impose the obligation on their callers,
//! not their declaration, and are skipped. A site counts as documented
//! when a comment containing `SAFETY` appears on the same line, within
//! the [`WINDOW`] lines above it (attributes and sibling `unsafe impl`
//! lines may sit between the comment and the keyword), or on the first
//! line inside the block — the comment placements this codebase already
//! uses.

use super::scan::SourceFile;
use super::Diagnostic;

/// How far above an `unsafe` site a `SAFETY` comment may sit. Wide
/// enough for a multi-line justification plus an attribute; narrow
/// enough that a comment cannot plausibly document an unrelated site.
const WINDOW: usize = 6;

pub const RULE: &str = "unsafe-needs-safety-comment";

pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in files {
        for line_no in unsafe_sites(f) {
            if !documented(f, line_no) {
                out.push(Diagnostic {
                    file: f.path.clone(),
                    line: line_no,
                    rule: RULE,
                    message: "`unsafe` without a `// SAFETY:` comment (same line, the \
                              6 lines above, or the first line of the block)"
                        .into(),
                });
            }
        }
    }
    out
}

/// 1-indexed lines holding an obligation-introducing `unsafe`.
fn unsafe_sites(f: &SourceFile) -> Vec<usize> {
    let mut sites = Vec::new();
    // Flatten the masked text so a site whose `{` falls on the next line
    // is still classified correctly.
    let flat: String = f.masked.join("\n");
    let bytes: Vec<char> = flat.chars().collect();
    let mut line = 1usize;
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if flat_word_at(&bytes, i, "unsafe") {
            let site_line = line;
            let mut j = i + "unsafe".len();
            // Next non-whitespace token decides the kind.
            while j < bytes.len() && bytes[j].is_whitespace() {
                j += 1;
            }
            let rest: String = bytes[j..bytes.len().min(j + 16)].iter().collect();
            if rest.starts_with('{') || rest.starts_with("impl") {
                sites.push(site_line);
            } else if rest.starts_with("fn") {
                // `unsafe fn name(` is a declaration; `unsafe fn(` is a
                // function-pointer type.
                let mut k = j + 2;
                while k < bytes.len() && bytes[k].is_whitespace() {
                    k += 1;
                }
                if bytes.get(k).map(|c| c.is_alphabetic() || *c == '_').unwrap_or(false) {
                    sites.push(site_line);
                }
            } else if rest.starts_with("extern") {
                // `unsafe extern "C" fn …` declaration.
                sites.push(site_line);
            }
            i += "unsafe".len();
            continue;
        }
        i += 1;
    }
    sites
}

fn flat_word_at(b: &[char], i: usize, w: &str) -> bool {
    let wc: Vec<char> = w.chars().collect();
    if i + wc.len() > b.len() || b[i..i + wc.len()] != wc[..] {
        return false;
    }
    let before_ok = i == 0 || !(b[i - 1].is_alphanumeric() || b[i - 1] == '_');
    let after_ok = b
        .get(i + wc.len())
        .map(|c| !(c.is_alphanumeric() || *c == '_'))
        .unwrap_or(true);
    before_ok && after_ok
}

fn documented(f: &SourceFile, line_no: usize) -> bool {
    if f.comments.is_empty() {
        return false;
    }
    let idx = line_no - 1;
    // Same line, the WINDOW lines above, or the first line of the block.
    let lo = idx.saturating_sub(WINDOW);
    for c in &f.comments[lo..=idx.min(f.comments.len() - 1)] {
        if c.contains("SAFETY") {
            return true;
        }
    }
    if let Some(next) = f.comments.get(idx + 1) {
        if next.contains("SAFETY") {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::SourceFile;

    fn lint_one(src: &str) -> Vec<Diagnostic> {
        check(&[SourceFile::parse("x.rs", src)])
    }

    #[test]
    fn documented_block_passes() {
        let src = "// SAFETY: disjoint rows\nlet s = unsafe { from_raw(p) };\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn undocumented_block_flagged() {
        let src = "let s = unsafe { from_raw(p) };\n";
        let d = lint_one(src);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].line, 1);
        assert_eq!(d[0].rule, RULE);
    }

    #[test]
    fn comment_inside_block_counts() {
        let src = "let s = unsafe {\n    // SAFETY: caller holds the borrow\n    from_raw(p)\n};\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn shared_comment_covers_adjacent_impls() {
        let src = "// SAFETY: plain address, tasks write disjoint ranges\nunsafe impl<T> Send for P<T> {}\nunsafe impl<T> Sync for P<T> {}\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn undocumented_unsafe_fn_flagged_but_fn_pointer_type_is_not() {
        let src = "struct S { call: unsafe fn(*const (), usize) }\nunsafe fn call_never(_: *const ()) {}\n";
        let d = lint_one(src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn unsafe_in_string_or_comment_is_ignored() {
        let src = "let s = \"unsafe { }\"; // unsafe in prose\n";
        assert!(lint_one(src).is_empty());
    }

    #[test]
    fn far_away_comment_does_not_count() {
        let mut src = String::from("// SAFETY: something else\n");
        for _ in 0..8 {
            src.push_str("let filler = 0;\n");
        }
        src.push_str("let s = unsafe { from_raw(p) };\n");
        let d = lint_one(&src);
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn doc_comment_safety_counts() {
        let src = "/// SAFETY: calls data as &F; only instantiated by run<F>.\nunsafe fn call_as<F>(data: *const ()) {}\n";
        assert!(lint_one(src).is_empty());
    }
}
