//! Lint pass 4: checkpoint-section symmetry.
//!
//! The v2 checkpoint format is a roster of tagged sections (`SEC_*` in
//! `coordinator/checkpoint.rs`). A section written by a `save_*` path
//! that no `load_*`/`restore_*` path reads is silently-dropped state on
//! resume; a section read but never written is a resume that can never
//! find its data. Both are asymmetries a reviewer has to *remember* to
//! check — so this pass checks them instead:
//!
//! - per file: the set of tags used inside `save*` functions must equal
//!   the set used inside `load*`/`restore*` functions;
//! - globally: every declared tag must be read somewhere, and every
//!   non-legacy tag written somewhere.
//!
//! *Legacy* tags (doc comment on the declaration contains "legacy") are
//! the sanctioned exception: kept only so old files are rejected loudly,
//! they must be read and never written.
//!
//! Test code is excluded — round-trip tests legitimately write and read
//! tags in the same function.

use super::scan::SourceFile;
use super::Diagnostic;
use std::collections::{BTreeMap, BTreeSet};

pub const RULE: &str = "checkpoint-section-symmetry";

/// Path suffix of the file declaring the `SEC_*` tags.
pub const DECL_PATH: &str = "coordinator/checkpoint.rs";

/// How far above a declaration its doc comment may start.
const DOC_WINDOW: usize = 3;

pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let Some(decl_file) = files.iter().find(|f| f.path.ends_with(DECL_PATH)) else {
        return Vec::new();
    };
    let decls = declared_tags(decl_file);
    if decls.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();

    // tag -> (files-that-write, files-that-read), non-test uses only.
    let mut writers: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let mut readers: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    // file -> (tags written, tags read) for the per-file symmetry check.
    let mut per_file: BTreeMap<String, (BTreeSet<String>, BTreeSet<String>)> = BTreeMap::new();

    for f in files {
        for (idx, masked) in f.masked.iter().enumerate() {
            let line_no = idx + 1;
            if f.line_is_test(line_no) || is_decl_line(masked) {
                continue;
            }
            for tag in tags_on_line(masked) {
                if !decls.contains_key(&tag) {
                    continue;
                }
                let Some(fun) = f.enclosing_fn(line_no) else { continue };
                let entry = per_file.entry(f.path.clone()).or_default();
                if fun.name.contains("save") {
                    writers.entry(tag.clone()).or_default().insert(f.path.clone());
                    entry.0.insert(tag);
                } else if fun.name.contains("load") || fun.name.contains("restore") {
                    readers.entry(tag.clone()).or_default().insert(f.path.clone());
                    entry.1.insert(tag);
                }
            }
        }
    }

    for (path, (written, read)) in &per_file {
        for tag in written.difference(read) {
            out.push(Diagnostic {
                file: path.clone(),
                line: 1,
                rule: RULE,
                message: format!(
                    "section `{tag}` is written by a save path in this file but read by \
                     no load/restore path here — resumed runs would drop it"
                ),
            });
        }
        for tag in read.difference(written) {
            if decls.get(tag).map(|d| d.legacy).unwrap_or(false) {
                continue;
            }
            out.push(Diagnostic {
                file: path.clone(),
                line: 1,
                rule: RULE,
                message: format!(
                    "section `{tag}` is read by a load/restore path in this file but \
                     written by no save path here (mark the declaration's doc comment \
                     `legacy` if read-only rejection is intended)"
                ),
            });
        }
    }

    for (tag, decl) in &decls {
        let is_read = readers.contains_key(tag);
        let is_written = writers.contains_key(tag);
        if decl.legacy {
            if is_written {
                out.push(Diagnostic {
                    file: decl_file.path.clone(),
                    line: decl.line,
                    rule: RULE,
                    message: format!("legacy section `{tag}` must never be written, but a save path writes it"),
                });
            }
            if !is_read {
                out.push(Diagnostic {
                    file: decl_file.path.clone(),
                    line: decl.line,
                    rule: RULE,
                    message: format!("legacy section `{tag}` is read nowhere — dead tag, delete it"),
                });
            }
        } else if !is_read || !is_written {
            out.push(Diagnostic {
                file: decl_file.path.clone(),
                line: decl.line,
                rule: RULE,
                message: format!(
                    "section `{tag}` is {} — every live tag needs both a writer and a reader",
                    match (is_written, is_read) {
                        (false, false) => "never written or read",
                        (false, true) => "read but never written",
                        (true, false) => "written but never read",
                        _ => unreachable!(),
                    }
                ),
            });
        }
    }
    out
}

struct Decl {
    line: usize,
    legacy: bool,
}

/// `const SEC_<X>` declarations with their legacy marking (doc comment
/// on or within [`DOC_WINDOW`] lines above containing "legacy").
fn declared_tags(f: &SourceFile) -> BTreeMap<String, Decl> {
    let mut out = BTreeMap::new();
    for (idx, masked) in f.masked.iter().enumerate() {
        if !is_decl_line(masked) {
            continue;
        }
        let Some(tag) = tags_on_line(masked).into_iter().next() else { continue };
        let lo = idx.saturating_sub(DOC_WINDOW);
        let legacy = f.comments[lo..=idx].iter().any(|c| c.to_ascii_lowercase().contains("legacy"));
        out.insert(tag, Decl { line: idx + 1, legacy });
    }
    out
}

fn is_decl_line(masked: &str) -> bool {
    let t = masked.trim_start();
    t.strip_prefix("pub ").unwrap_or(t).starts_with("const SEC_")
}

/// All `SEC_<IDENT>` identifiers on a masked line.
fn tags_on_line(masked: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = masked[start..].find("SEC_") {
        let at = start + pos;
        let before_ok = at == 0
            || !(masked.as_bytes()[at - 1].is_ascii_alphanumeric()
                || masked.as_bytes()[at - 1] == b'_');
        let ident: String = masked[at..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        start = at + ident.len().max(4);
        if before_ok && ident.len() > "SEC_".len() {
            out.push(ident);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::SourceFile;

    const DECLS: &str = "\
/// Optimizer state.\n\
pub const SEC_OPT: &[u8; 4] = b\"OPTS\";\n\
/// Legacy fused-path section — recognized only to reject; never written.\n\
pub const SEC_OLD: &[u8; 4] = b\"FUSD\";\n";

    fn lint(decl_extra: &str, user: &str) -> Vec<Diagnostic> {
        let decls = format!("{DECLS}{decl_extra}");
        check(&[
            SourceFile::parse("coordinator/checkpoint.rs", &decls),
            SourceFile::parse("coordinator/trainer.rs", user),
        ])
    }

    const SYMMETRIC: &str = "\
fn save_checkpoint() { write(SEC_OPT); }\n\
fn restore_checkpoint() { read(SEC_OPT); if has(SEC_OLD) { reject(); } }\n";

    #[test]
    fn symmetric_tree_is_clean() {
        let d = lint("", SYMMETRIC);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn written_but_never_read_flagged() {
        let d = lint("", "fn save_checkpoint() { write(SEC_OPT); }\nfn restore_checkpoint() { if has(SEC_OLD) { reject(); } }\n");
        assert!(!d.is_empty());
        assert!(d.iter().any(|x| x.message.contains("`SEC_OPT`") && x.message.contains("read by")), "{d:?}");
    }

    #[test]
    fn read_but_never_written_flagged() {
        let d = lint("", "fn save_checkpoint() { nothing(); }\nfn restore_checkpoint() { read(SEC_OPT); if has(SEC_OLD) { reject(); } }\n");
        assert!(d.iter().any(|x| x.message.contains("`SEC_OPT`")), "{d:?}");
    }

    #[test]
    fn legacy_tag_may_be_read_only_but_never_written() {
        // SYMMETRIC already proves read-only SEC_OLD passes; writing it fails.
        let d = lint("", "fn save_checkpoint() { write(SEC_OPT); write(SEC_OLD); }\nfn restore_checkpoint() { read(SEC_OPT); read(SEC_OLD); }\n");
        assert!(d.iter().any(|x| x.message.contains("legacy section `SEC_OLD`")), "{d:?}");
    }

    #[test]
    fn dead_tag_flagged() {
        let d = lint("pub const SEC_DEAD: &[u8; 4] = b\"DEAD\";\n", SYMMETRIC);
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("`SEC_DEAD`"));
        assert!(d[0].message.contains("never written or read"));
    }

    #[test]
    fn test_code_uses_ignored() {
        let user = format!(
            "{SYMMETRIC}#[cfg(test)]\nmod tests {{\n    #[test]\n    fn t() {{ roundtrip(SEC_OPT, SEC_OLD); }}\n}}\n"
        );
        let d = lint("", &user);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn no_decl_file_skips_pass() {
        let files = [SourceFile::parse("x.rs", "fn save_x() { write(SEC_OPT); }")];
        assert!(check(&files).is_empty());
    }
}
