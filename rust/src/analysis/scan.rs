//! Lightweight Rust source scanner for the `galore lint` passes.
//!
//! Not a parser: a line-oriented model of one `.rs` file built from a
//! single character-level sweep that understands exactly as much Rust
//! lexical structure as the lint rules need — comments (line, nested
//! block, doc), string/char/byte literals (including raw strings with
//! any `#` count), brace depth, `#[cfg(test)]`/`#[test]` regions, and
//! `fn` item spans. Everything else stays text. The passes then search
//! *masked* lines (comment and literal contents blanked to spaces, with
//! layout preserved) so `"panic!("` inside a string or a doc comment can
//! never produce a diagnostic, while the comment text itself is kept
//! per line for the `SAFETY:` / `PANIC-OK:` checks.

/// The span of one `fn` item (any nesting depth), used to classify a
/// token occurrence by its innermost enclosing function.
#[derive(Clone, Debug)]
pub struct FnSpan {
    pub name: String,
    /// 1-indexed, inclusive.
    pub start_line: usize,
    /// 1-indexed, inclusive (line of the matching closing brace).
    pub end_line: usize,
}

/// One scanned source file.
pub struct SourceFile {
    /// Repo-relative path label used in diagnostics (e.g.
    /// `optim/galore.rs`).
    pub path: String,
    /// Raw lines, as written.
    pub lines: Vec<String>,
    /// Lines with comment and string/char-literal contents replaced by
    /// spaces; same length and layout as `lines`, so column positions
    /// still correspond.
    pub masked: Vec<String>,
    /// Comment text found on each line (concatenated if several), with
    /// the `//` / `/*` markers stripped off the scan but the words kept.
    pub comments: Vec<String>,
    /// True for lines inside a `#[cfg(test)]` item or a `#[test]` fn.
    pub is_test: Vec<bool>,
    /// Every `fn` item span, in source order.
    pub fns: Vec<FnSpan>,
}

#[derive(Clone, Copy, PartialEq)]
enum Lex {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    Char,
}

impl SourceFile {
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let (masked_text, comment_text) = mask(text);
        let lines: Vec<String> = text.lines().map(str::to_string).collect();
        let masked: Vec<String> = masked_text.lines().map(str::to_string).collect();
        let comments: Vec<String> = comment_text.lines().map(str::to_string).collect();
        let is_test = test_lines(&masked);
        let fns = fn_spans(&masked);
        SourceFile { path: path.to_string(), lines, masked, comments, is_test, fns }
    }

    /// Innermost `fn` whose span contains `line` (1-indexed).
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.start_line <= line && line <= f.end_line)
            .min_by_key(|f| f.end_line - f.start_line)
    }

    /// Is the 1-indexed line inside test code?
    pub fn line_is_test(&self, line: usize) -> bool {
        self.is_test.get(line - 1).copied().unwrap_or(false)
    }
}

/// Blank comment and literal contents out of `text`. Returns
/// `(masked, comments)`, both with `text`'s exact line structure: in
/// `masked` every comment/literal character becomes a space; in
/// `comments` only comment characters survive (code becomes spaces), so
/// per-line comment text can be recovered with `lines()`.
fn mask(text: &str) -> (String, String) {
    let b: Vec<char> = text.chars().collect();
    let mut masked = String::with_capacity(text.len());
    let mut comments = String::with_capacity(text.len());
    let mut st = Lex::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            // Newlines survive in both views; a line comment ends here.
            if st == Lex::LineComment {
                st = Lex::Code;
            }
            masked.push('\n');
            comments.push('\n');
            i += 1;
            continue;
        }
        match st {
            Lex::Code => {
                let next = b.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && next == '/' {
                    st = Lex::LineComment;
                    masked.push(' ');
                    comments.push(' ');
                    i += 1;
                } else if c == '/' && next == '*' {
                    st = Lex::BlockComment(1);
                    masked.push(' ');
                    comments.push(' ');
                    i += 1;
                } else if c == '"' {
                    st = Lex::Str;
                    masked.push(' ');
                    comments.push(' ');
                } else if c == 'r' && (next == '"' || next == '#') && is_raw_str_start(&b, i) {
                    let hashes = count_hashes(&b, i + 1);
                    st = Lex::RawStr(hashes);
                    // Consume `r`, the hashes, and the opening quote.
                    for _ in 0..(hashes as usize + 2) {
                        masked.push(' ');
                        comments.push(' ');
                    }
                    i += hashes as usize + 1;
                } else if c == '\'' && is_char_literal(&b, i) {
                    st = Lex::Char;
                    masked.push(' ');
                    comments.push(' ');
                } else {
                    masked.push(c);
                    comments.push(' ');
                }
            }
            Lex::LineComment => {
                masked.push(' ');
                comments.push(c);
            }
            Lex::BlockComment(d) => {
                let next = b.get(i + 1).copied().unwrap_or('\0');
                if c == '/' && next == '*' {
                    st = Lex::BlockComment(d + 1);
                    masked.push(' ');
                    masked.push(' ');
                    comments.push(' ');
                    comments.push(' ');
                    i += 1;
                } else if c == '*' && next == '/' {
                    st = if d > 1 { Lex::BlockComment(d - 1) } else { Lex::Code };
                    masked.push(' ');
                    masked.push(' ');
                    comments.push(' ');
                    comments.push(' ');
                    i += 1;
                } else {
                    masked.push(' ');
                    comments.push(c);
                }
            }
            Lex::Str => {
                if c == '\\' {
                    // Skip the escaped character (handles \" and \\).
                    masked.push(' ');
                    comments.push(' ');
                    if b.get(i + 1).map(|&n| n != '\n').unwrap_or(false) {
                        masked.push(' ');
                        comments.push(' ');
                        i += 1;
                    }
                } else {
                    masked.push(' ');
                    comments.push(' ');
                    if c == '"' {
                        st = Lex::Code;
                    }
                }
            }
            Lex::RawStr(h) => {
                if c == '"' && count_hashes(&b, i + 1) >= h && has_hashes(&b, i + 1, h) {
                    for _ in 0..(h as usize + 1) {
                        masked.push(' ');
                        comments.push(' ');
                    }
                    i += h as usize;
                    st = Lex::Code;
                } else {
                    masked.push(' ');
                    comments.push(' ');
                }
            }
            Lex::Char => {
                if c == '\\' {
                    masked.push(' ');
                    comments.push(' ');
                    if b.get(i + 1).map(|&n| n != '\n').unwrap_or(false) {
                        masked.push(' ');
                        comments.push(' ');
                        i += 1;
                    }
                } else {
                    masked.push(' ');
                    comments.push(' ');
                    if c == '\'' {
                        st = Lex::Code;
                    }
                }
            }
        }
        i += 1;
    }
    (masked, comments)
}

/// `r` at `i` starts a raw string iff `r`, optional `#`s, then `"` —
/// and `r` is not the tail of an identifier (e.g. `var"..."` is not
/// Rust, but `for r#"` must not trip on the identifier `for`).
fn is_raw_str_start(b: &[char], i: usize) -> bool {
    if i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_') {
        return false;
    }
    let h = count_hashes(b, i + 1) as usize;
    b.get(i + 1 + h) == Some(&'"')
}

fn count_hashes(b: &[char], mut i: usize) -> u32 {
    let mut n = 0;
    while b.get(i) == Some(&'#') {
        n += 1;
        i += 1;
    }
    n
}

fn has_hashes(b: &[char], i: usize, h: u32) -> bool {
    (0..h as usize).all(|k| b.get(i + k) == Some(&'#'))
}

/// Distinguish a char literal from a lifetime: `'x'` / `'\n'` are
/// literals; `'a` followed by anything but a closing quote is a
/// lifetime (or a loop label).
fn is_char_literal(b: &[char], i: usize) -> bool {
    match b.get(i + 1) {
        Some('\\') => true,
        Some(_) => b.get(i + 2) == Some(&'\''),
        None => false,
    }
}

/// Mark lines covered by `#[cfg(test)]` items and `#[test]` fns: an
/// attribute arms a pending flag; the next `{` opens a test region that
/// ends at its matching `}` (regions nest — anything inside a test
/// region is test). A `;` before any `{` disarms (attribute on a
/// body-less item).
fn test_lines(masked: &[String]) -> Vec<bool> {
    let mut out = vec![false; masked.len()];
    let mut pending = false;
    // Stack of booleans: is the region opened by this brace a test one?
    let mut stack: Vec<bool> = Vec::new();
    for (ln, line) in masked.iter().enumerate() {
        if line.contains("#[cfg(test)]") || line.contains("#[test]") {
            pending = true;
        }
        let in_test_before = stack.iter().any(|&t| t);
        if in_test_before || pending {
            out[ln] = true;
        }
        for c in line.chars() {
            match c {
                '{' => {
                    let t = stack.iter().any(|&x| x) || pending;
                    stack.push(t);
                    pending = false;
                }
                '}' => {
                    stack.pop();
                }
                ';' if stack.iter().all(|&t| !t) => {
                    // Item without a body at non-test depth consumes the
                    // pending attribute.
                    pending = false;
                }
                _ => {}
            }
        }
        if stack.iter().any(|&t| t) {
            out[ln] = true;
        }
    }
    out
}

/// Find `fn NAME … { … }` item spans by scanning masked text: the
/// keyword `fn` followed by an identifier, then the first `{` at
/// paren/bracket depth 0, then its matching `}`. Trait-method
/// *declarations* (`fn f(&self) -> T;`) have no body and are skipped.
fn fn_spans(masked: &[String]) -> Vec<FnSpan> {
    // Flatten with line bookkeeping.
    let mut chars: Vec<(char, usize)> = Vec::new();
    for (ln, line) in masked.iter().enumerate() {
        for c in line.chars() {
            chars.push((c, ln + 1));
        }
        chars.push(('\n', ln + 1));
    }
    let mut spans = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if chars[i].0 == 'f'
            && chars.get(i + 1).map(|&(c, _)| c) == Some('n')
            && chars.get(i + 2).map(|&(c, _)| !c.is_alphanumeric() && c != '_').unwrap_or(true)
            && (i == 0
                || !(chars[i - 1].0.is_alphanumeric() || chars[i - 1].0 == '_'))
        {
            let start_line = chars[i].1;
            // Skip whitespace, collect the identifier (absent for fn
            // pointer types `fn(...)` — skip those).
            let mut j = i + 2;
            while j < chars.len() && chars[j].0.is_whitespace() {
                j += 1;
            }
            let mut name = String::new();
            while j < chars.len() && (chars[j].0.is_alphanumeric() || chars[j].0 == '_') {
                name.push(chars[j].0);
                j += 1;
            }
            if name.is_empty() {
                i += 2;
                continue;
            }
            // Find the body `{` at bracket depth 0, bailing at a `;`
            // (body-less declaration).
            let mut depth = 0i32;
            let mut body = None;
            while j < chars.len() {
                match chars[j].0 {
                    '(' | '[' => depth += 1,
                    ')' | ']' => depth -= 1,
                    '{' if depth == 0 => {
                        body = Some(j);
                        break;
                    }
                    ';' if depth == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            if let Some(open) = body {
                let mut bd = 0i32;
                let mut k = open;
                let mut end_line = chars[open].1;
                while k < chars.len() {
                    match chars[k].0 {
                        '{' => bd += 1,
                        '}' => {
                            bd -= 1;
                            if bd == 0 {
                                end_line = chars[k].1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
                spans.push(FnSpan { name, start_line, end_line });
                // Continue scanning *inside* the body too (nested fns,
                // and the next sibling after short bodies).
                i += 2;
                continue;
            }
        }
        i += 1;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_comments() {
        let src = "let x = \"panic!(\\\"no\\\")\"; // .unwrap() here\nlet y = 1; /* .expect( */\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.masked[0].contains("panic!"));
        assert!(!f.masked[0].contains("unwrap"));
        assert!(f.masked[0].contains("let x ="));
        assert!(!f.masked[1].contains("expect"));
        assert!(f.comments[0].contains(".unwrap() here"));
        assert!(f.comments[1].contains(".expect("));
    }

    #[test]
    fn masks_raw_strings_and_chars() {
        let src = "let s = r#\"unsafe { \"quote\" }\"#;\nlet c = '\\'';\nlet l: &'static str = \"x\";\nfor<'a> fn(&'a u8);\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.masked[0].contains("unsafe"));
        assert!(f.masked[2].contains("&'static str"), "lifetime must stay code: {}", f.masked[2]);
        assert!(f.masked[3].contains("'a"), "{}", f.masked[3]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ let x = 1;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.masked[0].contains("let x = 1;"));
        assert!(!f.masked[0].contains("outer"));
    }

    #[test]
    fn test_region_detection() {
        let src = "fn real() { work(); }\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n    #[test]\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(!f.line_is_test(1));
        assert!(f.line_is_test(2));
        assert!(f.line_is_test(4));
        assert!(f.line_is_test(6));
        assert!(!f.line_is_test(8), "code after the test mod is not test");
    }

    #[test]
    fn standalone_test_fn() {
        let src = "#[test]\nfn t() {\n    x.unwrap();\n}\nfn real() { y(); }\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.line_is_test(3));
        assert!(!f.line_is_test(5));
    }

    #[test]
    fn fn_spans_and_enclosing() {
        let src = "fn alpha() {\n    inner();\n}\nimpl Foo {\n    fn save_beta(&self) -> u8 {\n        1\n    }\n}\ntrait T { fn decl(&self); }\nlet f: fn(usize) = alpha;\n";
        let f = SourceFile::parse("t.rs", src);
        let names: Vec<&str> = f.fns.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["alpha", "save_beta"], "{names:?}");
        assert_eq!(f.enclosing_fn(2).unwrap().name, "alpha");
        assert_eq!(f.enclosing_fn(6).unwrap().name, "save_beta");
        assert!(f.enclosing_fn(9).is_none());
    }

    #[test]
    fn comment_text_is_recoverable_per_line() {
        let src = "unsafe { x }; // SAFETY: fine\n// PANIC-OK: startup only\nlet y = 2;\n";
        let f = SourceFile::parse("t.rs", src);
        assert!(f.comments[0].contains("SAFETY: fine"));
        assert!(f.comments[1].contains("PANIC-OK: startup only"));
        assert_eq!(f.comments[2].trim(), "");
    }
}
