//! Lint pass 2: no `.unwrap()` / `.expect(` / `panic!(` in non-test
//! code under the process-critical directories, outside an explicit
//! per-site allowlist.
//!
//! The trainer is a resident process (`galore serve` runs many jobs in
//! one daemon): a panic on a fallible path aborts every co-resident
//! job, so mid-run code must propagate `Result` instead. The scope is
//! the directories whose code runs while jobs are live —
//! `coordinator/`, `serve/`, `optim/`, `runtime/`. Test modules are
//! exempt (a test unwrap *is* the assertion).
//!
//! Allowlist mechanism: a site is permitted when the same line or the
//! line above carries a `// PANIC-OK: <justification>` comment with a
//! non-empty justification — the linter verifies the justification text
//! is actually present, so an allowlisted site always explains itself
//! at the point of use (e.g. "process startup, before any job exists",
//! or "infallible by construction: index i < senders.len()").
//!
//! `self.expect(…)` is not flagged: that is a user-defined method (the
//! JSON parser's token matcher), not `Option::expect`.

use super::scan::SourceFile;
use super::Diagnostic;

pub const RULE: &str = "no-panic-on-hot-paths";

/// Directories whose non-test code must not contain unlisted panic
/// sites (prefixes of the repo-relative path labels).
pub const SCOPED_DIRS: &[&str] = &["coordinator/", "serve/", "optim/", "runtime/"];

const PATTERNS: &[&str] = &[".unwrap()", ".expect(", "panic!("];

pub fn check(files: &[SourceFile]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in files {
        if !SCOPED_DIRS.iter().any(|d| f.path.starts_with(d) || f.path.contains(&format!("/{d}")))
        {
            continue;
        }
        for (idx, masked) in f.masked.iter().enumerate() {
            let line_no = idx + 1;
            if f.line_is_test(line_no) {
                continue;
            }
            for pat in PATTERNS {
                let mut start = 0;
                while let Some(pos) = masked[start..].find(pat) {
                    let at = start + pos;
                    start = at + pat.len();
                    if *pat == ".expect(" && is_self_call(masked, at) {
                        continue;
                    }
                    if allowlisted(f, idx) {
                        continue;
                    }
                    out.push(Diagnostic {
                        file: f.path.clone(),
                        line: line_no,
                        rule: RULE,
                        message: format!(
                            "`{}` on a resident-process path — propagate a Result, or \
                             justify with `// PANIC-OK: <reason>` on this line or the \
                             line above",
                            pat.trim_end_matches('(')
                        ),
                    });
                }
            }
        }
    }
    out
}

/// `self.expect(` / `r.expect(`-style calls on a *parser* receiver are
/// user methods, not `Option::expect`. Only the literal receiver `self`
/// is exempted — everything else is assumed to be the std method.
fn is_self_call(masked: &str, dot_pos: usize) -> bool {
    masked[..dot_pos].trim_end().ends_with("self")
}

/// `// PANIC-OK: <reason>` on the site's line or anywhere in the
/// contiguous comment-only block directly above it, with a non-empty
/// reason after the colon (a justification may span several comment
/// lines; the marker can sit on any of them).
fn allowlisted(f: &SourceFile, idx: usize) -> bool {
    let has_reason = |c: &str| {
        c.find("PANIC-OK:")
            .map(|p| !c[p + "PANIC-OK:".len()..].trim().is_empty())
            .unwrap_or(false)
    };
    if f.comments.get(idx).map(|c| has_reason(c)).unwrap_or(false) {
        return true;
    }
    // Walk up through comment-only lines (masked text blank, comment
    // text present).
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let comment_only = f.masked[i].trim().is_empty() && !f.comments[i].trim().is_empty();
        if !comment_only {
            return false;
        }
        if has_reason(&f.comments[i]) {
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::SourceFile;

    fn lint_one(path: &str, src: &str) -> Vec<Diagnostic> {
        check(&[SourceFile::parse(path, src)])
    }

    #[test]
    fn unwrap_in_scope_flagged() {
        let d = lint_one("coordinator/x.rs", "fn f() { y().unwrap(); }\n");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].line, 1);
    }

    #[test]
    fn expect_and_panic_flagged() {
        let d = lint_one("optim/x.rs", "fn f() {\n    y().expect(\"boom\");\n    panic!(\"no\");\n}\n");
        assert_eq!(d.len(), 2);
        assert_eq!((d[0].line, d[1].line), (2, 3));
    }

    #[test]
    fn out_of_scope_dirs_ignored() {
        assert!(lint_one("tensor/x.rs", "fn f() { y().unwrap(); }\n").is_empty());
        assert!(lint_one("config/x.rs", "fn f() { panic!(); }\n").is_empty());
    }

    #[test]
    fn test_code_ignored() {
        let src = "fn real() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { y().unwrap(); }\n}\n";
        assert!(lint_one("serve/x.rs", src).is_empty());
    }

    #[test]
    fn panic_ok_with_reason_allowed() {
        let src = "fn f() {\n    // PANIC-OK: process startup, no jobs are resident yet\n    spawn().expect(\"spawning worker\");\n    y().unwrap() // PANIC-OK: index bounded by len above\n}\n";
        assert!(lint_one("runtime/x.rs", src).is_empty());
    }

    #[test]
    fn multi_line_justification_allowed() {
        let src = "fn f() {\n    // PANIC-OK: pool construction happens at startup,\n    // before any job state exists to lose.\n    spawn().expect(\"spawn\");\n}\n";
        assert!(lint_one("runtime/x.rs", src).is_empty());
    }

    #[test]
    fn comment_block_interrupted_by_code_does_not_allowlist() {
        let src = "fn f() {\n    // PANIC-OK: covers only the line below it\n    a().unwrap();\n    b().unwrap();\n}\n";
        let d = lint_one("runtime/x.rs", src);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 4);
    }

    #[test]
    fn panic_ok_without_reason_still_flagged() {
        let src = "fn f() {\n    // PANIC-OK:\n    y().unwrap();\n}\n";
        let d = lint_one("runtime/x.rs", src);
        assert_eq!(d.len(), 1, "an empty justification must not allowlist");
    }

    #[test]
    fn unwrap_or_variants_not_flagged() {
        let src = "fn f() { a.unwrap_or(0); b.unwrap_or_else(|| 1); c.unwrap_or_default(); }\n";
        assert!(lint_one("optim/x.rs", src).is_empty());
    }

    #[test]
    fn self_expect_parser_method_not_flagged() {
        let src = "fn parse(&mut self) {\n    self.expect(b'{');\n}\n";
        assert!(lint_one("runtime/x.rs", src).is_empty());
    }

    #[test]
    fn patterns_in_strings_and_comments_ignored() {
        let src = "fn f() { log(\"never .unwrap() here\"); } // .expect( in prose\n";
        assert!(lint_one("coordinator/x.rs", src).is_empty());
    }
}
