//! Pure-Rust dense tensor substrate.
//!
//! No external linear-algebra crates are available offline, so the
//! framework carries its own row-major `Matrix` (f32) with the small set of
//! BLAS-like operations the coordinator needs: blocked matmuls (plain and
//! transposed variants), AXPY-style element-wise kernels, norms, and
//! reductions. The *model* math runs inside the AOT HLO artifacts; this
//! module exists for the optimizer states, projector refreshes and
//! host-side glue — and is one of the perf targets in EXPERIMENTS.md §Perf.

mod matrix;
mod ops;

pub use matrix::Matrix;
pub use ops::{
    matmul, matmul_a_bt, matmul_a_bt_into, matmul_at_b, matmul_at_b_into, matmul_into,
};
