//! Row-major f32 matrix with the element-wise and reduction operations the
//! optimizer stack needs. 1-D tensors are represented as (1, n) matrices.

use crate::rng::Rng;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![1.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length != rows*cols");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// N(0, std^2) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// Reshape in place to (rows, cols), reusing the existing allocation
    /// whenever capacity suffices (`Vec::resize` never shrinks capacity, so
    /// a buffer cycled through the same shapes stops allocating after the
    /// first pass — the contract the optimizer workspaces rely on).
    /// Existing contents are unspecified afterwards; callers overwrite.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `other`, reusing this allocation when possible.
    pub fn copy_from(&mut self, other: &Matrix) {
        self.resize(other.rows, other.cols);
        self.data.copy_from_slice(&other.data);
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a caller-provided buffer (no allocation in steady
    /// state). Blocked for cache friendliness on large matrices.
    pub fn transpose_into(&self, out: &mut Matrix) {
        out.resize(self.cols, self.rows);
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    // -- element-wise ------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in self.data.iter_mut() {
            *v = f(*v);
        }
    }

    pub fn zip_inplace(&mut self, other: &Matrix, f: impl Fn(f32, f32) -> f32) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a = f(*a, b);
        }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        self.zip_inplace(other, |a, b| a + b);
    }

    pub fn sub_assign(&mut self, other: &Matrix) {
        self.zip_inplace(other, |a, b| a - b);
    }

    pub fn scale(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    /// self += alpha * other (AXPY).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    // -- reductions --------------------------------------------------------

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        self.sum() / self.len() as f64
    }

    pub fn dot_with(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Columns i..j as a new (rows, j-i) matrix.
    pub fn slice_cols(&self, i: usize, j: usize) -> Matrix {
        assert!(i <= j && j <= self.cols);
        let mut out = Matrix::zeros(self.rows, j - i);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[i..j]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.col(1), vec![2., 5.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
    }

    #[test]
    fn transpose_correct() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.at(2, 1), 6.0);
        assert_eq!(t.at(0, 1), 4.0);
    }

    #[test]
    fn axpy_and_norms() {
        let mut a = Matrix::ones(2, 2);
        let b = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data, vec![1.5, 2.0, 2.5, 3.0]);
        let e = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert!((e.frobenius_norm() - 5.0).abs() < 1e-6);
        assert_eq!(e.max_abs(), 4.0);
    }

    #[test]
    fn slice_cols_works() {
        let m = Matrix::from_vec(2, 4, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let s = m.slice_cols(1, 3);
        assert_eq!(s.shape(), (2, 2));
        assert_eq!(s.data, vec![2., 3., 6., 7.]);
    }

    #[test]
    fn resize_reuses_capacity_and_copy_from_matches() {
        let mut buf = Matrix::zeros(8, 8);
        let cap = buf.data.capacity();
        buf.resize(4, 6);
        assert_eq!(buf.shape(), (4, 6));
        assert_eq!(buf.data.capacity(), cap, "shrinking must keep capacity");
        let src = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        buf.copy_from(&src);
        assert_eq!(buf, src);
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        let mut out = Matrix::zeros(0, 0);
        m.transpose_into(&mut out);
        assert_eq!(out, m.transpose());
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(5, 5, 1.0, &mut rng);
        let i = Matrix::eye(5);
        let prod = crate::tensor::matmul(&m, &i);
        for (a, b) in prod.data.iter().zip(m.data.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
