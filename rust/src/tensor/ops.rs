//! Blocked matmul kernels (plain / A^T B / A B^T).
//!
//! The hot caller is the GaLore projector path on the Rust side
//! (`P^T G`, `P N`, and the subspace-iteration refresh `G (G^T Y)`), so
//! these are written as cache-blocked i-k-j loops with a threaded outer
//! split for large shapes. Above-threshold shapes dispatch row chunks to
//! the persistent worker pool (`runtime::pool` — sized by
//! `GALORE_THREADS` / the `threads` run knob) instead of spawning scoped
//! threads per call; each output row keeps one fixed FMA order, so
//! results are bit-identical at any thread count. Perf iterations on
//! this file are logged in EXPERIMENTS.md §Perf.

use crate::runtime::pool::{self, SendPtr};

use super::Matrix;

/// Below this many multiply-adds, threading overhead dominates.
const PAR_THRESHOLD: usize = 1 << 21;

fn num_threads() -> usize {
    pool::num_threads()
}

/// C = A @ B. (m,k) x (k,n) -> (m,n). Thin allocating wrapper over
/// [`matmul_into`].
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(0, 0);
    matmul_into(a, b, &mut c);
    c
}

/// C = A @ B written into a caller-provided buffer. `c` is resized to
/// (m, n); with a warmed-up buffer the call performs zero heap
/// allocations — the contract of the optimizer hot path (EXPERIMENTS.md
/// §Perf). Same blocked/threaded kernels as [`matmul`], so results are
/// bit-for-bit identical.
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.resize(m, n);
    c.data.fill(0.0);
    let work = m * k * n;
    if work < PAR_THRESHOLD {
        matmul_rows(&a.data, &b.data, &mut c.data, 0, m, k, n);
    } else {
        par_rows(&a.data, &b.data, &mut c.data, m, k, n);
    }
}

/// Row-range kernel: i-k-j loop order with 4-way k unrolling — the j-loop
/// is a contiguous FMA over C's row and four B rows, which auto-vectorizes
/// to AVX2 FMA under target-cpu=native (§Perf: the unroll lifted 512³ from
/// 4.5 to >20 GFLOP/s by cutting the C-row load/store traffic 4x).
fn matmul_rows(a: &[f32], b: &[f32], c: &mut [f32], i0: usize, i1: usize, k: usize, n: usize) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[(i - i0) * n..(i - i0 + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
            let b0 = &b[kk * n..kk * n + n];
            let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &b[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &b[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        while kk < k {
            let aik = arow[kk];
            let brow = &b[kk * n..kk * n + n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += aik * bv;
            }
            kk += 1;
        }
    }
}

/// Split C's rows into per-thread chunks dispatched on the worker pool;
/// each task writes a disjoint row range of `c` (rebuilt from the base
/// pointer — no per-call chunk `Vec`, no allocation).
fn par_rows(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    let nt = num_threads().min(m).max(1);
    let chunk = m.div_ceil(nt);
    let n_chunks = m.div_ceil(chunk);
    let base = SendPtr(c.as_mut_ptr());
    pool::run(n_chunks, move |t| {
        let i0 = t * chunk;
        let i1 = ((t + 1) * chunk).min(m);
        // SAFETY: row ranges are disjoint across tasks and `c` outlives
        // the pool's join barrier.
        let cchunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(i0 * n), (i1 - i0) * n) };
        pool::sanitizer::claim_mut(cchunk.as_ptr(), cchunk.len());
        matmul_rows(a, b, cchunk, i0, i1, k, n);
    });
}

/// C = A^T @ B. (k,m) x (k,n) -> (m,n). Thin allocating wrapper over
/// [`matmul_at_b_into`].
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(0, 0);
    matmul_at_b_into(a, b, &mut c);
    c
}

/// C = A^T @ B written into a caller-provided buffer (resized to (m, n);
/// allocation-free once warm). Avoids materializing A^T: loop over k rows
/// of both A and B and accumulate rank-1 updates into C.
pub fn matmul_at_b_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_at_b: A^T({},{}) @ B({},{})", a.cols, a.rows, b.rows, b.cols);
    let (k, m, n) = (a.rows, a.cols, b.cols);
    c.resize(m, n);
    c.data.fill(0.0);
    // Parallelize over output rows (columns of A) when large.
    let work = m * k * n;
    if work < PAR_THRESHOLD {
        at_b_rows(&a.data, &b.data, &mut c.data, 0, m, k, n, m);
    } else {
        let nt = num_threads().min(m).max(1);
        let chunk = m.div_ceil(nt);
        let n_chunks = m.div_ceil(chunk);
        let base = SendPtr(c.data.as_mut_ptr());
        let (ad, bd) = (&a.data, &b.data);
        pool::run(n_chunks, move |t| {
            let j0 = t * chunk;
            let j1 = ((t + 1) * chunk).min(m);
            // SAFETY: disjoint row ranges; `c` outlives the join barrier.
            let cchunk =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(j0 * n), (j1 - j0) * n) };
            pool::sanitizer::claim_mut(cchunk.as_ptr(), cchunk.len());
            at_b_rows(ad, bd, cchunk, j0, j1, k, n, m);
        });
    }
}

/// c[j - j0, :] = Σ_k a[k, j] * b[k, :] for j in j0..j1. `a_stride` is
/// A's full column count (its row stride) — the chunked callers hand in
/// the whole A alongside a row-range window of C.
#[allow(clippy::too_many_arguments)]
fn at_b_rows(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    j0: usize,
    j1: usize,
    k: usize,
    n: usize,
    a_stride: usize,
) {
    debug_assert_eq!(a.len(), k * a_stride, "A is (k, a_stride) row-major");
    debug_assert!(j1 <= a_stride && j0 <= j1);
    let acols = a_stride;
    // 4-way unroll over the k (reduction) axis: each C row is loaded and
    // stored once per 4 B rows instead of once per B row (§Perf iteration 2).
    let mut kk = 0;
    while kk + 4 <= k {
        let a0 = &a[kk * acols..kk * acols + acols];
        let a1 = &a[(kk + 1) * acols..(kk + 1) * acols + acols];
        let a2 = &a[(kk + 2) * acols..(kk + 2) * acols + acols];
        let a3 = &a[(kk + 3) * acols..(kk + 3) * acols + acols];
        let b0 = &b[kk * n..kk * n + n];
        let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
        let b2 = &b[(kk + 2) * n..(kk + 2) * n + n];
        let b3 = &b[(kk + 3) * n..(kk + 3) * n + n];
        for j in j0..j1 {
            let (c0, c1, c2, c3) = (a0[j], a1[j], a2[j], a3[j]);
            let crow = &mut c[(j - j0) * n..(j - j0 + 1) * n];
            for jj in 0..n {
                crow[jj] += c0 * b0[jj] + c1 * b1[jj] + c2 * b2[jj] + c3 * b3[jj];
            }
        }
        kk += 4;
    }
    while kk < k {
        let arow = &a[kk * acols..(kk + 1) * acols];
        let brow = &b[kk * n..(kk + 1) * n];
        for j in j0..j1 {
            let ajk = arow[j];
            let crow = &mut c[(j - j0) * n..(j - j0 + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += ajk * bv;
            }
        }
        kk += 1;
    }
}

/// C = A @ B^T. (m,k) x (n,k) -> (m,n). Thin allocating wrapper over
/// [`matmul_a_bt_into`].
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(0, 0);
    matmul_a_bt_into(a, b, &mut c);
    c
}

/// C = A @ B^T written into a caller-provided buffer (resized to (m, n);
/// allocation-free once warm). Dot products of contiguous rows; every
/// output cell is assigned, so no zero-fill pass is needed.
pub fn matmul_a_bt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt: A({},{}) @ B^T({},{})", a.rows, a.cols, b.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    c.resize(m, n);
    let work = m * k * n;
    let kernel = |c: &mut [f32], i0: usize, i1: usize| {
        for i in i0..i1 {
            let arow = &a.data[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&x, &y) in arow.iter().zip(brow.iter()) {
                    acc += x * y;
                }
                c[(i - i0) * n + j] = acc;
            }
        }
    };
    if work < PAR_THRESHOLD {
        kernel(&mut c.data, 0, m);
    } else {
        let nt = num_threads().min(m).max(1);
        let chunk = m.div_ceil(nt);
        let n_chunks = m.div_ceil(chunk);
        let base = SendPtr(c.data.as_mut_ptr());
        let kernel = &kernel;
        pool::run(n_chunks, move |t| {
            let i0 = t * chunk;
            let i1 = ((t + 1) * chunk).min(m);
            // SAFETY: disjoint row ranges; `c` outlives the join barrier.
            let cchunk =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(i0 * n), (i1 - i0) * n) };
            pool::sanitizer::claim_mut(cchunk.as_ptr(), cchunk.len());
            kernel(cchunk, i0, i1);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 13, 31), (64, 32, 48)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-5);
        }
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(160, 120, 1.0, &mut rng);
        let b = Matrix::randn(120, 140, 1.0, &mut rng);
        // Force both paths by size: this is above PAR_THRESHOLD.
        assert!(160 * 120 * 140 >= super::PAR_THRESHOLD);
        assert_close(&matmul(&a, &b), &naive(&a, &b), 1e-4);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        for &(k, m, n) in &[(5, 3, 4), (32, 8, 40), (130, 70, 90)] {
            let a = Matrix::randn(k, m, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_close(&matmul_at_b(&a, &b), &matmul(&a.transpose(), &b), 1e-4);
        }
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(4, 6, 5), (20, 33, 18), (90, 110, 80)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            assert_close(&matmul_a_bt(&a, &b), &matmul(&a, &b.transpose()), 1e-4);
        }
    }

    #[test]
    fn matmul_shapes_panic() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        assert!(std::panic::catch_unwind(|| matmul(&a, &b)).is_err());
    }
}
