//! # GaLore — Memory-Efficient LLM Training by Gradient Low-Rank Projection
//!
//! A from-scratch reproduction of *GaLore* (Zhao et al., ICML 2024) as a
//! three-layer Rust + JAX + Pallas training framework:
//!
//! * **L1/L2 (build time)** — `python/compile/` authors the LLaMA forward/
//!   backward graph and the fused Pallas GaLore-Adam step, AOT-lowered to
//!   HLO-text artifacts (`make artifacts`).
//! * **L3 (run time, this crate)** — the coordinator: data pipeline,
//!   training loop, per-layer (layerwise) weight updates, data-parallel
//!   workers with a ring all-reduce, the full optimizer zoo (Adam, AdamW,
//!   Adafactor, 8-bit Adam, GaLore wrappers, LoRA/ReLoRA baselines), memory
//!   accounting, metrics, checkpoints, and the PJRT runtime that executes
//!   the artifacts. The fused GaLore kernels plug into the one `GaLore<O>`
//!   optimizer as a swappable step backend (`optim::backend`), so "fused"
//!   is a backend choice, not a second implementation. Python never runs
//!   on the training path.
//!
//! See `DESIGN.md` for the system inventory and the experiment index that
//! maps every table/figure of the paper to a module and bench.
//! Hot-path allocation discipline (workspace-based kernels, zero
//! allocations per steady-state optimizer step) is documented and measured
//! in EXPERIMENTS.md §Perf.

// Index-based loops in the numeric kernels (matmul/QR/Jacobi) are the
// clearest way to express blocked/strided access; iterator rewrites hurt
// readability without changing codegen here.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod linalg;
pub mod lowrank;
pub mod memory;
pub mod model;
pub mod optim;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod ser;
pub mod serve;
pub mod tensor;
pub mod testing;

pub use tensor::Matrix;
