//! Versioned binary checkpointing.
//!
//! Two on-disk formats share the `"GLCK"` magic:
//!
//! **v1** (legacy, weights-only):
//!   magic "GLCK" | version=1 u32 | step u64 | model-name str |
//!   n_tensors u32 | per tensor: rows u32, cols u32, f32 data.
//!
//! **v2** (full training state — the resume format):
//!   magic "GLCK" | version=2 u32 | payload-len u64 | payload |
//!   fnv1a-64(payload) u64
//!
//! where the payload is
//!   config-fingerprint str | step u64 | model-name str |
//!   n_tensors u32 | tensors (v1 layout) |
//!   n_sections u32 | per section: 4-byte tag, length-prefixed bytes.
//!
//! Sections carry the rest of the training state as opaque `crate::ser`
//! blobs — optimizer moments/projectors (`OPTS`), the data-loader
//! position (`LOAD`), and metrics counters (`METR`). Unknown tags are
//! preserved on read, so older binaries skip newer sections instead of
//! failing. (`FUSD` is legacy: pre-StepBackend fused runs kept their
//! per-layer moments there; current artifact-backend runs carry
//! everything in `OPTS`, and the trainer rejects files that still have a
//! `FUSD` section rather than cold-start those layers.) The trailing checksum plus the
//! length prefix reject truncated or bit-flipped files up front — a
//! partial checkpoint must never poison a resume.
//!
//! Durability: every save writes to a `.tmp` sibling, fsyncs, then
//! renames over the target, so a crash mid-save leaves either the old
//! checkpoint or the new one — never a torn file.
//!
//! v1 files still load (`read` returns [`Checkpoint::V1`]); resuming from
//! one restores weights + step only and the trainer warns loudly that
//! optimizer moments are cold-started.

use crate::model::{ModelConfig, ParamStore};
use crate::ser::{self, Reader};
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 4] = b"GLCK";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;

/// Section tags for the v2 state blobs.
pub const SEC_OPTIMIZER: &[u8; 4] = b"OPTS";
/// Legacy (pre-StepBackend) fused-path section — recognized only to
/// reject such files loudly; never written anymore.
pub const SEC_FUSED: &[u8; 4] = b"FUSD";
pub const SEC_LOADER: &[u8; 4] = b"LOAD";
pub const SEC_METRICS: &[u8; 4] = b"METR";
/// Int8 master weight store (codes + block scales + the stochastic-
/// rounding RNG stream); present iff the run has `weight_precision =
/// int8`. The store cannot be re-derived from the f32 weights on load:
/// absmax re-quantization is not bit-stable and the rounding is
/// stochastic, so a resume that re-quantized would fork the trajectory.
pub const SEC_WSTORE: &[u8; 4] = b"WSTR";

/// Everything a v2 checkpoint carries beyond the weights.
pub struct V2Data {
    pub fingerprint: String,
    pub step: u64,
    pub params: ParamStore,
    pub sections: Vec<([u8; 4], Vec<u8>)>,
}

impl V2Data {
    pub fn section(&self, tag: &[u8; 4]) -> Option<&[u8]> {
        self.sections.iter().find(|(t, _)| t == tag).map(|(_, b)| b.as_slice())
    }
}

/// A parsed checkpoint of either version.
pub enum Checkpoint {
    V1 { params: ParamStore, step: u64 },
    V2(V2Data),
}

fn err(msg: impl Into<String>) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.into())
}

/// FNV-1a 64-bit — cheap, dependency-free integrity check for the v2
/// payload (not cryptographic; it guards against truncation and stray
/// bit flips, which is what crash-interrupted writes produce).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Write `bytes` to `path` atomically: `.tmp` sibling, flush + fsync,
/// rename. The target is either the old file or the complete new one.
fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| err(format!("checkpoint path {path:?} has no file name")))?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.flush()?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
}

fn put_params(out: &mut Vec<u8>, params: &ParamStore) {
    ser::put_str(out, &params.cfg.name);
    ser::put_u32(out, params.tensors.len() as u32);
    for t in &params.tensors {
        ser::put_matrix(out, t);
    }
}

fn read_params(r: &mut Reader<'_>, cfg: &'static ModelConfig) -> std::io::Result<ParamStore> {
    let name = r.str().map_err(err)?;
    if name != cfg.name {
        return Err(err(format!("checkpoint is for model '{name}', not '{}'", cfg.name)));
    }
    let n = r.u32().map_err(err)? as usize;
    let mut store = ParamStore::zeros(cfg);
    if n != store.tensors.len() {
        return Err(err(format!(
            "tensor count mismatch: checkpoint has {n}, schema has {}",
            store.tensors.len()
        )));
    }
    for (i, t) in store.tensors.iter_mut().enumerate() {
        let m = r.matrix().map_err(err)?;
        if m.shape() != t.shape() {
            return Err(err(format!(
                "tensor {i} shape mismatch: checkpoint {:?}, schema {:?}",
                m.shape(),
                t.shape()
            )));
        }
        *t = m;
    }
    Ok(store)
}

/// Save a weights-only v1 checkpoint (legacy format; kept for
/// interoperability with pre-v2 tooling). Atomic like every save.
pub fn save(path: impl AsRef<Path>, params: &ParamStore, step: u64) -> std::io::Result<()> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    ser::put_u32(&mut out, VERSION_V1);
    ser::put_u64(&mut out, step);
    put_params(&mut out, params);
    atomic_write(path.as_ref(), &out)
}

/// Save a full-state v2 checkpoint: weights + step + config fingerprint +
/// the given state sections (tag, blob), checksummed and written
/// atomically.
pub fn save_v2(
    path: impl AsRef<Path>,
    params: &ParamStore,
    fingerprint: &str,
    step: u64,
    sections: &[(&[u8; 4], &[u8])],
) -> std::io::Result<()> {
    let mut payload = Vec::new();
    ser::put_str(&mut payload, fingerprint);
    ser::put_u64(&mut payload, step);
    put_params(&mut payload, params);
    ser::put_u32(&mut payload, sections.len() as u32);
    for (tag, blob) in sections {
        payload.extend_from_slice(*tag);
        ser::put_bytes(&mut payload, blob);
    }
    let mut out = Vec::with_capacity(payload.len() + 24);
    out.extend_from_slice(MAGIC);
    ser::put_u32(&mut out, VERSION_V2);
    ser::put_u64(&mut out, payload.len() as u64);
    out.extend_from_slice(&payload);
    ser::put_u64(&mut out, fnv1a64(&payload));
    atomic_write(path.as_ref(), &out)
}

/// Parse a checkpoint of either version. v2 files are checksum-verified
/// before any field is trusted; truncated or corrupted files are rejected
/// with a descriptive error.
pub fn read(path: impl AsRef<Path>, cfg: &'static ModelConfig) -> std::io::Result<Checkpoint> {
    let bytes = std::fs::read(path.as_ref())?;
    let mut r = Reader::new(&bytes);
    let magic = r.take(4).map_err(err)?;
    if magic != &MAGIC[..] {
        return Err(err("not a GaLore checkpoint"));
    }
    match r.u32().map_err(err)? {
        VERSION_V1 => {
            let step = r.u64().map_err(err)?;
            let params = read_params(&mut r, cfg)?;
            r.expect_end().map_err(err)?;
            Ok(Checkpoint::V1 { params, step })
        }
        VERSION_V2 => {
            let payload_len = r.u64().map_err(err)? as usize;
            let payload = r
                .take(payload_len)
                .map_err(|_| err("checkpoint truncated: payload shorter than header claims"))?;
            let want = r.u64().map_err(|_| err("checkpoint truncated: checksum missing"))?;
            r.expect_end().map_err(err)?;
            let got = fnv1a64(payload);
            if got != want {
                return Err(err(format!(
                    "checkpoint corrupted: checksum {got:#018x} != stored {want:#018x}"
                )));
            }
            let mut p = Reader::new(payload);
            let fingerprint = p.str().map_err(err)?;
            let step = p.u64().map_err(err)?;
            let params = read_params(&mut p, cfg)?;
            let n_sections = p.u32().map_err(err)? as usize;
            let mut sections = Vec::with_capacity(n_sections);
            for _ in 0..n_sections {
                let tag_bytes = p.take(4).map_err(err)?;
                let tag = [tag_bytes[0], tag_bytes[1], tag_bytes[2], tag_bytes[3]];
                let blob = p.bytes().map_err(err)?.to_vec();
                sections.push((tag, blob));
            }
            p.expect_end().map_err(err)?;
            Ok(Checkpoint::V2(V2Data { fingerprint, step, params, sections }))
        }
        v => Err(err(format!("unsupported checkpoint version {v}"))),
    }
}

/// Load weights + step from a checkpoint of either version (the v1-era
/// convenience API; full-state resume goes through `Trainer::restore`).
pub fn load(
    path: impl AsRef<Path>,
    cfg: &'static ModelConfig,
) -> std::io::Result<(ParamStore, u64)> {
    match read(path, cfg)? {
        Checkpoint::V1 { params, step } => Ok((params, step)),
        Checkpoint::V2(d) => Ok((d.params, d.step)),
    }
}

/// Retention: keep the lexicographically-last `keep_last` files in `dir`
/// matching `prefix*.ckpt` (periodic names zero-pad the step, so
/// lexicographic == chronological) and delete the rest. Returns how many
/// files were removed. `keep_last == 0` keeps everything.
pub fn prune(dir: impl AsRef<Path>, prefix: &str, keep_last: usize) -> std::io::Result<usize> {
    if keep_last == 0 {
        return Ok(0);
    }
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(dir.as_ref())? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with(prefix) && name.ends_with(".ckpt") {
            names.push(name);
        }
    }
    names.sort();
    let mut removed = 0;
    if names.len() > keep_last {
        for name in &names[..names.len() - keep_last] {
            std::fs::remove_file(dir.as_ref().join(name))?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// File name for a periodic checkpoint at `step` (zero-padded so
/// lexicographic order is step order — the contract `prune` relies on).
pub fn periodic_name(step: usize) -> String {
    periodic_name_with("step_", step)
}

/// Periodic-checkpoint file name under a caller-chosen prefix. Jobs that
/// share one `checkpoint_dir` (the `galore serve` scheduler) write under
/// distinct prefixes (`job{id}_step_…`) and prune with the same prefix,
/// so one job's retention sweep can never delete another job's files —
/// with the bare `step_` prefix, two jobs pruning the same directory used
/// to collect each other's checkpoints.
pub fn periodic_name_with(prefix: &str, step: usize) -> String {
    format!("{prefix}{step:08}.ckpt")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ModelConfig};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("galore_test_ckpt").join(name)
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 42);
        let path = tmp("nano.ckpt");
        save(&path, &params, 123).unwrap();
        let (loaded, step) = load(&path, cfg).unwrap();
        assert_eq!(step, 123);
        for (a, b) in params.tensors.iter().zip(loaded.tensors.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn v2_roundtrip_with_sections() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 7);
        let path = tmp("nano_v2.ckpt");
        let opt = vec![1u8, 2, 3, 4, 5];
        let loader = vec![9u8; 17];
        save_v2(&path, &params, "fp=test", 55, &[(SEC_OPTIMIZER, &opt), (SEC_LOADER, &loader)])
            .unwrap();
        match read(&path, cfg).unwrap() {
            Checkpoint::V2(d) => {
                assert_eq!(d.fingerprint, "fp=test");
                assert_eq!(d.step, 55);
                assert_eq!(d.section(SEC_OPTIMIZER), Some(opt.as_slice()));
                assert_eq!(d.section(SEC_LOADER), Some(loader.as_slice()));
                assert_eq!(d.section(SEC_FUSED), None);
                for (a, b) in params.tensors.iter().zip(d.params.tensors.iter()) {
                    assert_eq!(a.data, b.data);
                }
            }
            _ => panic!("expected v2"),
        }
        // The convenience loader also reads v2 (weights + step).
        let (_, step) = load(&path, cfg).unwrap();
        assert_eq!(step, 55);
    }

    #[test]
    fn wrong_model_is_rejected() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 0);
        let path = tmp("mismatch.ckpt");
        save(&path, &params, 1).unwrap();
        let other = ModelConfig::by_name("micro").unwrap();
        assert!(load(&path, other).is_err());
        let path2 = tmp("mismatch_v2.ckpt");
        save_v2(&path2, &params, "fp", 1, &[]).unwrap();
        assert!(load(&path2, other).is_err());
    }

    #[test]
    fn garbage_is_rejected() {
        let path = tmp("garbage.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let cfg = ModelConfig::by_name("nano").unwrap();
        assert!(load(&path, cfg).is_err());
    }

    #[test]
    fn truncated_v2_is_rejected() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 3);
        let path = tmp("trunc.ckpt");
        save_v2(&path, &params, "fp", 9, &[(SEC_OPTIMIZER, &[1, 2, 3])]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // A crash mid-write can leave any prefix; every prefix must fail
        // cleanly (v1 had no defense against this).
        for frac in [1, 2, 3, 4] {
            let cut = bytes.len() * frac / 5;
            let path_cut = tmp("trunc_cut.ckpt");
            std::fs::write(&path_cut, &bytes[..cut]).unwrap();
            assert!(read(&path_cut, cfg).is_err(), "prefix of {cut} bytes parsed");
        }
    }

    #[test]
    fn bit_flip_is_rejected_by_checksum() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 3);
        let path = tmp("flip.ckpt");
        save_v2(&path, &params, "fp", 9, &[]).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let e = read(&path, cfg).unwrap_err();
        assert!(e.to_string().contains("checksum") || e.to_string().contains("corrupt"), "{e}");
    }

    #[test]
    fn saves_are_atomic_no_tmp_left_behind() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 1);
        // Own directory: other tests write checkpoints concurrently and a
        // scan of the shared dir could catch their in-flight .tmp files.
        let path = std::env::temp_dir().join("galore_test_ckpt_atomic").join("atomic.ckpt");
        save_v2(&path, &params, "fp", 1, &[]).unwrap();
        // Overwrite an existing checkpoint in place.
        save_v2(&path, &params, "fp", 2, &[]).unwrap();
        let (_, step) = load(&path, cfg).unwrap();
        assert_eq!(step, 2);
        let dir = path.parent().unwrap();
        for entry in std::fs::read_dir(dir).unwrap() {
            let name = entry.unwrap().file_name().to_string_lossy().into_owned();
            assert!(!name.ends_with(".tmp"), "stale tmp file {name}");
        }
    }

    #[test]
    fn prune_keeps_newest_checkpoints() {
        let dir = std::env::temp_dir().join("galore_test_prune");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for step in [10usize, 20, 30, 40] {
            std::fs::write(dir.join(periodic_name(step)), b"x").unwrap();
        }
        std::fs::write(dir.join("other.txt"), b"x").unwrap();
        let removed = prune(&dir, "step_", 2).unwrap();
        assert_eq!(removed, 2);
        assert!(!dir.join(periodic_name(10)).exists());
        assert!(!dir.join(periodic_name(20)).exists());
        assert!(dir.join(periodic_name(30)).exists());
        assert!(dir.join(periodic_name(40)).exists());
        assert!(dir.join("other.txt").exists(), "prune must only touch its own files");
        assert_eq!(prune(&dir, "step_", 0).unwrap(), 0, "keep_last=0 keeps everything");
    }

    #[test]
    fn prefixed_prunes_are_isolated_per_job() {
        // Two jobs retaining in one directory: each prune sweep must only
        // ever see its own prefix's files.
        let dir = std::env::temp_dir().join("galore_test_prune_prefix");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for step in [10usize, 20, 30] {
            std::fs::write(dir.join(periodic_name_with("job1_step_", step)), b"x").unwrap();
            std::fs::write(dir.join(periodic_name_with("job2_step_", step)), b"x").unwrap();
        }
        let removed = prune(&dir, "job1_step_", 1).unwrap();
        assert_eq!(removed, 2);
        assert!(dir.join(periodic_name_with("job1_step_", 30)).exists());
        for step in [10usize, 20, 30] {
            assert!(
                dir.join(periodic_name_with("job2_step_", step)).exists(),
                "job1's prune deleted job2's step-{step} checkpoint"
            );
        }
    }
}
