//! Binary checkpointing: weights + step counter + config fingerprint.
//!
//! Format (little-endian):
//!   magic "GLCK" | version u32 | step u64 | model-name len u32 + bytes |
//!   n_tensors u32 | per tensor: rows u32, cols u32, f32 data.

use crate::model::{ModelConfig, ParamStore};
use crate::tensor::Matrix;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"GLCK";
const VERSION: u32 = 1;

pub fn save(path: impl AsRef<Path>, params: &ParamStore, step: u64) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&step.to_le_bytes())?;
    let name = params.cfg.name.as_bytes();
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name)?;
    f.write_all(&(params.tensors.len() as u32).to_le_bytes())?;
    for t in &params.tensors {
        f.write_all(&(t.rows as u32).to_le_bytes())?;
        f.write_all(&(t.cols as u32).to_le_bytes())?;
        // Safe little-endian serialization of the f32 payload.
        let mut bytes = Vec::with_capacity(t.data.len() * 4);
        for &v in &t.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes)?;
    }
    Ok(())
}

fn read_u32(r: &mut impl Read) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn err(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}

/// Load a checkpoint; the model config must match the stored name.
pub fn load(path: impl AsRef<Path>, cfg: &'static ModelConfig) -> std::io::Result<(ParamStore, u64)> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(err("not a GaLore checkpoint"));
    }
    if read_u32(&mut f)? != VERSION {
        return Err(err("unsupported checkpoint version"));
    }
    let step = read_u64(&mut f)?;
    let name_len = read_u32(&mut f)? as usize;
    let mut name = vec![0u8; name_len];
    f.read_exact(&mut name)?;
    let name = String::from_utf8(name).map_err(|_| err("bad model name"))?;
    if name != cfg.name {
        return Err(err(&format!("checkpoint is for model '{name}', not '{}'", cfg.name)));
    }
    let n = read_u32(&mut f)? as usize;
    let mut store = ParamStore::zeros(cfg);
    if n != store.tensors.len() {
        return Err(err("tensor count mismatch"));
    }
    for (i, t) in store.tensors.iter_mut().enumerate() {
        let rows = read_u32(&mut f)? as usize;
        let cols = read_u32(&mut f)? as usize;
        if (rows, cols) != (t.rows, t.cols) {
            return Err(err(&format!("tensor {i} shape mismatch")));
        }
        let mut bytes = vec![0u8; rows * cols * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        *t = Matrix::from_vec(rows, cols, data);
    }
    Ok((store, step))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ModelConfig};

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 42);
        let path = std::env::temp_dir().join("galore_test_ckpt/nano.ckpt");
        save(&path, &params, 123).unwrap();
        let (loaded, step) = load(&path, cfg).unwrap();
        assert_eq!(step, 123);
        for (a, b) in params.tensors.iter().zip(loaded.tensors.iter()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn wrong_model_is_rejected() {
        let cfg = ModelConfig::by_name("nano").unwrap();
        let params = init_params(cfg, 0);
        let path = std::env::temp_dir().join("galore_test_ckpt/mismatch.ckpt");
        save(&path, &params, 1).unwrap();
        let other = ModelConfig::by_name("micro").unwrap();
        assert!(load(&path, other).is_err());
    }

    #[test]
    fn garbage_is_rejected() {
        let path = std::env::temp_dir().join("galore_test_ckpt/garbage.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let cfg = ModelConfig::by_name("nano").unwrap();
        assert!(load(&path, cfg).is_err());
    }
}
