//! Ring transports for data-parallel training.
//!
//! The DP worker loop (`coordinator::parallel`) speaks to its peers
//! through the [`Transport`] trait — one neighbour-exchange primitive on
//! a ring — and the chunked all-reduce collectives ([`all_reduce_sum`] /
//! [`all_reduce_mean`]) are generic over it. Two implementations:
//!
//! * [`RingHandle`] — the in-process channel ring (one handle per worker
//!   thread, wired by [`Ring::into_handles`]). This is the original
//!   transport; the generic collectives reproduce its chunk arithmetic
//!   *exactly*, so swapping transports never changes a single bit of the
//!   reduced values.
//! * [`SocketRing`] — a multi-process ring over Unix domain sockets.
//!   Either wired in-process from socketpairs ([`local_socket_ring`], the
//!   test/bench seam) or across OS processes via a rank-0 **rendezvous**
//!   ([`Rendezvous`] / [`join_rendezvous`]): workers connect to a
//!   well-known socket, rank 0 assigns ranks in join order and tells each
//!   worker its ring successor, and the control connections stay open for
//!   end-of-run result frames.
//!
//! Failure model: a peer that exits (error, panic, or death) closes its
//! sockets/channels; neighbours observe the closure on their next hop and
//! get [`RingClosed`] instead of hanging. The aggregator demotes these
//! shutdown echoes below the root cause (see
//! `parallel::collect_worker_results`).
//!
//! Elastic membership (join/leave mid-run) is out of scope here; the
//! rendezvous/control-socket seam is the attachment point it will use.

use std::io::{Read, Write};
use std::os::unix::ffi::OsStrExt;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// Marker text shared by every ring-shutdown error. The aggregator uses
/// it to demote these secondary failures below the root-cause worker
/// error (a `RingClosed` is a symptom of *another* worker dying).
pub const RING_ABORT_MSG: &str =
    "ring all-reduce aborted: a peer worker shut down mid-collective";

/// The ring collective could not complete because a peer dropped its
/// end — it returned an error, panicked, or (process transport) died.
/// Not a data error: the observing worker should abort its replica and
/// let the aggregator surface the peer's failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RingClosed;

impl std::fmt::Display for RingClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(RING_ABORT_MSG)
    }
}

impl std::error::Error for RingClosed {}

/// One ring participant: the neighbour-exchange primitive the chunked
/// collectives are built on. `exchange` sends a chunk to the successor
/// `(rank + 1) % world` and receives the predecessor's chunk — every
/// ring hop is one such simultaneous send/receive on all ranks.
pub trait Transport: Send {
    /// This participant's rank in `0..world`.
    fn rank(&self) -> usize;
    /// Number of ring participants.
    fn world(&self) -> usize;
    /// Send `send` to the ring successor and receive the predecessor's
    /// chunk into `recv` (cleared and resized; capacity reused across
    /// hops). Errors with [`RingClosed`] when a peer is gone.
    fn exchange(&mut self, send: &[f32], recv: &mut Vec<f32>) -> Result<(), RingClosed>;
}

/// In-place chunked ring all-reduce (sum) over `data`: W−1 reduce-scatter
/// hops then W−1 all-gather hops, `data` split into `world` chunks of
/// `ceil(n/world)`. Bit-identical across [`Transport`] implementations —
/// the arithmetic (chunk bounds, hop order, elementwise add) lives here
/// once; transports only move bytes.
pub fn all_reduce_sum<T: Transport + ?Sized>(
    tp: &mut T,
    data: &mut [f32],
) -> Result<(), RingClosed> {
    let w = tp.world();
    if w == 1 {
        return Ok(());
    }
    let rank = tp.rank();
    let n = data.len();
    let chunk = n.div_ceil(w);
    let bounds = |c: usize| -> (usize, usize) { ((c * chunk).min(n), ((c + 1) * chunk).min(n)) };
    let mut recv = Vec::new();
    // Reduce-scatter: after step s, worker owns the fully-reduced chunk
    // (rank - s) mod w at the end.
    for s in 0..w - 1 {
        let send_c = (rank + w - s) % w;
        let (a, b) = bounds(send_c);
        // Split the borrow: the sent chunk is read-only, the received
        // chunk is accumulated into a different range afterwards.
        tp.exchange(&data[a..b], &mut recv)?;
        let recv_c = (rank + w - s - 1) % w;
        let (a, b) = bounds(recv_c);
        for (d, r) in data[a..b].iter_mut().zip(recv.iter()) {
            *d += r;
        }
    }
    // All-gather the reduced chunks around the ring.
    for s in 0..w - 1 {
        let send_c = (rank + 1 + w - s) % w;
        let (a, b) = bounds(send_c);
        tp.exchange(&data[a..b], &mut recv)?;
        let recv_c = (rank + w - s) % w;
        let (a, b) = bounds(recv_c);
        data[a..b].copy_from_slice(&recv);
    }
    Ok(())
}

/// Average instead of sum (sum, then scale by `1/world` — the exact
/// arithmetic the channel ring always used).
pub fn all_reduce_mean<T: Transport + ?Sized>(
    tp: &mut T,
    data: &mut [f32],
) -> Result<(), RingClosed> {
    all_reduce_sum(tp, data)?;
    let inv = 1.0 / tp.world() as f32;
    for v in data.iter_mut() {
        *v *= inv;
    }
    Ok(())
}

// -- in-process channel ring -------------------------------------------------

/// Channel mesh for a ring of `n` in-process participants exchanging f32
/// chunks (the thread transport).
pub struct Ring {
    /// senders[i] sends to worker (i+1) % n.
    senders: Vec<Sender<Vec<f32>>>,
    receivers: Vec<Receiver<Vec<f32>>>,
}

impl Ring {
    /// Build the channel mesh for `n` participants.
    pub fn new(n: usize) -> Ring {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        Ring { senders, receivers }
    }

    /// Split into per-worker handles (must be called once).
    pub fn into_handles(self) -> Vec<RingHandle> {
        let n = self.senders.len();
        let mut senders: Vec<Option<Sender<Vec<f32>>>> =
            self.senders.into_iter().map(Some).collect();
        let mut receivers: Vec<Option<Receiver<Vec<f32>>>> =
            self.receivers.into_iter().map(Some).collect();
        (0..n)
            .map(|i| RingHandle {
                rank: i,
                world: n,
                // worker i sends on channel i (to i+1), receives on channel
                // (i-1+n)%n (from i-1).
                to_next: senders[i].take().unwrap(), // PANIC-OK: slot i is Some — filled above, taken only here
                from_prev: receivers[(i + n - 1) % n].take().unwrap(), // PANIC-OK: i -> (i-1+n)%n is a bijection, each slot taken once
            })
            .collect()
    }
}

/// One worker's end of the in-process channel ring.
pub struct RingHandle {
    /// This worker's rank in `0..world`.
    pub rank: usize,
    /// Ring size.
    pub world: usize,
    to_next: Sender<Vec<f32>>,
    from_prev: Receiver<Vec<f32>>,
}

impl RingHandle {
    /// In-place ring all-reduce (sum) — see [`all_reduce_sum`].
    pub fn all_reduce_sum(&mut self, data: &mut [f32]) -> Result<(), RingClosed> {
        all_reduce_sum(self, data)
    }

    /// Average instead of sum — see [`all_reduce_mean`].
    pub fn all_reduce_mean(&mut self, data: &mut [f32]) -> Result<(), RingClosed> {
        all_reduce_mean(self, data)
    }
}

impl Transport for RingHandle {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn exchange(&mut self, send: &[f32], recv: &mut Vec<f32>) -> Result<(), RingClosed> {
        self.to_next.send(send.to_vec()).map_err(|_| RingClosed)?;
        let got = self.from_prev.recv().map_err(|_| RingClosed)?;
        recv.clear();
        recv.extend_from_slice(&got);
        Ok(())
    }
}

// -- Unix-domain-socket ring -------------------------------------------------

/// Body segment size for the interleaved socket exchange, in bytes. Must
/// stay comfortably below the kernel's default socket buffer (~208 KiB on
/// Linux for AF_UNIX): each hop interleaves write-one-segment /
/// read-one-segment, and with segments this small the ring provably
/// cannot fill every buffer at once, so a cycle of blocked writers is
/// impossible (a naive "write the whole chunk, then read" deadlocks as
/// soon as chunks exceed the buffer).
const SEG_BYTES: usize = 32 * 1024;

/// One worker's end of a Unix-domain-socket ring (same-host processes or
/// threads). `next` carries this rank's sends; `prev` carries the
/// predecessor's. Dropping it closes both streams, which is how peers
/// learn this worker is gone ([`RingClosed`] on their next hop).
pub struct SocketRing {
    rank: usize,
    world: usize,
    next: UnixStream,
    prev: UnixStream,
}

/// Reinterpret an f32 slice as native-endian bytes for the wire.
///
/// SAFETY: f32 has no invalid bit patterns and u8 has alignment 1, so
/// viewing the f32 buffer's bytes is always valid. Same-host only — both
/// ends share endianness, documented on [`SocketRing`].
fn f32s_as_bytes(xs: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

/// Mutable byte view of an f32 buffer (see [`f32s_as_bytes`]).
///
/// SAFETY: as above; every byte pattern written is a valid f32.
fn f32s_as_bytes_mut(xs: &mut [f32]) -> &mut [u8] {
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut u8, xs.len() * 4) }
}

impl SocketRing {
    /// This worker's rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Ring size.
    pub fn world(&self) -> usize {
        self.world
    }
}

impl Transport for SocketRing {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world(&self) -> usize {
        self.world
    }

    fn exchange(&mut self, send: &[f32], recv: &mut Vec<f32>) -> Result<(), RingClosed> {
        // Length header first (4 bytes always fit the socket buffer, which
        // is drained between hops), then bodies in interleaved segments so
        // neither direction can back up a full chunk. Send and receive
        // lengths may differ: the last ring chunk is smaller.
        let send_bytes = f32s_as_bytes(send);
        self.next
            .write_all(&(send.len() as u32).to_le_bytes())
            .map_err(|_| RingClosed)?;
        let mut hdr = [0u8; 4];
        self.prev.read_exact(&mut hdr).map_err(|_| RingClosed)?;
        let recv_len = u32::from_le_bytes(hdr) as usize;
        recv.clear();
        recv.resize(recv_len, 0.0);
        let recv_bytes = f32s_as_bytes_mut(recv);
        let (mut so, mut ro) = (0usize, 0usize);
        while so < send_bytes.len() || ro < recv_bytes.len() {
            if so < send_bytes.len() {
                let e = (so + SEG_BYTES).min(send_bytes.len());
                self.next.write_all(&send_bytes[so..e]).map_err(|_| RingClosed)?;
                so = e;
            }
            if ro < recv_bytes.len() {
                let e = (ro + SEG_BYTES).min(recv_bytes.len());
                self.prev.read_exact(&mut recv_bytes[ro..e]).map_err(|_| RingClosed)?;
                ro = e;
            }
        }
        Ok(())
    }
}

/// Wire a socket ring entirely in-process from socketpairs: pair `k`
/// connects rank `k`'s `next` to rank `(k+1) % world`'s `prev`. The
/// test/bench seam for exercising the socket transport without processes
/// or rendezvous — hand each returned end to its own thread.
pub fn local_socket_ring(world: usize) -> std::io::Result<Vec<SocketRing>> {
    let mut nexts: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    let mut prevs: Vec<Option<UnixStream>> = (0..world).map(|_| None).collect();
    for k in 0..world {
        let (a, b) = UnixStream::pair()?;
        nexts[k] = Some(a);
        prevs[(k + 1) % world] = Some(b);
    }
    Ok((0..world)
        .map(|r| SocketRing {
            rank: r,
            world,
            next: nexts[r].take().unwrap(), // PANIC-OK: pair loop fills every slot, each taken once
            prev: prevs[r].take().unwrap(), // PANIC-OK: k -> (k+1)%world is a bijection over 0..world
        })
        .collect())
}

// -- control-socket frames ---------------------------------------------------

/// Write one length-prefixed frame (u32 LE header + payload) to a control
/// socket.
pub fn write_frame(s: &mut UnixStream, bytes: &[u8]) -> std::io::Result<()> {
    s.write_all(&(bytes.len() as u32).to_le_bytes())?;
    s.write_all(bytes)
}

/// Read one length-prefixed frame. An EOF here means the peer process is
/// gone — callers turn that into their "worker died" root cause.
pub fn read_frame(s: &mut UnixStream) -> std::io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    s.read_exact(&mut hdr)?;
    let n = u32::from_le_bytes(hdr) as usize;
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf)?;
    Ok(buf)
}

// -- multi-process rendezvous ------------------------------------------------

/// Environment variable through which a spawned worker process finds the
/// host's rendezvous socket. Set by the process-transport host on its
/// children; its presence is how a re-exec'd `galore` binary knows it is
/// a DP worker, not a fresh run.
pub const RENDEZVOUS_ENV: &str = "GALORE_DP_RENDEZVOUS";

/// Rank-0 side of the multi-process rendezvous: binds the well-known
/// socket (so it exists before any child is spawned), collects joiners,
/// assigns ranks in join order, and wires the socket ring.
pub struct Rendezvous {
    listener: UnixListener,
    path: PathBuf,
    ring_listener: UnixListener,
    ring_path: PathBuf,
    world: usize,
}

fn io_err(msg: String) -> std::io::Error {
    std::io::Error::other(msg)
}

impl Rendezvous {
    /// Bind the rendezvous socket at `dir/rendezvous.sock` (and rank 0's
    /// own ring listener). Call this *before* spawning workers so their
    /// immediate connect cannot race the bind.
    pub fn bind(dir: &Path, world: usize) -> std::io::Result<Rendezvous> {
        let path = dir.join("rendezvous.sock");
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)?;
        let ring_path = dir.join("ring-0.sock");
        let _ = std::fs::remove_file(&ring_path);
        let ring_listener = UnixListener::bind(&ring_path)?;
        Ok(Rendezvous { listener, path, ring_listener, ring_path, world })
    }

    /// Path workers must connect to (export as [`RENDEZVOUS_ENV`]).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Wait (up to `timeout`) for `world - 1` workers to join, assign
    /// ranks in join order, wire the ring, and return rank 0's ring end
    /// plus the per-worker control sockets (index `i` is rank `i + 1`),
    /// kept open for end-of-run report frames. Times out with an error —
    /// never hangs — if a spawned worker dies before joining.
    pub fn establish(self, timeout: Duration) -> std::io::Result<(SocketRing, Vec<UnixStream>)> {
        let deadline = Instant::now() + timeout;
        self.listener.set_nonblocking(true)?;
        let mut ctrls: Vec<UnixStream> = Vec::new();
        let mut ring_paths: Vec<PathBuf> = Vec::new();
        while ctrls.len() + 1 < self.world {
            match self.listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false)?;
                    let frame = read_frame(&mut s)?;
                    ring_paths.push(PathBuf::from(os_string_from_bytes(frame)));
                    ctrls.push(s);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        return Err(io_err(format!(
                            "rendezvous timed out with {}/{} workers joined — \
                             did a spawned worker die before connecting?",
                            ctrls.len() + 1,
                            self.world
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        // Reply (rank, world, successor's ring-listener path) to each
        // worker. Every listener is already bound, so the connects that
        // follow can only land in a live backlog — no lost-connection
        // races.
        for (i, ctrl) in ctrls.iter_mut().enumerate() {
            let rank = i + 1;
            let next_path =
                if rank + 1 == self.world { &self.ring_path } else { &ring_paths[rank] };
            let mut frame = Vec::new();
            crate::ser::put_u32(&mut frame, rank as u32);
            crate::ser::put_u32(&mut frame, self.world as u32);
            crate::ser::put_bytes(&mut frame, next_path.as_os_str().as_bytes());
            write_frame(ctrl, &frame)?;
        }
        // Rank 0 wires itself like any worker: connect to rank 1's
        // listener, accept from rank world-1.
        let next = UnixStream::connect(&ring_paths[0])?;
        self.ring_listener.set_nonblocking(true)?;
        let prev = loop {
            match self.ring_listener.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    break s;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        return Err(io_err(
                            "rendezvous timed out waiting for the ring predecessor".into(),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        };
        let _ = std::fs::remove_file(&self.ring_path);
        let _ = std::fs::remove_file(&self.path);
        Ok((SocketRing { rank: 0, world: self.world, next, prev }, ctrls))
    }
}

/// Path bytes → `OsString` (Unix-only crate: the bytes *are* the path
/// encoding).
fn os_string_from_bytes(v: Vec<u8>) -> std::ffi::OsString {
    use std::os::unix::ffi::OsStringExt;
    std::ffi::OsString::from_vec(v)
}

/// Worker side of the multi-process rendezvous: bind an own ring
/// listener, join the host at `rendezvous`, learn (rank, world,
/// successor), and wire this worker's ring end. Returns the ring plus the
/// control socket (keep it open; send the end-of-run report frame on it).
pub fn join_rendezvous(rendezvous: &Path) -> std::io::Result<(SocketRing, UnixStream)> {
    // pid + process-local counter keeps listener paths unique even when
    // several joiners share one process (thread-hosted tests).
    static JOIN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = JOIN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let dir = rendezvous.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
    let my_path = dir.join(format!("ring-{}-{}.sock", std::process::id(), seq));
    let _ = std::fs::remove_file(&my_path);
    let listener = UnixListener::bind(&my_path)?;
    let mut ctrl = UnixStream::connect(rendezvous)?;
    write_frame(&mut ctrl, my_path.as_os_str().as_bytes())?;
    let reply = read_frame(&mut ctrl)?;
    let mut r = crate::ser::Reader::new(&reply);
    let parse = |e: String| io_err(format!("malformed rendezvous reply: {e}"));
    let rank = r.u32().map_err(parse)? as usize;
    let world = r.u32().map_err(parse)? as usize;
    let next_path = PathBuf::from(os_string_from_bytes(r.bytes().map_err(parse)?.to_vec()));
    let next = UnixStream::connect(&next_path)?;
    let (prev, _) = listener.accept()?;
    let _ = std::fs::remove_file(&my_path);
    Ok((SocketRing { rank, world, next, prev }, ctrl))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ring(world: usize, len: usize) {
        let handles = Ring::new(world).into_handles();
        let results = reduce_all(handles, len);
        check_sum(&results, world, len);
    }

    /// Drive `all_reduce_sum` on every transport end, one thread each,
    /// with rank-dependent data `data[i] = rank * len + i`.
    fn reduce_all<T: Transport + Send>(ends: Vec<T>, len: usize) -> Vec<Vec<f32>> {
        std::thread::scope(|scope| {
            let joins: Vec<_> = ends
                .into_iter()
                .map(|mut t| {
                    scope.spawn(move || {
                        let mut data: Vec<f32> =
                            (0..len).map(|i| (t.rank() * len + i) as f32).collect();
                        all_reduce_sum(&mut t, &mut data).unwrap();
                        data
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        })
    }

    fn check_sum(results: &[Vec<f32>], world: usize, len: usize) {
        for i in 0..len {
            let want: f32 = (0..world).map(|r| (r * len + i) as f32).sum();
            for (r, res) in results.iter().enumerate() {
                assert!((res[i] - want).abs() < 1e-4, "w{world} len{len} rank{r} idx{i}");
            }
        }
    }

    #[test]
    fn ring_all_reduce_correct_various_sizes() {
        for world in [1, 2, 3, 4, 7] {
            for len in [1, 5, 16, 103] {
                run_ring(world, len);
            }
        }
    }

    #[test]
    fn socket_ring_matches_channel_ring_bit_exactly() {
        // Same data, both transports: the reduced values must agree to the
        // bit — the collectives' arithmetic is transport-independent.
        for world in [2, 3, 4] {
            for len in [1, 7, 64, 1003] {
                let chan = reduce_all(Ring::new(world).into_handles(), len);
                let sock = reduce_all(local_socket_ring(world).unwrap(), len);
                for (c, s) in chan.iter().zip(sock.iter()) {
                    assert_eq!(c, s, "world {world} len {len}");
                }
            }
        }
    }

    #[test]
    fn socket_exchange_survives_chunks_larger_than_socket_buffers() {
        // 1 MiB per rank chunk — far beyond the kernel's AF_UNIX buffer.
        // The interleaved segment protocol must complete (a naive
        // write-all-then-read deadlocks here and the test would time out).
        run_large(3, 786_432); // 3 MiB total, 1 MiB chunks
        fn run_large(world: usize, len: usize) {
            let ends = local_socket_ring(world).unwrap();
            let results = reduce_all(ends, len);
            check_sum(&results, world, len);
        }
    }

    #[test]
    fn mean_divides_by_world() {
        let handles = Ring::new(4).into_handles();
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|mut h| {
                    scope.spawn(move || {
                        let mut data = vec![(h.rank + 1) as f32; 8];
                        h.all_reduce_mean(&mut data).unwrap();
                        data
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        for res in results {
            for v in res {
                assert!((v - 2.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn dead_peer_yields_ring_closed_not_panic() {
        // Worker 1 "fails" before its first collective (drops its handle);
        // the survivors' all-reduce must come back as RingClosed, not hang
        // or panic.
        let handles = Ring::new(3).into_handles();
        assert!(count_survivor_errors(handles) >= 2);
    }

    #[test]
    fn dead_socket_peer_yields_ring_closed_not_hang() {
        // Same failure mode over the socket transport: the dropped end
        // closes its streams, survivors read EOF / write EPIPE.
        let ends = local_socket_ring(3).unwrap();
        assert!(count_survivor_errors(ends) >= 2);
    }

    fn count_survivor_errors<T: Transport + Send>(ends: Vec<T>) -> usize {
        let results: Vec<Result<(), RingClosed>> = std::thread::scope(|scope| {
            let joins: Vec<_> = ends
                .into_iter()
                .map(|mut t| {
                    scope.spawn(move || {
                        if t.rank() == 1 {
                            return Err(RingClosed); // simulate an early worker error
                        }
                        let mut data = vec![1.0f32; 64];
                        // Loop: the first collective may partially succeed
                        // on buffered sends; shutdown must surface within a
                        // bounded number of rounds.
                        for _ in 0..4 {
                            all_reduce_sum(&mut t, &mut data)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        results.iter().filter(|r| r.is_err()).count()
    }

    #[test]
    fn frames_roundtrip_and_eof_is_an_error() {
        let (mut a, mut b) = UnixStream::pair().unwrap();
        write_frame(&mut a, b"hello frames").unwrap();
        assert_eq!(read_frame(&mut b).unwrap(), b"hello frames");
        write_frame(&mut a, &[]).unwrap();
        assert_eq!(read_frame(&mut b).unwrap(), Vec::<u8>::new());
        drop(a);
        assert!(read_frame(&mut b).is_err(), "EOF must surface as an error");
    }

    #[test]
    fn rendezvous_assigns_ranks_and_wires_a_working_ring() {
        let dir = std::env::temp_dir().join(format!("galore-rdv-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let world = 3;
        let rdv = Rendezvous::bind(&dir, world).unwrap();
        let path = rdv.path().to_path_buf();
        // "Workers" are threads here; process mode drives the same code.
        let results = std::thread::scope(|scope| {
            let joiners: Vec<_> = (1..world)
                .map(|_| {
                    let path = path.clone();
                    scope.spawn(move || {
                        let (mut ring, mut ctrl) = join_rendezvous(&path).unwrap();
                        let mut data = vec![ring.rank() as f32; 16];
                        all_reduce_sum(&mut ring, &mut data).unwrap();
                        write_frame(&mut ctrl, &ring.rank().to_le_bytes()).unwrap();
                        data
                    })
                })
                .collect();
            let (mut ring, mut ctrls) =
                rdv.establish(Duration::from_secs(30)).unwrap();
            assert_eq!(ring.rank(), 0);
            assert_eq!(ring.world(), world);
            let mut data = vec![0.0f32; 16];
            all_reduce_sum(&mut ring, &mut data).unwrap();
            // Control sockets stay open for report frames, rank order.
            for (i, c) in ctrls.iter_mut().enumerate() {
                let frame = read_frame(c).unwrap();
                let rank = usize::from_le_bytes(frame.try_into().unwrap());
                assert_eq!(rank, i + 1);
            }
            let mut all = vec![data];
            all.extend(joiners.into_iter().map(|j| j.join().unwrap()));
            all
        });
        // Sum of ranks 0..world in every slot, on every participant.
        let want = (0..world).sum::<usize>() as f32;
        for res in &results {
            assert!(res.iter().all(|&v| (v - want).abs() < 1e-6), "{res:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
