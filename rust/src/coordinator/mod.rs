//! L3 coordinator: the training orchestrator.
//!
//! * [`trainer`] — the core loop: artifact execution, §4.3 per-layer
//!   weight updates, optimizer dispatch for every method in the paper.
//!   The GaLore step backend (pure Rust vs the fused Pallas-kernel
//!   artifacts, `optim::backend`) is chosen once in `build_optimizer` —
//!   the loop itself is backend-agnostic.
//! * [`fused`] — thin artifact-discovery/validation glue for the fused
//!   backend (shape gathering + engine construction).
//! * [`parallel`] — synchronous data-parallel workers with a chunked ring
//!   all-reduce (barrier or bucketed/overlapped), generic over the ring
//!   transport.
//! * [`transport`] — the ring transports: in-process channels and
//!   multi-process Unix-domain sockets (rank-0 rendezvous, worker
//!   processes spawned by `--dp-transport process`).
//! * [`schedule`] — warmup + cosine LR (Appendix C.1).
//! * [`metrics`] — loss/ppl/throughput tracking, CSV sinks for figures.
//! * [`checkpoint`] — versioned full-training-state checkpoints (v2:
//!   weights + optimizer moments + projectors + loader position + metrics,
//!   atomic writes, checksum-verified; v1 weights-only files still load).

pub mod checkpoint;
pub mod fused;
pub mod job;
pub mod metrics;
pub mod parallel;
pub mod schedule;
pub mod trainer;
pub mod transport;

pub use metrics::{thread_alloc_stats, AllocStats, Metrics};
pub use parallel::{
    collect_worker_results, exchange_grads, exchange_grads_overlapped, plan_grads,
    train_data_parallel, train_data_parallel_resumable, train_dp_over, DpResult, OverlapTimes,
};
pub use transport::{
    all_reduce_mean, all_reduce_sum, local_socket_ring, Ring, RingClosed, RingHandle, SocketRing,
    Transport, RING_ABORT_MSG,
};
pub use job::{Job, JobInfo, JobRunner, JobSpec, JobState, SyntheticRunner, WorkloadKind};
pub use schedule::LrSchedule;
pub use trainer::{build_optimizer, build_optimizer_with, Trainer};
