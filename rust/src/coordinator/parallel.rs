//! Data-parallel training: worker threads with a chunked **ring
//! all-reduce** over channels (the §5.5 scaling story: GaLore's small
//! states make data parallelism the cheap axis — gradients are the only
//! cross-worker traffic).
//!
//! Topology: W workers, each owning a full model replica, its own PJRT
//! engine and a disjoint shard stream. Per step each worker computes
//! gradients, the ring averages them (reduce-scatter + all-gather, W−1
//! hops each), and every worker applies the identical optimizer update —
//! replicas stay bit-identical without weight broadcasts, exactly like
//! synchronous DDP.
//!
//! Adaptive-rank runs (`galore.rank_schedule`) need no extra coordination:
//! rank decisions and lazy-refresh gating are deterministic functions of
//! the *averaged* gradient and the shared run seed, and every worker sees
//! the same averaged gradient — so per-layer ranks stay identical across
//! replicas, and so do the remapped moments.

use crate::config::RunConfig;
use crate::coordinator::Trainer;
use crate::data::{DataLoader, SyntheticCorpus};
use crate::runtime::{default_dir, Engine};
use anyhow::Result;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Channel mesh for a ring of `n` participants exchanging f32 chunks.
pub struct Ring {
    /// senders[i] sends to worker (i+1) % n.
    senders: Vec<Sender<Vec<f32>>>,
    receivers: Vec<Receiver<Vec<f32>>>,
}

impl Ring {
    pub fn new(n: usize) -> Ring {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        Ring { senders, receivers }
    }

    /// Split into per-worker handles (must be called once).
    pub fn into_handles(self) -> Vec<RingHandle> {
        let n = self.senders.len();
        let mut senders: Vec<Option<Sender<Vec<f32>>>> =
            self.senders.into_iter().map(Some).collect();
        let mut receivers: Vec<Option<Receiver<Vec<f32>>>> =
            self.receivers.into_iter().map(Some).collect();
        (0..n)
            .map(|i| RingHandle {
                rank: i,
                world: n,
                // worker i sends on channel i (to i+1), receives on channel
                // (i-1+n)%n (from i-1).
                to_next: senders[i].take().unwrap(),
                from_prev: receivers[(i + n - 1) % n].take().unwrap(),
            })
            .collect()
    }
}

pub struct RingHandle {
    pub rank: usize,
    pub world: usize,
    to_next: Sender<Vec<f32>>,
    from_prev: Receiver<Vec<f32>>,
}

impl RingHandle {
    /// In-place ring all-reduce (sum) over `data`, chunked into `world`
    /// segments: W−1 reduce-scatter hops then W−1 all-gather hops.
    pub fn all_reduce_sum(&self, data: &mut [f32]) {
        let w = self.world;
        if w == 1 {
            return;
        }
        let n = data.len();
        let chunk = n.div_ceil(w);
        let bounds =
            |c: usize| -> (usize, usize) { ((c * chunk).min(n), ((c + 1) * chunk).min(n)) };
        // Reduce-scatter: after step s, worker owns the fully-reduced chunk
        // (rank - s) mod w at the end.
        for s in 0..w - 1 {
            let send_c = (self.rank + w - s) % w;
            let (a, b) = bounds(send_c);
            self.to_next.send(data[a..b].to_vec()).expect("ring send");
            let recv = self.from_prev.recv().expect("ring recv");
            let recv_c = (self.rank + w - s - 1) % w;
            let (a, b) = bounds(recv_c);
            for (d, r) in data[a..b].iter_mut().zip(recv.iter()) {
                *d += r;
            }
        }
        // All-gather the reduced chunks around the ring.
        for s in 0..w - 1 {
            let send_c = (self.rank + 1 + w - s) % w;
            let (a, b) = bounds(send_c);
            self.to_next.send(data[a..b].to_vec()).expect("ring send");
            let recv = self.from_prev.recv().expect("ring recv");
            let recv_c = (self.rank + w - s) % w;
            let (a, b) = bounds(recv_c);
            data[a..b].copy_from_slice(&recv);
        }
    }

    /// Average instead of sum.
    pub fn all_reduce_mean(&self, data: &mut [f32]) {
        self.all_reduce_sum(data);
        let inv = 1.0 / self.world as f32;
        for v in data.iter_mut() {
            *v *= inv;
        }
    }
}

/// Result of a data-parallel run.
pub struct DpResult {
    pub final_train_loss: f32,
    pub final_eval_loss: f32,
    pub total_tokens: u64,
    pub elapsed: std::time::Duration,
    /// Rank-0 optimizer-state bytes at the end of the run (per replica;
    /// shrinks over time under adaptive rank schedules).
    pub final_state_bytes: usize,
}

/// Synchronous data-parallel training of `cfg` over `cfg.dp_workers`
/// workers. Each worker holds a replica; gradients are ring-averaged each
/// step. Returns the rank-0 metrics.
pub fn train_data_parallel(cfg: &RunConfig) -> Result<DpResult> {
    train_data_parallel_resumable(cfg, None)
}

/// As [`train_data_parallel`], optionally resuming from a full-state (v2)
/// checkpoint. Checkpoint participation follows the replica invariant:
/// replicas are bit-identical after every step (same averaged gradient,
/// same seeds), so **rank 0 alone writes** periodic checkpoints
/// (`cfg.checkpoint_every`) and **every replica restores** from the same
/// file on resume — the loader position it carries (the shard counter)
/// applies to each worker's own seed-offset corpus.
pub fn train_data_parallel_resumable(
    cfg: &RunConfig,
    resume: Option<&std::path::Path>,
) -> Result<DpResult> {
    let world = cfg.dp_workers.max(1);
    let handles = Ring::new(world).into_handles();
    let t0 = std::time::Instant::now();
    let results: Vec<Result<(f32, f32, u64, usize)>> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for handle in handles {
            let cfg = cfg.clone();
            let resume = resume.map(|p| p.to_path_buf());
            joins.push(scope.spawn(move || -> Result<(f32, f32, u64, usize)> {
                let engine = Engine::new(default_dir())?;
                // Disjoint shard streams per worker: offset the corpus seed.
                let corpus =
                    SyntheticCorpus::new(cfg.model.vocab, cfg.seed ^ 0xDA7A ^ (handle.rank as u64) << 32);
                let loader = DataLoader::synthetic(corpus, cfg.batch, cfg.model.seq);
                let mut trainer = Trainer::new(cfg.clone(), engine, loader)?;
                if let Some(path) = &resume {
                    trainer.restore_checkpoint(path)?;
                }
                while trainer.step < cfg.steps {
                    let step = trainer.step;
                    let batch = trainer.loader.next_batch();
                    // Gradients land in the trainer's persistent buffers
                    // and are ring-reduced in place — no per-step clones.
                    let loss = trainer.compute_grads_into(&batch)?;
                    for g in trainer.grad_bufs.iter_mut() {
                        handle.all_reduce_mean(&mut g.data);
                    }
                    let mut loss_buf = [loss];
                    handle.all_reduce_mean(&mut loss_buf);
                    let lr = trainer.schedule.at(step);
                    let a0 = crate::coordinator::metrics::thread_alloc_stats();
                    let bufs = std::mem::take(&mut trainer.grad_bufs);
                    trainer.apply_updates(&bufs, lr);
                    trainer.grad_bufs = bufs;
                    let a1 = crate::coordinator::metrics::thread_alloc_stats();
                    trainer
                        .metrics
                        .log_step_allocs(a1.allocs - a0.allocs, a1.bytes - a0.bytes);
                    trainer.metrics.log_step(step, loss_buf[0], lr, batch.n_tokens());
                    trainer.step += 1;
                    if handle.rank == 0
                        && cfg.checkpoint_every > 0
                        && trainer.step % cfg.checkpoint_every == 0
                    {
                        trainer.save_periodic_checkpoint()?;
                    }
                }
                let eval = trainer.eval(2)?;
                Ok((
                    trainer.metrics.tail_loss(10).unwrap_or(f32::NAN),
                    eval,
                    trainer.metrics.total_tokens(),
                    trainer.optimizer_state_bytes(),
                ))
            }));
        }
        joins.into_iter().map(|j| j.join().expect("worker panicked")).collect()
    });
    let elapsed = t0.elapsed();
    let mut first = None;
    let mut total_tokens = 0;
    for r in results {
        let (train, eval, tokens, state_bytes) = r?;
        total_tokens += tokens;
        if first.is_none() {
            first = Some((train, eval, state_bytes));
        }
    }
    let (final_train_loss, final_eval_loss, final_state_bytes) = first.unwrap();
    Ok(DpResult { final_train_loss, final_eval_loss, total_tokens, elapsed, final_state_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ring(world: usize, len: usize) {
        let handles = Ring::new(world).into_handles();
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    scope.spawn(move || {
                        let mut data: Vec<f32> =
                            (0..len).map(|i| (h.rank * len + i) as f32).collect();
                        h.all_reduce_sum(&mut data);
                        data
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // Expected: elementwise sum over workers.
        for i in 0..len {
            let want: f32 = (0..world).map(|r| (r * len + i) as f32).sum();
            for (r, res) in results.iter().enumerate() {
                assert!((res[i] - want).abs() < 1e-4, "w{world} len{len} rank{r} idx{i}");
            }
        }
    }

    #[test]
    fn ring_all_reduce_correct_various_sizes() {
        for world in [1, 2, 3, 4, 7] {
            for len in [1, 5, 16, 103] {
                run_ring(world, len);
            }
        }
    }

    #[test]
    fn mean_divides_by_world() {
        let handles = Ring::new(4).into_handles();
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    scope.spawn(move || {
                        let mut data = vec![(h.rank + 1) as f32; 8];
                        h.all_reduce_mean(&mut data);
                        data
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        for res in results {
            for v in res {
                assert!((v - 2.5).abs() < 1e-5);
            }
        }
    }
}
