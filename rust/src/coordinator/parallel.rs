//! Data-parallel training: replicas over a chunked **ring all-reduce**
//! (the §5.5 scaling story: GaLore's small states make data parallelism
//! the cheap axis — gradients are the only cross-worker traffic).
//!
//! Topology: W workers, each owning a full model replica, its own PJRT
//! engine and a disjoint shard stream. Per step each worker computes
//! gradients, the ring averages them (reduce-scatter + all-gather, W−1
//! hops each), and every worker applies the identical optimizer update —
//! replicas stay bit-identical without weight broadcasts, exactly like
//! synchronous DDP.
//!
//! **Transports** ([`coordinator::transport`](crate::coordinator::transport)):
//! the worker loop is generic over [`Transport`], so the same code drives
//! the in-process channel ring (`dp_transport = thread`, workers are
//! threads of this process) and the multi-process Unix-domain-socket ring
//! (`dp_transport = process`: rank 0 is this process, ranks 1..W are
//! spawned `galore` child processes wired through a rendezvous socket).
//! The collectives' chunk arithmetic lives in the transport module once,
//! so switching transports never changes a single reduced bit.
//!
//! **Compact-gradient exchange** (`cfg.dp_compress`): between subspace
//! refreshes a GaLore-targeted layer's update consumes only the projected
//! gradient `R = Pᵀ G` (`r×n`), and every replica holds a bit-identical
//! basis `P` — so replicas project *before* the all-reduce and exchange
//! `R` instead of `G`, an exact (real-arithmetic) `min(m,n)/r`× traffic
//! cut per targeted layer. Full gradients still flow for non-target
//! parameters and at refresh boundaries, where the randomized SVD, the
//! rank schedule, and the lazy-refresh gate all need the *averaged* `G`
//! to keep replica projectors bit-identical. The per-parameter decision
//! is the optimizer's ([`Optimizer::grad_reduce_mode`]); this module just
//! executes the plan and accounts the traffic.
//!
//! **Bucketed overlap** (`cfg.dp_bucket_mb`): instead of one
//! stop-the-world exchange per step, [`exchange_grads_overlapped`] splits
//! the planned payload into fixed-size buckets and reduces them on a
//! dedicated comm thread while the update path applies already-reduced
//! buckets — comm hides behind compute. The collective *sequence* is
//! identical to the barrier path (same parameters, same order, loss
//! last), so replicas and loss curves stay bit-identical; only wall-clock
//! changes. [`OverlapTimes`] reports how much comm was hidden.
//!
//! Adaptive-rank runs (`galore.rank_schedule`) need no extra coordination:
//! rank decisions and lazy-refresh gating are deterministic functions of
//! the *averaged* gradient and the shared run seed, and every worker sees
//! the same averaged gradient — so per-layer ranks stay identical across
//! replicas, and so do the remapped moments. Under `dp_compress` the rank
//! decision points are exactly the refresh boundaries, where the full
//! gradient is reduced, so compact exchange composes with every schedule.
//!
//! Failure handling: collectives are fallible. A worker that errors (or
//! panics, or — process transport — dies) drops its ring endpoints;
//! neighbours observe [`RingClosed`] on their next hop, shut down in
//! turn, and the aggregator surfaces the *first root-cause* worker error
//! instead of a process-wide recv panic or a hang.

use crate::config::{DpTransport, RunConfig};
use crate::coordinator::transport::{
    all_reduce_mean, join_rendezvous, read_frame, write_frame, Rendezvous, Ring, RingClosed,
    SocketRing, Transport, RENDEZVOUS_ENV, RING_ABORT_MSG,
};
use crate::coordinator::Trainer;
use crate::data::{DataLoader, SyntheticCorpus};
use crate::optim::{GradReduceMode, Optimizer};
use crate::runtime::Engine;
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Result};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Build one step's per-parameter communication plan (written into
/// `plan`, schema order) and project compact-reduced gradients into
/// `compact`. With `compress` off every parameter is planned `Full` (the
/// plan is still recorded). Returns the logical reduced payload in f32
/// elements — the per-step communication the metrics account; ring wire
/// traffic per worker is `2·(W−1)/W` of it.
///
/// `compact` and `plan` are caller-owned workspaces reused across steps:
/// zero steady-state allocations once warm.
pub fn plan_grads(
    opt: &dyn Optimizer,
    grads: &[Matrix],
    compact: &mut Vec<Matrix>,
    plan: &mut Vec<GradReduceMode>,
    compress: bool,
) -> u64 {
    if compact.len() < grads.len() {
        compact.resize_with(grads.len(), || Matrix::zeros(0, 0));
    }
    plan.clear();
    let mut payload = 0u64;
    for (idx, g) in grads.iter().enumerate() {
        let mode = if compress {
            opt.grad_reduce_mode(idx, g.rows, g.cols)
        } else {
            GradReduceMode::Full
        };
        if let GradReduceMode::Compact { .. } = mode {
            // The plan and the projection come from the same optimizer
            // state, so a refusal here is a contract violation — fail
            // loudly rather than reduce a stale buffer.
            assert!(
                opt.project_grad_into(idx, g, &mut compact[idx]),
                "optimizer planned a compact reduce for param {idx} but refused \
                 to project its gradient"
            );
        }
        payload += mode.payload_f32s(g.rows, g.cols) as u64;
        plan.push(mode);
    }
    payload
}

/// Execute one step's gradient exchange according to the per-parameter
/// communication plan ([`plan_grads`], which this calls first): a full
/// ring average for [`GradReduceMode::Full`] entries, project-then-average
/// into `compact[idx]` for [`GradReduceMode::Compact`] ones. Barrier
/// semantics: returns only when every parameter has been reduced. Returns
/// the logical reduced payload in f32 elements.
pub fn exchange_grads<T: Transport + ?Sized>(
    tp: &mut T,
    opt: &dyn Optimizer,
    grads: &mut [Matrix],
    compact: &mut Vec<Matrix>,
    plan: &mut Vec<GradReduceMode>,
    compress: bool,
) -> Result<u64, RingClosed> {
    let payload = plan_grads(opt, grads, compact, plan, compress);
    for (idx, g) in grads.iter_mut().enumerate() {
        match plan[idx] {
            GradReduceMode::Full => all_reduce_mean(tp, &mut g.data)?,
            GradReduceMode::Compact { .. } => all_reduce_mean(tp, &mut compact[idx].data)?,
        }
    }
    Ok(payload)
}

/// Greedy bucket plan over the payload sizes in `plan`: ascending
/// end-indices into the parameter list, closing a bucket when adding the
/// next parameter would exceed `cap_f32s` (a parameter larger than the
/// cap gets a bucket of its own). The last entry is always `plan.len()`.
fn plan_buckets(plan: &[GradReduceMode], grads: &[Matrix], cap_f32s: usize) -> Vec<usize> {
    let mut ends = Vec::new();
    let mut cur = 0usize;
    let mut count = 0usize;
    for (i, mode) in plan.iter().enumerate() {
        let p = mode.payload_f32s(grads[i].rows, grads[i].cols);
        if count > 0 && cur + p > cap_f32s {
            ends.push(i);
            cur = 0;
            count = 0;
        }
        cur += p;
        count += 1;
    }
    ends.push(plan.len());
    ends
}

/// Wall-clock split of one overlapped exchange (rank-local).
#[derive(Clone, Copy, Debug, Default)]
pub struct OverlapTimes {
    /// Time the comm thread spent inside ring collectives.
    pub comm: Duration,
    /// Time the update thread actually stalled waiting for a reduced
    /// bucket. `comm − wait` is the comm hidden behind compute.
    pub wait: Duration,
}

impl OverlapTimes {
    /// Comm time hidden behind compute.
    pub fn hidden(&self) -> Duration {
        self.comm.saturating_sub(self.wait)
    }

    /// Overlap efficiency: `hidden / comm` in `[0, 1]`; `0.0` when there
    /// was no communication at all.
    pub fn efficiency(&self) -> f64 {
        if self.comm.is_zero() {
            0.0
        } else {
            self.hidden().as_secs_f64() / self.comm.as_secs_f64()
        }
    }
}

/// Bucketed, overlapped gradient exchange: split the planned payload into
/// buckets of at most `bucket_cap_f32s` elements ([`plan_buckets`]),
/// reduce them on a dedicated comm thread in plan order, and invoke
/// `apply(start, grads, compact)` on each bucket's parameter range
/// `[start, start + grads.len())` as soon as its reduction lands — the
/// update work overlaps the remaining buckets' communication. The loss
/// scalar is reduced last; the mean is returned with the measured
/// [`OverlapTimes`].
///
/// `plan` and `compact` must already be populated by [`plan_grads`]
/// (`compact` sliced to `grads.len()`). The collective *sequence* is
/// identical on every rank and identical to [`exchange_grads`] + a loss
/// reduce, so bucketing never changes a reduced bit — replicas running
/// different bucket caps (or none) stay in lockstep.
///
/// On an `apply` error the remaining buckets are still drained and
/// reduced — peers need this rank's hops to complete their own step —
/// and the apply error takes precedence over any subsequent ring error.
pub fn exchange_grads_overlapped<T: Transport + ?Sized>(
    tp: &mut T,
    grads: &mut [Matrix],
    compact: &mut [Matrix],
    plan: &[GradReduceMode],
    bucket_cap_f32s: usize,
    loss: f32,
    apply: &mut dyn FnMut(usize, &[Matrix], &[Matrix]) -> Result<()>,
) -> Result<(f32, OverlapTimes)> {
    if plan.len() != grads.len() || compact.len() != grads.len() {
        bail!(
            "overlapped exchange: plan covers {} of {} parameters ({} compact buffers)",
            plan.len(),
            grads.len(),
            compact.len()
        );
    }
    let ends = plan_buckets(plan, grads, bucket_cap_f32s.max(1));
    // Slice grads/compact into disjoint per-bucket chunks the comm thread
    // can own mutably while the update thread applies finished buckets.
    let mut chunks: Vec<(usize, &mut [Matrix], &mut [Matrix])> = Vec::with_capacity(ends.len());
    {
        let mut g_rest: &mut [Matrix] = grads;
        let mut c_rest: &mut [Matrix] = compact;
        let mut start = 0usize;
        for &end in &ends {
            let (g_head, g_tail) = g_rest.split_at_mut(end - start);
            let (c_head, c_tail) = c_rest.split_at_mut(end - start);
            chunks.push((start, g_head, c_head));
            g_rest = g_tail;
            c_rest = c_tail;
            start = end;
        }
    }
    let n_buckets = chunks.len();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut wait = Duration::ZERO;
    let mut apply_err: Option<anyhow::Error> = None;
    let comm_res: Result<(f32, Duration), RingClosed> = std::thread::scope(|scope| {
        let tp = &mut *tp;
        let comm = scope.spawn(move || -> Result<(f32, Duration), RingClosed> {
            let mut comm_time = Duration::ZERO;
            for (start, gs, cs) in chunks {
                let t = Instant::now();
                for i in 0..gs.len() {
                    match plan[start + i] {
                        GradReduceMode::Full => all_reduce_mean(tp, &mut gs[i].data)?,
                        GradReduceMode::Compact { .. } => {
                            all_reduce_mean(tp, &mut cs[i].data)?
                        }
                    }
                }
                comm_time += t.elapsed();
                // The update thread may have stopped applying (apply
                // error); never let that stall the ring — peers still
                // need this rank's hops.
                let _ = tx.send((start, gs, cs));
            }
            let t = Instant::now();
            let mut loss_buf = [loss];
            all_reduce_mean(tp, &mut loss_buf)?;
            comm_time += t.elapsed();
            Ok((loss_buf[0], comm_time))
        });
        for _ in 0..n_buckets {
            let t = Instant::now();
            match rx.recv() {
                Ok((start, gs, cs)) => {
                    wait += t.elapsed();
                    if apply_err.is_none() {
                        if let Err(e) = apply(start, gs, cs) {
                            apply_err = Some(e);
                        }
                    }
                }
                // Comm thread bailed early; its join result carries why.
                Err(_) => break,
            }
        }
        comm.join().unwrap_or_else(|p| std::panic::resume_unwind(p))
    });
    if let Some(e) = apply_err {
        return Err(e);
    }
    let (mean_loss, comm) = comm_res?;
    Ok((mean_loss, OverlapTimes { comm, wait }))
}

/// Result of a data-parallel run.
pub struct DpResult {
    /// Rank-0 mean training loss over the last 10 steps.
    pub final_train_loss: f32,
    /// Rank-0 held-out eval loss after the final step.
    pub final_eval_loss: f32,
    /// Global tokens consumed across all replicas over the whole training
    /// run, including any segment before a checkpoint restore.
    pub total_tokens: u64,
    /// Wall-clock of the whole run (spawn to aggregate).
    pub elapsed: std::time::Duration,
    /// Rank-0 optimizer-state bytes at the end of the run (per replica;
    /// shrinks over time under adaptive rank schedules).
    pub final_state_bytes: usize,
    /// Rank-0's cumulative reduced gradient payload (f32 elements;
    /// logical all-reduce size, see [`exchange_grads`]). Observational
    /// and per-process, like throughput: a resumed run counts only the
    /// post-restore segment (unlike `total_tokens`, which attributes the
    /// pre-interrupt segment explicitly).
    pub comm_f32s_total: u64,
    /// Rank-0's reduced payload on the final step (the steady-state
    /// per-step figure when the run does not end on a refresh boundary).
    pub comm_f32s_last_step: u64,
    /// Rank-0's cumulative wall-clock inside ring collectives.
    pub comm_time: Duration,
    /// Rank-0's cumulative wall-clock the update path actually stalled on
    /// communication. Equals `comm_time` on the barrier path; smaller
    /// under bucketed overlap (`dp_bucket_mb > 0`), where
    /// `comm_time − comm_wait_time` was hidden behind compute.
    pub comm_wait_time: Duration,
}

impl DpResult {
    /// Overlap efficiency over the whole run:
    /// `(comm_time − comm_wait_time) / comm_time` in `[0, 1]`.
    pub fn overlap_efficiency(&self) -> f64 {
        OverlapTimes { comm: self.comm_time, wait: self.comm_wait_time }.efficiency()
    }
}

/// What one worker reports back on success.
struct WorkerOutcome {
    train_loss: f32,
    eval_loss: f32,
    session_tokens: u64,
    resumed_tokens: u64,
    state_bytes: usize,
    comm_f32s_total: u64,
    comm_f32s_last_step: u64,
    comm_nanos: u64,
    wait_nanos: u64,
}

fn save_outcome(out: &mut Vec<u8>, o: &WorkerOutcome) {
    crate::ser::put_f32(out, o.train_loss);
    crate::ser::put_f32(out, o.eval_loss);
    crate::ser::put_u64(out, o.session_tokens);
    crate::ser::put_u64(out, o.resumed_tokens);
    crate::ser::put_usize(out, o.state_bytes);
    crate::ser::put_u64(out, o.comm_f32s_total);
    crate::ser::put_u64(out, o.comm_f32s_last_step);
    crate::ser::put_u64(out, o.comm_nanos);
    crate::ser::put_u64(out, o.wait_nanos);
}

fn load_outcome(r: &mut crate::ser::Reader) -> Result<WorkerOutcome, String> {
    Ok(WorkerOutcome {
        train_loss: r.f32()?,
        eval_loss: r.f32()?,
        session_tokens: r.u64()?,
        resumed_tokens: r.u64()?,
        state_bytes: r.usize()?,
        comm_f32s_total: r.u64()?,
        comm_f32s_last_step: r.u64()?,
        comm_nanos: r.u64()?,
        wait_nanos: r.u64()?,
    })
}

/// Synchronous data-parallel training of `cfg` over `cfg.dp_workers`
/// workers. Each worker holds a replica; gradients are ring-averaged each
/// step (compact-projected first when `cfg.dp_compress` is set). Returns
/// the rank-0 metrics.
pub fn train_data_parallel(cfg: &RunConfig) -> Result<DpResult> {
    train_data_parallel_resumable(cfg, None)
}

/// As [`train_data_parallel`], optionally resuming from a full-state (v2)
/// checkpoint. Checkpoint participation follows the replica invariant:
/// replicas are bit-identical after every step (same averaged gradient,
/// same seeds), so **rank 0 alone writes** periodic checkpoints
/// (`cfg.checkpoint_every`) and **every replica restores** from the same
/// file on resume — the loader position it carries (the shard counter)
/// applies to each worker's own seed-offset corpus.
///
/// `cfg.dp_transport` picks the substrate: `thread` runs the workers as
/// threads of this process over the channel ring; `process` spawns
/// `dp_workers − 1` child processes of the current executable and wires
/// them (plus this process as rank 0) over the Unix-socket ring.
pub fn train_data_parallel_resumable(
    cfg: &RunConfig,
    resume: Option<&std::path::Path>,
) -> Result<DpResult> {
    let world = cfg.dp_workers.max(1);
    match cfg.dp_transport {
        DpTransport::Thread => train_dp_over(cfg, Ring::new(world).into_handles(), resume),
        DpTransport::Process => train_dp_process(cfg, world, resume),
    }
}

/// Run the full data-parallel training loop over caller-provided ring
/// transports, one worker thread per transport (rank order). This is the
/// transport seam: production paths hand it channel handles or let
/// [`train_data_parallel_resumable`] drive the process transport, tests
/// hand it `local_socket_ring` ends to exercise the socket protocol
/// in-process.
pub fn train_dp_over<T: Transport>(
    cfg: &RunConfig,
    transports: Vec<T>,
    resume: Option<&Path>,
) -> Result<DpResult> {
    let world = transports.len();
    let t0 = Instant::now();
    let results: Vec<Result<WorkerOutcome>> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for mut tp in transports {
            let cfg = cfg.clone();
            let resume = resume.map(|p| p.to_path_buf());
            joins.push(scope.spawn(move || dp_worker_loop(&cfg, &mut tp, resume.as_deref())));
        }
        joins
            .into_iter()
            .enumerate()
            .map(|(rank, j)| match j.join() {
                Ok(r) => r,
                // A panicking worker drops its ring endpoints like an
                // erroring one; convert the payload into an error so
                // neighbours' RingClosed shutdowns and this root cause
                // aggregate the same way instead of poisoning the whole
                // process.
                Err(payload) => Err(anyhow!(
                    "worker {rank} panicked: {}",
                    panic_message(payload.as_ref())
                )),
            })
            .collect()
    });
    aggregate_outcomes(results, world, t0.elapsed())
}

/// One replica's full training run over its ring transport. Shared by the
/// thread workers, the process-mode host (rank 0) and the process-mode
/// children.
fn dp_worker_loop<T: Transport + ?Sized>(
    cfg: &RunConfig,
    tp: &mut T,
    resume: Option<&Path>,
) -> Result<WorkerOutcome> {
    let engine = Engine::new(cfg.artifacts_dir())?;
    // Disjoint shard streams per worker: offset the corpus seed.
    let corpus =
        SyntheticCorpus::new(cfg.model.vocab, cfg.seed ^ 0xDA7A ^ (tp.rank() as u64) << 32);
    let loader = DataLoader::synthetic(corpus, cfg.batch, cfg.model.seq);
    let mut trainer = Trainer::new(cfg.clone(), engine, loader)?;
    if let Some(path) = resume {
        trainer.restore_checkpoint(path)?;
    }
    let mut compact_bufs: Vec<Matrix> = Vec::new();
    let mut plan: Vec<GradReduceMode> = Vec::new();
    // Layerwise mode models strictly sequential per-layer consumption —
    // its reverse walk is incompatible with bucket-order application, so
    // it keeps the barrier exchange.
    let bucketed = cfg.dp_bucket_mb > 0 && !cfg.layerwise && tp.world() > 1;
    let bucket_cap_f32s = cfg.dp_bucket_mb.saturating_mul(1 << 20) / 4;
    while trainer.step < cfg.steps {
        let step = trainer.step;
        let batch = trainer.loader.next_batch();
        // Gradients land in the trainer's persistent buffers and are
        // ring-reduced in place — no per-step clones.
        let loss = trainer.compute_grads_into(&batch)?;
        let lr = trainer.schedule.at(step);
        // `mem::take` detaches the buffers (no allocation) so the
        // optimizer can plan/project against them while the trainer is
        // mutably borrowed below.
        let mut bufs = std::mem::take(&mut trainer.grad_bufs);
        let comm;
        let mean_loss;
        let a0;
        if bucketed {
            comm = plan_grads(
                trainer.opt.as_ref(),
                &bufs,
                &mut compact_bufs,
                &mut plan,
                cfg.dp_compress,
            );
            let n = bufs.len();
            let total_bytes: usize = bufs.iter().map(|g| 4 * g.len()).sum();
            // Allocation accounting brackets the whole overlapped
            // exchange: the per-bucket updates run interleaved with it on
            // this thread (comm-thread hop buffers land on its own
            // counter, not here).
            a0 = crate::coordinator::metrics::thread_alloc_stats();
            let exchanged = {
                let trainer = &mut trainer;
                let plan_ref = &plan;
                let mut apply = |start: usize, gs: &[Matrix], cs: &[Matrix]| {
                    trainer.apply_bucket(start, gs, &plan_ref[start..start + gs.len()], cs, lr)
                };
                exchange_grads_overlapped(
                    tp,
                    &mut bufs,
                    &mut compact_bufs[..n],
                    &plan,
                    bucket_cap_f32s,
                    loss,
                    &mut apply,
                )
            };
            trainer.grad_bufs = bufs;
            let (ml, times) = exchanged?;
            // Buckets stepped the weights; round them through the bf16
            // master store once per applied step, like the barrier walk.
            trainer.params.commit();
            trainer.peak_grad_bytes = trainer.peak_grad_bytes.max(total_bytes);
            trainer.metrics.comm_time += times.comm;
            trainer.metrics.comm_wait_time += times.wait;
            mean_loss = ml;
        } else {
            let t = Instant::now();
            comm = exchange_grads(
                tp,
                trainer.opt.as_ref(),
                &mut bufs,
                &mut compact_bufs,
                &mut plan,
                cfg.dp_compress,
            )?;
            let mut loss_buf = [loss];
            all_reduce_mean(tp, &mut loss_buf)?;
            let d = t.elapsed();
            a0 = crate::coordinator::metrics::thread_alloc_stats();
            let applied = trainer.apply_updates_planned(&bufs, &plan, &compact_bufs, lr);
            trainer.grad_bufs = bufs;
            applied?;
            // Barrier semantics: every comm nanosecond is waited on.
            trainer.metrics.comm_time += d;
            trainer.metrics.comm_wait_time += d;
            mean_loss = loss_buf[0];
        }
        let a1 = crate::coordinator::metrics::thread_alloc_stats();
        trainer
            .metrics
            .log_step_allocs(a1.allocs - a0.allocs, a1.bytes - a0.bytes);
        trainer.metrics.log_step_comm(comm);
        trainer.metrics.log_step(step, mean_loss, lr, batch.n_tokens());
        trainer.step += 1;
        if tp.rank() == 0
            && cfg.checkpoint_every > 0
            && trainer.step % cfg.checkpoint_every == 0
        {
            trainer.save_periodic_checkpoint()?;
        }
    }
    let eval = trainer.eval(cfg.eval_batches)?;
    Ok(WorkerOutcome {
        train_loss: trainer.metrics.tail_loss(10).unwrap_or(f32::NAN),
        eval_loss: eval,
        session_tokens: trainer.metrics.session_tokens(),
        resumed_tokens: trainer.metrics.resumed_tokens(),
        state_bytes: trainer.optimizer_state_bytes(),
        comm_f32s_total: trainer.metrics.comm_f32s_total(),
        comm_f32s_last_step: trainer.metrics.last_step_comm_f32s,
        comm_nanos: trainer.metrics.comm_time.as_nanos() as u64,
        wait_nanos: trainer.metrics.comm_wait_time.as_nanos() as u64,
    })
}

/// Fold per-rank outcomes into the run result (rank-0 metrics + global
/// token attribution).
fn aggregate_outcomes(
    results: Vec<Result<WorkerOutcome>>,
    world: usize,
    elapsed: Duration,
) -> Result<DpResult> {
    let outcomes = collect_worker_results(results)?;
    // Global token accounting: every replica consumed `session_tokens`
    // in this process, plus — by the lockstep-replica invariant — the
    // same per-replica `resumed` share before the interrupt (the
    // checkpoint's counter is rank-0's *own* consumption, not a global
    // sum). Attribute the restored share explicitly once per replica;
    // summing raw `total_tokens()` counters would instead bake rank-0's
    // restored counter into every worker implicitly, which is only
    // correct while every replica's per-step token count stays equal.
    let resumed = outcomes[0].resumed_tokens;
    let total_tokens =
        outcomes.iter().map(|o| o.session_tokens).sum::<u64>() + world as u64 * resumed;
    let r0 = &outcomes[0];
    Ok(DpResult {
        final_train_loss: r0.train_loss,
        final_eval_loss: r0.eval_loss,
        total_tokens,
        elapsed,
        final_state_bytes: r0.state_bytes,
        comm_f32s_total: r0.comm_f32s_total,
        comm_f32s_last_step: r0.comm_f32s_last_step,
        comm_time: Duration::from_nanos(r0.comm_nanos),
        comm_wait_time: Duration::from_nanos(r0.wait_nanos),
    })
}

// -- process transport -------------------------------------------------------

/// Spawn `world − 1` copies of the current executable (same argv, plus
/// [`RENDEZVOUS_ENV`]) and rendezvous them into a socket ring with this
/// process as rank 0. Returns the host's ring end, the per-child control
/// sockets (index `i` ↔ rank `i + 1`), the child handles, and the temp
/// rendezvous dir (caller removes it when done).
#[allow(clippy::type_complexity)]
fn spawn_process_ring(
    world: usize,
) -> Result<(SocketRing, Vec<UnixStream>, Vec<std::process::Child>, PathBuf)> {
    let dir = std::env::temp_dir().join(format!("galore-dp-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let rdv = Rendezvous::bind(&dir, world)
        .map_err(|e| anyhow!("binding DP rendezvous in {}: {e}", dir.display()))?;
    let exe = std::env::current_exe()?;
    let args: Vec<std::ffi::OsString> = std::env::args_os().skip(1).collect();
    let mut children: Vec<std::process::Child> = Vec::new();
    for _ in 1..world {
        match std::process::Command::new(&exe)
            .args(&args)
            .env(RENDEZVOUS_ENV, rdv.path())
            .spawn()
        {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_children(&mut children);
                let _ = std::fs::remove_dir_all(&dir);
                bail!("failed to spawn DP worker process: {e}");
            }
        }
    }
    match rdv.establish(Duration::from_secs(30)) {
        Ok((ring, ctrls)) => Ok((ring, ctrls, children, dir)),
        Err(e) => {
            kill_children(&mut children);
            let _ = std::fs::remove_dir_all(&dir);
            bail!("DP rendezvous failed: {e}");
        }
    }
}

fn kill_children(children: &mut [std::process::Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Multi-process data-parallel training: this process is rank 0.
fn train_dp_process(cfg: &RunConfig, world: usize, resume: Option<&Path>) -> Result<DpResult> {
    if world < 2 {
        bail!("dp_transport = process needs dp_workers >= 2 (got {world})");
    }
    let (mut ring, ctrls, mut children, dir) = spawn_process_ring(world)?;
    let t0 = Instant::now();
    let host = dp_worker_loop(cfg, &mut ring, resume);
    // Close the host's ring endpoints *before* collecting reports: if the
    // host failed mid-collective, children would otherwise block on their
    // next hop forever instead of erroring out and reporting.
    drop(ring);
    let mut results: Vec<Result<WorkerOutcome>> = vec![host];
    for (i, mut ctrl) in ctrls.into_iter().enumerate() {
        let rank = i + 1;
        results.push(read_report(&mut ctrl, load_outcome).unwrap_or_else(|e| {
            Err(anyhow!(
                "worker process (rank {rank}) exited without reporting a result: {e}"
            ))
        }));
    }
    for c in children.iter_mut() {
        let _ = c.wait();
    }
    let _ = std::fs::remove_dir_all(&dir);
    aggregate_outcomes(results, world, t0.elapsed())
}

/// Entry point for a spawned DP worker process (rank ≥ 1): join the
/// host's rendezvous, run the replica loop, and report the outcome (or
/// error) on the control socket. `cfg` is rebuilt from the child's argv
/// by `main` — identical to the host's by construction.
pub fn dp_process_child(cfg: &RunConfig, rendezvous: &Path, resume: Option<&Path>) -> Result<()> {
    let (mut ring, mut ctrl) = join_rendezvous(rendezvous)
        .map_err(|e| anyhow!("joining DP rendezvous at {}: {e}", rendezvous.display()))?;
    let outcome = dp_worker_loop(cfg, &mut ring, resume);
    drop(ring);
    send_report(&mut ctrl, outcome, save_outcome).map(|_| ())
}

/// Serialize a worker result (tag 0 + payload on success, tag 1 + message
/// on error) and frame it onto the control socket. Returns the original
/// error, if any, so the child process can exit nonzero.
fn send_report<O>(
    ctrl: &mut UnixStream,
    outcome: Result<O>,
    save: fn(&mut Vec<u8>, &O),
) -> Result<O> {
    let mut frame = Vec::new();
    match &outcome {
        Ok(o) => {
            crate::ser::put_u8(&mut frame, 0);
            save(&mut frame, o);
        }
        Err(e) => {
            crate::ser::put_u8(&mut frame, 1);
            crate::ser::put_str(&mut frame, &e.to_string());
        }
    }
    // Best-effort on the error path: the report is a courtesy, the exit
    // code carries the failure regardless.
    let sent = write_frame(ctrl, &frame);
    if outcome.is_ok() {
        sent.map_err(|e| anyhow!("reporting DP worker result: {e}"))?;
    }
    outcome
}

/// Read one worker report frame and decode it with `load`. An `Err` from
/// this function means the *transport* failed (worker died before
/// reporting); an inner `Err` is the worker's own reported failure.
fn read_report<O>(
    ctrl: &mut UnixStream,
    load: fn(&mut crate::ser::Reader) -> Result<O, String>,
) -> std::io::Result<Result<O>> {
    let frame = read_frame(ctrl)?;
    let mut r = crate::ser::Reader::new(&frame);
    let parse = |e: String| std::io::Error::other(format!("malformed worker report: {e}"));
    match r.u8().map_err(parse)? {
        0 => Ok(Ok(load(&mut r).map_err(parse)?)),
        1 => {
            let msg = r.str().map_err(parse)?;
            Ok(Err(anyhow!("{msg}")))
        }
        t => Err(std::io::Error::other(format!("unknown worker report tag {t}"))),
    }
}

// -- dp-smoke (process-transport harness) ------------------------------------

/// Per-step element count of the dp-smoke workload.
const SMOKE_LEN: usize = 8192;

/// Deterministic per-rank smoke data for one step.
fn smoke_data(rank: usize, step: usize) -> Vec<f32> {
    (0..SMOKE_LEN).map(|i| ((rank * SMOKE_LEN + i + step * 31) % 97) as f32).collect()
}

/// The dp-smoke per-rank loop: `steps` all-reduce-mean rounds over
/// deterministic data, folding the reduced values into an f64 checksum
/// (bit-identical on every rank — the ring reduces every chunk in a fixed
/// order). `die_at` makes this rank exit(1) before the given step — the
/// dropout fault injection.
fn smoke_loop<T: Transport + ?Sized>(
    tp: &mut T,
    steps: usize,
    die_at: Option<usize>,
) -> Result<f64, RingClosed> {
    let mut checksum = 0f64;
    for step in 0..steps {
        if die_at == Some(step) {
            std::process::exit(1);
        }
        let mut data = smoke_data(tp.rank(), step);
        all_reduce_mean(tp, &mut data)?;
        checksum += data.iter().map(|&v| v as f64).sum::<f64>();
    }
    Ok(checksum)
}

fn save_checksum(out: &mut Vec<u8>, sum: &f64) {
    crate::ser::put_f64(out, *sum);
}

fn load_checksum(r: &mut crate::ser::Reader) -> Result<f64, String> {
    r.f64()
}

/// Host side of `galore dp-smoke`: spawn `world − 1` worker processes
/// (argv pass-through, so `--die-rank`/`--die-step` reach them), run the
/// smoke loop as rank 0, and verify every rank reported the bit-identical
/// checksum. A worker that dies mid-run surfaces as a root-cause error
/// naming its rank — never a hang.
pub fn dp_smoke_host(world: usize, steps: usize) -> Result<()> {
    if world < 2 {
        bail!("dp-smoke needs --world >= 2 (got {world})");
    }
    let (mut ring, ctrls, mut children, dir) = spawn_process_ring(world)?;
    let host = smoke_loop(&mut ring, steps, None).map_err(anyhow::Error::from);
    drop(ring);
    let mut results: Vec<Result<f64>> = vec![host];
    for (i, mut ctrl) in ctrls.into_iter().enumerate() {
        let rank = i + 1;
        results.push(read_report(&mut ctrl, load_checksum).unwrap_or_else(|e| {
            Err(anyhow!(
                "dp-smoke worker process (rank {rank}) exited without reporting a result: {e}"
            ))
        }));
    }
    for c in children.iter_mut() {
        let _ = c.wait();
    }
    let _ = std::fs::remove_dir_all(&dir);
    let sums = collect_worker_results(results)?;
    let first = sums[0];
    for (rank, s) in sums.iter().enumerate() {
        if s.to_bits() != first.to_bits() {
            bail!("dp-smoke checksum mismatch: rank 0 got {first}, rank {rank} got {s}");
        }
    }
    println!("dp-smoke ok: world={world} steps={steps} checksum={first}");
    Ok(())
}

/// Worker side of `galore dp-smoke` (invoked when [`RENDEZVOUS_ENV`] is
/// set): join, run the smoke loop — exiting at `--die-step` if this
/// worker was assigned `--die-rank` — and report the checksum.
pub fn dp_smoke_child(rendezvous: &Path, steps: usize, die: Option<(usize, usize)>) -> Result<()> {
    let (mut ring, mut ctrl) = join_rendezvous(rendezvous)
        .map_err(|e| anyhow!("joining dp-smoke rendezvous at {}: {e}", rendezvous.display()))?;
    let die_at = die.and_then(|(rank, step)| (ring.rank() == rank).then_some(step));
    let outcome = smoke_loop(&mut ring, steps, die_at).map_err(anyhow::Error::from);
    drop(ring);
    send_report(&mut ctrl, outcome, save_checksum).map(|_| ())
}

/// Fold per-rank worker results into their outcomes, or the run's error.
/// When workers failed, surface the first **root cause**: a failing
/// worker drops its ring endpoints, which makes every neighbour's next
/// collective fail with a [`RingClosed`]-derived error — those shutdown
/// echoes are demoted below the first error that is *not* one, so the
/// run reports "rank 0: checkpoint save failed", not "rank 1: ring
/// all-reduce aborted".
pub fn collect_worker_results<T>(results: Vec<Result<T>>) -> Result<Vec<T>> {
    let mut outcomes = Vec::with_capacity(results.len());
    let mut first_err: Option<anyhow::Error> = None;
    let mut first_root_err: Option<anyhow::Error> = None;
    for (rank, r) in results.into_iter().enumerate() {
        match r {
            Ok(o) => outcomes.push(o),
            Err(e) => {
                // Substring classification is deliberate: the vendored
                // anyhow is string-backed with no downcast/source chain,
                // and its `context(..)` folds wrappers into the message as
                // "context: cause" — so the marker text survives wrapping,
                // which a type-based check could not even attempt here.
                let is_ring_echo = e.to_string().contains(RING_ABORT_MSG);
                let tagged = anyhow!("data-parallel worker {rank} failed: {e}");
                if !is_ring_echo && first_root_err.is_none() {
                    first_root_err = Some(tagged);
                } else if first_err.is_none() {
                    first_err = Some(tagged);
                }
            }
        }
    }
    match first_root_err.or(first_err) {
        Some(e) => Err(e),
        None => Ok(outcomes),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_payloads_render() {
        let p: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_message(p.as_ref()), "boom");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("kaboom"));
        assert_eq!(panic_message(p.as_ref()), "kaboom");
        let p: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }

    #[test]
    fn bucket_plan_closes_on_capacity_and_covers_all_params() {
        let grads: Vec<Matrix> =
            [(2, 3), (2, 3), (4, 4), (1, 2), (1, 2), (1, 2)] // payloads 6,6,16,2,2,2
                .iter()
                .map(|&(r, c)| Matrix::zeros(r, c))
                .collect();
        let plan = vec![GradReduceMode::Full; grads.len()];
        // cap 12: [0,1], [2] (oversized alone), [3,4,5]
        assert_eq!(plan_buckets(&plan, &grads, 12), vec![2, 3, 6]);
        // huge cap: one bucket
        assert_eq!(plan_buckets(&plan, &grads, 1 << 20), vec![6]);
        // tiny cap: every param alone
        assert_eq!(plan_buckets(&plan, &grads, 1), vec![1, 2, 3, 4, 5, 6]);
        // compact payloads count, not full shapes
        let cplan = vec![
            GradReduceMode::Compact { rows: 1, cols: 2 }, // payload 2
            GradReduceMode::Full,                         // payload 6
            GradReduceMode::Compact { rows: 1, cols: 2 },
        ];
        assert_eq!(plan_buckets(&cplan, &grads[..3], 8), vec![2, 3]);
    }

    #[test]
    fn overlapped_exchange_means_match_and_buckets_apply_in_order() {
        let world = 2;
        let n_params = 5;
        let handles = Ring::new(world).into_handles();
        let results: Vec<(Vec<Matrix>, f32, Vec<(usize, usize)>)> =
            std::thread::scope(|scope| {
                let joins: Vec<_> = handles
                    .into_iter()
                    .map(|mut h| {
                        scope.spawn(move || {
                            let rank = h.rank;
                            let mut grads: Vec<Matrix> = (0..n_params)
                                .map(|i| {
                                    let mut m = Matrix::zeros(3, 4);
                                    for (j, v) in m.data.iter_mut().enumerate() {
                                        *v = (rank * 100 + i * 10 + j) as f32;
                                    }
                                    m
                                })
                                .collect();
                            let mut compact: Vec<Matrix> =
                                (0..n_params).map(|_| Matrix::zeros(0, 0)).collect();
                            let plan = vec![GradReduceMode::Full; n_params];
                            let mut applied: Vec<(usize, usize)> = Vec::new();
                            let mut apply =
                                |start: usize, gs: &[Matrix], _cs: &[Matrix]| -> Result<()> {
                                    applied.push((start, gs.len()));
                                    Ok(())
                                };
                            // cap 24 f32s over 12-f32 params → buckets of 2.
                            let (loss, _times) = exchange_grads_overlapped(
                                &mut h,
                                &mut grads,
                                &mut compact,
                                &plan,
                                24,
                                rank as f32,
                                &mut apply,
                            )
                            .unwrap();
                            (grads, loss, applied)
                        })
                    })
                    .collect();
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
        for (grads, loss, applied) in &results {
            assert_eq!(*loss, 0.5, "loss mean over ranks 0 and 1");
            assert_eq!(applied, &vec![(0, 2), (2, 2), (4, 1)]);
            for (i, g) in grads.iter().enumerate() {
                for (j, v) in g.data.iter().enumerate() {
                    let want = 50.0 + (i * 10 + j) as f32; // mean of rank 0/1 values
                    assert_eq!(*v, want, "param {i} elem {j}");
                }
            }
        }
    }

    #[test]
    fn overlapped_exchange_apply_error_wins_and_ring_stays_drained() {
        let world = 2;
        let handles = Ring::new(world).into_handles();
        let errs: Vec<String> = std::thread::scope(|scope| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|mut h| {
                    scope.spawn(move || {
                        let mut grads: Vec<Matrix> =
                            (0..4).map(|_| Matrix::zeros(2, 2)).collect();
                        let mut compact: Vec<Matrix> =
                            (0..4).map(|_| Matrix::zeros(0, 0)).collect();
                        let plan = vec![GradReduceMode::Full; 4];
                        let mut apply =
                            |start: usize, _gs: &[Matrix], _cs: &[Matrix]| -> Result<()> {
                                if start == 0 {
                                    bail!("synthetic apply failure");
                                }
                                Ok(())
                            };
                        exchange_grads_overlapped(
                            &mut h,
                            &mut grads,
                            &mut compact,
                            &plan,
                            4, // one param per bucket
                            0.0,
                            &mut apply,
                        )
                        .unwrap_err()
                        .to_string()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // Both ranks fail on the *first* bucket's apply, yet neither hangs:
        // the comm thread keeps reducing the remaining buckets so the peer's
        // collectives complete, and the apply error is what surfaces.
        for e in errs {
            assert!(e.contains("synthetic apply failure"), "{e}");
        }
    }
}
