//! Data-parallel training: worker threads with a chunked **ring
//! all-reduce** over channels (the §5.5 scaling story: GaLore's small
//! states make data parallelism the cheap axis — gradients are the only
//! cross-worker traffic).
//!
//! Topology: W workers, each owning a full model replica, its own PJRT
//! engine and a disjoint shard stream. Per step each worker computes
//! gradients, the ring averages them (reduce-scatter + all-gather, W−1
//! hops each), and every worker applies the identical optimizer update —
//! replicas stay bit-identical without weight broadcasts, exactly like
//! synchronous DDP.
//!
//! **Compact-gradient exchange** (`cfg.dp_compress`): between subspace
//! refreshes a GaLore-targeted layer's update consumes only the projected
//! gradient `R = Pᵀ G` (`r×n`), and every replica holds a bit-identical
//! basis `P` — so replicas project *before* the all-reduce and exchange
//! `R` instead of `G`, an exact (real-arithmetic) `min(m,n)/r`× traffic
//! cut per targeted layer. Full gradients still flow for non-target
//! parameters and at refresh boundaries, where the randomized SVD, the
//! rank schedule, and the lazy-refresh gate all need the *averaged* `G`
//! to keep replica projectors bit-identical. The per-parameter decision
//! is the optimizer's ([`Optimizer::grad_reduce_mode`]); this module just
//! executes the plan and accounts the traffic.
//!
//! **Step backends** compose with all of this: each worker's
//! `build_optimizer` plugs the configured `optim::backend::StepBackend`
//! into its replica (the artifact backend brings its own PJRT engine per
//! worker), and the compact entry point is backend-agnostic — so
//! `--backend artifact` (né `--fused`) now runs under `dp_workers > 1`
//! *and* `dp_compress`, a combination the pre-backend design rejected.
//!
//! Adaptive-rank runs (`galore.rank_schedule`) need no extra coordination:
//! rank decisions and lazy-refresh gating are deterministic functions of
//! the *averaged* gradient and the shared run seed, and every worker sees
//! the same averaged gradient — so per-layer ranks stay identical across
//! replicas, and so do the remapped moments. Under `dp_compress` the rank
//! decision points are exactly the refresh boundaries, where the full
//! gradient is reduced, so compact exchange composes with every schedule.
//!
//! Failure handling: collectives are fallible. A worker that errors (or
//! panics) drops its channel handles; neighbours observe [`RingClosed`]
//! on their next hop, shut down in turn, and the aggregator surfaces the
//! *first root-cause* worker error instead of a process-wide recv panic.

use crate::config::RunConfig;
use crate::coordinator::Trainer;
use crate::data::{DataLoader, SyntheticCorpus};
use crate::optim::{GradReduceMode, Optimizer};
use crate::runtime::{default_dir, Engine};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, Sender};

/// Marker text shared by every ring-shutdown error. The aggregator uses
/// it to demote these secondary failures below the root-cause worker
/// error (a `RingClosed` is a symptom of *another* worker dying).
pub const RING_ABORT_MSG: &str =
    "ring all-reduce aborted: a peer worker shut down mid-collective";

/// The ring collective could not complete because a peer dropped its
/// handles — it returned an error or panicked. Not a data error: the
/// observing worker should abort its replica and let the aggregator
/// surface the peer's failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RingClosed;

impl std::fmt::Display for RingClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(RING_ABORT_MSG)
    }
}

impl std::error::Error for RingClosed {}

/// Channel mesh for a ring of `n` participants exchanging f32 chunks.
pub struct Ring {
    /// senders[i] sends to worker (i+1) % n.
    senders: Vec<Sender<Vec<f32>>>,
    receivers: Vec<Receiver<Vec<f32>>>,
}

impl Ring {
    pub fn new(n: usize) -> Ring {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        Ring { senders, receivers }
    }

    /// Split into per-worker handles (must be called once).
    pub fn into_handles(self) -> Vec<RingHandle> {
        let n = self.senders.len();
        let mut senders: Vec<Option<Sender<Vec<f32>>>> =
            self.senders.into_iter().map(Some).collect();
        let mut receivers: Vec<Option<Receiver<Vec<f32>>>> =
            self.receivers.into_iter().map(Some).collect();
        (0..n)
            .map(|i| RingHandle {
                rank: i,
                world: n,
                // worker i sends on channel i (to i+1), receives on channel
                // (i-1+n)%n (from i-1).
                to_next: senders[i].take().unwrap(),
                from_prev: receivers[(i + n - 1) % n].take().unwrap(),
            })
            .collect()
    }
}

pub struct RingHandle {
    pub rank: usize,
    pub world: usize,
    to_next: Sender<Vec<f32>>,
    from_prev: Receiver<Vec<f32>>,
}

impl RingHandle {
    /// In-place ring all-reduce (sum) over `data`, chunked into `world`
    /// segments: W−1 reduce-scatter hops then W−1 all-gather hops.
    /// Errors with [`RingClosed`] when a peer has dropped its handles —
    /// the collective cannot complete and the caller should shut down.
    pub fn all_reduce_sum(&self, data: &mut [f32]) -> Result<(), RingClosed> {
        let w = self.world;
        if w == 1 {
            return Ok(());
        }
        let n = data.len();
        let chunk = n.div_ceil(w);
        let bounds =
            |c: usize| -> (usize, usize) { ((c * chunk).min(n), ((c + 1) * chunk).min(n)) };
        // Reduce-scatter: after step s, worker owns the fully-reduced chunk
        // (rank - s) mod w at the end.
        for s in 0..w - 1 {
            let send_c = (self.rank + w - s) % w;
            let (a, b) = bounds(send_c);
            self.to_next.send(data[a..b].to_vec()).map_err(|_| RingClosed)?;
            let recv = self.from_prev.recv().map_err(|_| RingClosed)?;
            let recv_c = (self.rank + w - s - 1) % w;
            let (a, b) = bounds(recv_c);
            for (d, r) in data[a..b].iter_mut().zip(recv.iter()) {
                *d += r;
            }
        }
        // All-gather the reduced chunks around the ring.
        for s in 0..w - 1 {
            let send_c = (self.rank + 1 + w - s) % w;
            let (a, b) = bounds(send_c);
            self.to_next.send(data[a..b].to_vec()).map_err(|_| RingClosed)?;
            let recv = self.from_prev.recv().map_err(|_| RingClosed)?;
            let recv_c = (self.rank + w - s) % w;
            let (a, b) = bounds(recv_c);
            data[a..b].copy_from_slice(&recv);
        }
        Ok(())
    }

    /// Average instead of sum.
    pub fn all_reduce_mean(&self, data: &mut [f32]) -> Result<(), RingClosed> {
        self.all_reduce_sum(data)?;
        let inv = 1.0 / self.world as f32;
        for v in data.iter_mut() {
            *v *= inv;
        }
        Ok(())
    }
}

/// Execute one step's gradient exchange according to the per-parameter
/// communication plan (written into `plan`, schema order): a full ring
/// average for [`GradReduceMode::Full`] entries, project-then-average
/// into `compact[idx]` for [`GradReduceMode::Compact`] ones. With
/// `compress` off every parameter reduces full (the plan is still
/// recorded, all-`Full`). Returns the logical reduced payload in f32
/// elements — the per-step communication the metrics account; ring wire
/// traffic per worker is `2·(W−1)/W` of it.
///
/// `compact` and `plan` are caller-owned workspaces reused across steps:
/// zero steady-state allocations once warm, matching the hot-path
/// contract of the single-process loop.
pub fn exchange_grads(
    handle: &RingHandle,
    opt: &dyn Optimizer,
    grads: &mut [Matrix],
    compact: &mut Vec<Matrix>,
    plan: &mut Vec<GradReduceMode>,
    compress: bool,
) -> Result<u64, RingClosed> {
    if compact.len() < grads.len() {
        compact.resize_with(grads.len(), || Matrix::zeros(0, 0));
    }
    plan.clear();
    let mut payload = 0u64;
    for (idx, g) in grads.iter_mut().enumerate() {
        let mode = if compress {
            opt.grad_reduce_mode(idx, g.rows, g.cols)
        } else {
            GradReduceMode::Full
        };
        match mode {
            GradReduceMode::Full => {
                handle.all_reduce_mean(&mut g.data)?;
            }
            GradReduceMode::Compact { .. } => {
                // The plan and the projection come from the same optimizer
                // state, so a refusal here is a contract violation — fail
                // loudly rather than reduce a stale buffer.
                assert!(
                    opt.project_grad_into(idx, g, &mut compact[idx]),
                    "optimizer planned a compact reduce for param {idx} but refused \
                     to project its gradient"
                );
                handle.all_reduce_mean(&mut compact[idx].data)?;
            }
        }
        payload += mode.payload_f32s(g.rows, g.cols) as u64;
        plan.push(mode);
    }
    Ok(payload)
}

/// Result of a data-parallel run.
pub struct DpResult {
    pub final_train_loss: f32,
    pub final_eval_loss: f32,
    /// Global tokens consumed across all replicas over the whole training
    /// run, including any segment before a checkpoint restore.
    pub total_tokens: u64,
    pub elapsed: std::time::Duration,
    /// Rank-0 optimizer-state bytes at the end of the run (per replica;
    /// shrinks over time under adaptive rank schedules).
    pub final_state_bytes: usize,
    /// Rank-0's cumulative reduced gradient payload (f32 elements;
    /// logical all-reduce size, see [`exchange_grads`]). Observational
    /// and per-process, like throughput: a resumed run counts only the
    /// post-restore segment (unlike `total_tokens`, which attributes the
    /// pre-interrupt segment explicitly).
    pub comm_f32s_total: u64,
    /// Rank-0's reduced payload on the final step (the steady-state
    /// per-step figure when the run does not end on a refresh boundary).
    pub comm_f32s_last_step: u64,
}

/// What one worker thread reports back on success.
struct WorkerOutcome {
    train_loss: f32,
    eval_loss: f32,
    session_tokens: u64,
    resumed_tokens: u64,
    state_bytes: usize,
    comm_f32s_total: u64,
    comm_f32s_last_step: u64,
}

/// Synchronous data-parallel training of `cfg` over `cfg.dp_workers`
/// workers. Each worker holds a replica; gradients are ring-averaged each
/// step (compact-projected first when `cfg.dp_compress` is set). Returns
/// the rank-0 metrics.
pub fn train_data_parallel(cfg: &RunConfig) -> Result<DpResult> {
    train_data_parallel_resumable(cfg, None)
}

/// As [`train_data_parallel`], optionally resuming from a full-state (v2)
/// checkpoint. Checkpoint participation follows the replica invariant:
/// replicas are bit-identical after every step (same averaged gradient,
/// same seeds), so **rank 0 alone writes** periodic checkpoints
/// (`cfg.checkpoint_every`) and **every replica restores** from the same
/// file on resume — the loader position it carries (the shard counter)
/// applies to each worker's own seed-offset corpus.
pub fn train_data_parallel_resumable(
    cfg: &RunConfig,
    resume: Option<&std::path::Path>,
) -> Result<DpResult> {
    let world = cfg.dp_workers.max(1);
    let handles = Ring::new(world).into_handles();
    let t0 = std::time::Instant::now();
    let results: Vec<Result<WorkerOutcome>> = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for handle in handles {
            let cfg = cfg.clone();
            let resume = resume.map(|p| p.to_path_buf());
            joins.push(scope.spawn(move || -> Result<WorkerOutcome> {
                let engine = Engine::new(default_dir())?;
                // Disjoint shard streams per worker: offset the corpus seed.
                let corpus =
                    SyntheticCorpus::new(cfg.model.vocab, cfg.seed ^ 0xDA7A ^ (handle.rank as u64) << 32);
                let loader = DataLoader::synthetic(corpus, cfg.batch, cfg.model.seq);
                let mut trainer = Trainer::new(cfg.clone(), engine, loader)?;
                if let Some(path) = &resume {
                    trainer.restore_checkpoint(path)?;
                }
                let mut compact_bufs: Vec<Matrix> = Vec::new();
                let mut plan: Vec<GradReduceMode> = Vec::new();
                while trainer.step < cfg.steps {
                    let step = trainer.step;
                    let batch = trainer.loader.next_batch();
                    // Gradients land in the trainer's persistent buffers
                    // and are ring-reduced in place — no per-step clones.
                    let loss = trainer.compute_grads_into(&batch)?;
                    // `mem::take` detaches the buffers (no allocation) so
                    // the optimizer can plan/project against them while the
                    // trainer is mutably borrowed below.
                    let mut bufs = std::mem::take(&mut trainer.grad_bufs);
                    let comm = exchange_grads(
                        &handle,
                        trainer.opt.as_ref(),
                        &mut bufs,
                        &mut compact_bufs,
                        &mut plan,
                        cfg.dp_compress,
                    )?;
                    let mut loss_buf = [loss];
                    handle.all_reduce_mean(&mut loss_buf)?;
                    let lr = trainer.schedule.at(step);
                    let a0 = crate::coordinator::metrics::thread_alloc_stats();
                    let applied = trainer.apply_updates_planned(&bufs, &plan, &compact_bufs, lr);
                    trainer.grad_bufs = bufs;
                    applied?;
                    let a1 = crate::coordinator::metrics::thread_alloc_stats();
                    trainer
                        .metrics
                        .log_step_allocs(a1.allocs - a0.allocs, a1.bytes - a0.bytes);
                    trainer.metrics.log_step_comm(comm);
                    trainer.metrics.log_step(step, loss_buf[0], lr, batch.n_tokens());
                    trainer.step += 1;
                    if handle.rank == 0
                        && cfg.checkpoint_every > 0
                        && trainer.step % cfg.checkpoint_every == 0
                    {
                        trainer.save_periodic_checkpoint()?;
                    }
                }
                let eval = trainer.eval(cfg.eval_batches)?;
                Ok(WorkerOutcome {
                    train_loss: trainer.metrics.tail_loss(10).unwrap_or(f32::NAN),
                    eval_loss: eval,
                    session_tokens: trainer.metrics.session_tokens(),
                    resumed_tokens: trainer.metrics.resumed_tokens(),
                    state_bytes: trainer.optimizer_state_bytes(),
                    comm_f32s_total: trainer.metrics.comm_f32s_total(),
                    comm_f32s_last_step: trainer.metrics.last_step_comm_f32s,
                })
            }));
        }
        joins
            .into_iter()
            .enumerate()
            .map(|(rank, j)| match j.join() {
                Ok(r) => r,
                // A panicking worker drops its ring handles like an erroring
                // one; convert the payload into an error so neighbours'
                // RingClosed shutdowns and this root cause aggregate the
                // same way instead of poisoning the whole process.
                Err(payload) => Err(anyhow!(
                    "worker {rank} panicked: {}",
                    panic_message(payload.as_ref())
                )),
            })
            .collect()
    });
    let elapsed = t0.elapsed();
    let outcomes = collect_worker_results(results)?;
    // Global token accounting: every replica consumed `session_tokens`
    // in this process, plus — by the lockstep-replica invariant — the
    // same per-replica `resumed` share before the interrupt (the
    // checkpoint's counter is rank-0's *own* consumption, not a global
    // sum). Attribute the restored share explicitly once per replica;
    // summing raw `total_tokens()` counters would instead bake rank-0's
    // restored counter into every worker implicitly, which is only
    // correct while every replica's per-step token count stays equal.
    let resumed = outcomes[0].resumed_tokens;
    let total_tokens = outcomes.iter().map(|o| o.session_tokens).sum::<u64>()
        + world as u64 * resumed;
    let r0 = &outcomes[0];
    Ok(DpResult {
        final_train_loss: r0.train_loss,
        final_eval_loss: r0.eval_loss,
        total_tokens,
        elapsed,
        final_state_bytes: r0.state_bytes,
        comm_f32s_total: r0.comm_f32s_total,
        comm_f32s_last_step: r0.comm_f32s_last_step,
    })
}

/// Fold per-rank worker results into their outcomes, or the run's error.
/// When workers failed, surface the first **root cause**: a failing
/// worker drops its ring handles, which makes every neighbour's next
/// collective fail with a [`RingClosed`]-derived error — those shutdown
/// echoes are demoted below the first error that is *not* one, so the
/// run reports "rank 0: checkpoint save failed", not "rank 1: ring
/// all-reduce aborted".
pub fn collect_worker_results<T>(results: Vec<Result<T>>) -> Result<Vec<T>> {
    let mut outcomes = Vec::with_capacity(results.len());
    let mut first_err: Option<anyhow::Error> = None;
    let mut first_root_err: Option<anyhow::Error> = None;
    for (rank, r) in results.into_iter().enumerate() {
        match r {
            Ok(o) => outcomes.push(o),
            Err(e) => {
                // Substring classification is deliberate: the vendored
                // anyhow is string-backed with no downcast/source chain,
                // and its `context(..)` folds wrappers into the message as
                // "context: cause" — so the marker text survives wrapping,
                // which a type-based check could not even attempt here.
                let is_ring_echo = e.to_string().contains(RING_ABORT_MSG);
                let tagged = anyhow!("data-parallel worker {rank} failed: {e}");
                if !is_ring_echo && first_root_err.is_none() {
                    first_root_err = Some(tagged);
                } else if first_err.is_none() {
                    first_err = Some(tagged);
                }
            }
        }
    }
    match first_root_err.or(first_err) {
        Some(e) => Err(e),
        None => Ok(outcomes),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ring(world: usize, len: usize) {
        let handles = Ring::new(world).into_handles();
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    scope.spawn(move || {
                        let mut data: Vec<f32> =
                            (0..len).map(|i| (h.rank * len + i) as f32).collect();
                        h.all_reduce_sum(&mut data).unwrap();
                        data
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // Expected: elementwise sum over workers.
        for i in 0..len {
            let want: f32 = (0..world).map(|r| (r * len + i) as f32).sum();
            for (r, res) in results.iter().enumerate() {
                assert!((res[i] - want).abs() < 1e-4, "w{world} len{len} rank{r} idx{i}");
            }
        }
    }

    #[test]
    fn ring_all_reduce_correct_various_sizes() {
        for world in [1, 2, 3, 4, 7] {
            for len in [1, 5, 16, 103] {
                run_ring(world, len);
            }
        }
    }

    #[test]
    fn mean_divides_by_world() {
        let handles = Ring::new(4).into_handles();
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    scope.spawn(move || {
                        let mut data = vec![(h.rank + 1) as f32; 8];
                        h.all_reduce_mean(&mut data).unwrap();
                        data
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        for res in results {
            for v in res {
                assert!((v - 2.5).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn dead_peer_yields_ring_closed_not_panic() {
        // Worker 1 "fails" before its first collective (drops its handle);
        // the survivors' all-reduce must come back as RingClosed, not hang
        // or panic.
        let handles = Ring::new(3).into_handles();
        let results: Vec<Result<(), RingClosed>> = std::thread::scope(|scope| {
            let joins: Vec<_> = handles
                .into_iter()
                .map(|h| {
                    scope.spawn(move || {
                        if h.rank == 1 {
                            return Err(RingClosed); // simulate an early worker error
                        }
                        let mut data = vec![1.0f32; 64];
                        // Loop: the first collective may partially succeed
                        // on buffered sends; shutdown must surface within a
                        // bounded number of rounds.
                        for _ in 0..4 {
                            h.all_reduce_sum(&mut data)?;
                        }
                        Ok(())
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert!(
            results.iter().filter(|r| r.is_err()).count() >= 2,
            "survivors did not observe the shutdown: {results:?}"
        );
    }

    #[test]
    fn panic_payloads_render() {
        let p: Box<dyn std::any::Any + Send> = Box::new("boom");
        assert_eq!(panic_message(p.as_ref()), "boom");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("kaboom"));
        assert_eq!(panic_message(p.as_ref()), "kaboom");
        let p: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }
}
