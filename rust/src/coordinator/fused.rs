//! Fused GaLore-Adam hot path: per-layer updates executed through the
//! `galore_step_{m}x{n}_r{r}` AOT artifacts (the Pallas kernels of
//! `python/compile/kernels/galore.py`), with projector refreshes through
//! either the `proj_refresh` artifact or the Rust randomized SVD.
//!
//! Tall gradients (m > n) are handled by transposition on entry/exit, so a
//! model needs artifacts only for its short-side-first shapes — exactly
//! what `aot.py` lowers (§4.2: only the short side is projected).

use crate::config::RunConfig;
use crate::model::ParamStore;
use crate::rng::Rng;
use crate::runtime::{Engine, Input};
use crate::tensor::Matrix;
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};

struct LayerState {
    m: Matrix,       // (r, n) compact first moment
    v: Matrix,       // (r, n) compact second moment
    p: Matrix,       // (m, r) projector
    t: u64,
}

pub struct FusedGaLore {
    rank: usize,
    update_freq: u64,
    scale: f32,
    handled: HashSet<usize>,
    states: HashMap<usize, LayerState>,
    rng: Rng,
}

impl FusedGaLore {
    /// Validate that every target shape has a matching artifact and
    /// pre-compile them.
    pub fn new(
        cfg: &RunConfig,
        params: &ParamStore,
        targets: &[usize],
        engine: &mut Engine,
    ) -> Result<FusedGaLore> {
        let rank = cfg.galore.rank;
        let mut handled = HashSet::new();
        for &idx in targets {
            let meta = &params.metas[idx];
            let (m, n) = short_side_first(meta.rows, meta.cols);
            let Some(art) = engine.manifest.galore_step_for(m, n, rank) else {
                bail!(
                    "no galore_step artifact for shape {}x{} rank {rank} — \
                     re-run `make artifacts` with matching ranks",
                    m,
                    n
                );
            };
            let name = art.name.clone();
            engine.prepare(&name)?;
            handled.insert(idx);
        }
        Ok(FusedGaLore {
            rank,
            update_freq: cfg.galore.update_freq,
            scale: cfg.galore.scale,
            handled,
            states: HashMap::new(),
            rng: Rng::new(cfg.seed ^ 0xF05ED),
        })
    }

    pub fn handles(&self, idx: usize) -> bool {
        self.handled.contains(&idx)
    }

    pub fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| 4 * (s.m.len() + s.v.len() + s.p.len()))
            .sum()
    }

    /// One fused step on parameter `idx`.
    pub fn step(
        &mut self,
        engine: &mut Engine,
        idx: usize,
        w: &mut Matrix,
        grad: &Matrix,
        lr: f32,
    ) -> Result<()> {
        let transposed = grad.rows > grad.cols;
        let (gm, gn) = short_side_first(grad.rows, grad.cols);
        let r = self.rank.min(gm);
        // Refresh the projector every T steps (Rust randomized SVD keeps
        // the refresh off the per-step path; an artifact-based refresh is
        // available via `proj_refresh_*` for benchmarking).
        let needs_refresh = match self.states.get(&idx) {
            None => true,
            Some(s) => s.t % self.update_freq == 0,
        };
        let g_short = if transposed { grad.transpose() } else { grad.clone() };
        if needs_refresh {
            let p = crate::linalg::top_r_left_subspace(&g_short, r, &mut self.rng);
            match self.states.get_mut(&idx) {
                Some(s) => s.p = p,
                None => {
                    self.states.insert(
                        idx,
                        LayerState {
                            m: Matrix::zeros(r, gn),
                            v: Matrix::zeros(r, gn),
                            p,
                            t: 0,
                        },
                    );
                }
            }
        }
        let artifact = format!("galore_step_{gm}x{gn}_r{r}");
        let state = self.states.get_mut(&idx).unwrap();
        state.t += 1;
        let w_short = if transposed { w.transpose() } else { w.clone() };
        let t_in = [state.t as f32];
        let la_in = [lr * self.scale];
        let outputs = engine.execute(
            &artifact,
            &[
                Input::F32(&w_short.data),
                Input::F32(&state.m.data),
                Input::F32(&state.v.data),
                Input::F32(&g_short.data),
                Input::F32(&state.p.data),
                Input::F32(&t_in),
                Input::F32(&la_in),
            ],
        )?;
        let w_new = Matrix::from_vec(gm, gn, outputs[0].data.clone());
        state.m = Matrix::from_vec(r, gn, outputs[1].data.clone());
        state.v = Matrix::from_vec(r, gn, outputs[2].data.clone());
        *w = if transposed { w_new.transpose() } else { w_new };
        Ok(())
    }
}

fn short_side_first(rows: usize, cols: usize) -> (usize, usize) {
    if rows <= cols {
        (rows, cols)
    } else {
        (cols, rows)
    }
}
