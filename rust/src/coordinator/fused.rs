//! Fused GaLore-Adam hot path: per-layer updates executed through the
//! `galore_step_{m}x{n}_r{r}` AOT artifacts (the Pallas kernels of
//! `python/compile/kernels/galore.py`), with projector refreshes through
//! either the `proj_refresh` artifact or the Rust randomized SVD.
//!
//! Tall gradients (m > n) are handled by transposition on entry/exit, so a
//! model needs artifacts only for its short-side-first shapes — exactly
//! what `aot.py` lowers (§4.2: only the short side is projected).
//!
//! Host-side staging (transposes, weight copies) runs through per-layer
//! reusable buffers and the shared SVD workspace, so the Rust side of a
//! fused step performs no steady-state allocations; the remaining
//! allocator traffic is the PJRT literal marshalling inside `execute`
//! (EXPERIMENTS.md §Perf).

use crate::config::RunConfig;
use crate::linalg::{top_r_left_subspace_into, SvdWorkspace};
use crate::model::ParamStore;
use crate::optim::{subspace_cosine, RefreshGate};
use crate::rng::Rng;
use crate::runtime::{Engine, Input};
use crate::ser;
use crate::tensor::{matmul_at_b_into, Matrix};
use anyhow::{bail, Result};
use std::collections::HashMap;

struct LayerState {
    m: Matrix, // (r, n) compact first moment
    v: Matrix, // (r, n) compact second moment
    p: Matrix, // (m, r) projector
    t: u64,
    /// Reusable staging for Gᵀ / Wᵀ / W' on transposed (tall) layers and
    /// for the short-side gradient copy. Working memory, excluded from
    /// `state_bytes`.
    g_short: Matrix,
    w_short: Matrix,
    /// Staging for the lazy-refresh gate's projected gradient Pᵀ G.
    pg: Matrix,
}

pub struct FusedGaLore {
    rank: usize,
    update_freq: u64,
    scale: f32,
    /// Cosine lazy-refresh gate (shared with the Rust path; the artifact
    /// step itself is untouched — only the host-side SVD is skipped).
    gate: RefreshGate,
    /// Refresh boundaries skipped by the gate, for metrics.
    pub gate_skips: u64,
    /// Per handled parameter: the short-side-first gradient shape and the
    /// effective rank its artifact was lowered for — the shapes every
    /// restored state blob must match (`load_state` cross-checks all of
    /// M, V, *and* P against these; a wrong-shape projector used to slip
    /// through and fail much later as an opaque artifact input-length
    /// error).
    expect: HashMap<usize, (usize, usize, usize)>,
    states: HashMap<usize, LayerState>,
    svd_ws: SvdWorkspace,
    rng: Rng,
}

impl FusedGaLore {
    /// Validate that every target shape has a matching artifact and
    /// pre-compile them.
    pub fn new(
        cfg: &RunConfig,
        params: &ParamStore,
        targets: &[usize],
        engine: &mut Engine,
    ) -> Result<FusedGaLore> {
        if cfg.galore.is_adaptive() {
            bail!(
                "adaptive rank schedules ('{}') run on the Rust path only — the fused \
                 galore_step artifacts are lowered for fixed shapes; drop --fused or \
                 use rank_schedule = \"fixed\"",
                cfg.galore.rank_schedule.label()
            );
        }
        if cfg.galore.projector_quant != crate::optim::ProjectorQuant::F32 {
            bail!(
                "projector_quant = '{}' runs on the Rust path only — the fused step \
                 feeds the artifact an f32 projector, so the int8 store would be \
                 silently ignored; drop --fused or use projector_quant = \"f32\"",
                cfg.galore.projector_quant.label()
            );
        }
        let rank = cfg.galore.rank;
        let mut expect = HashMap::new();
        for &idx in targets {
            let meta = &params.metas[idx];
            let (m, n) = short_side_first(meta.rows, meta.cols);
            let Some(art) = engine.manifest.galore_step_for(m, n, rank) else {
                bail!(
                    "no galore_step artifact for shape {}x{} rank {rank} — \
                     re-run `make artifacts` with matching ranks",
                    m,
                    n
                );
            };
            let name = art.name.clone();
            engine.prepare(&name)?;
            expect.insert(idx, (m, n, rank.min(m)));
        }
        Ok(FusedGaLore {
            rank,
            update_freq: cfg.galore.update_freq,
            scale: cfg.galore.scale,
            gate: cfg.galore.refresh_gate(),
            gate_skips: 0,
            expect,
            states: HashMap::new(),
            svd_ws: SvdWorkspace::new(),
            rng: Rng::new(cfg.seed ^ 0xF05ED),
        })
    }

    pub fn handles(&self, idx: usize) -> bool {
        self.expect.contains_key(&idx)
    }

    /// Checkpoint v2 (`FUSD` section): per-layer compact moments,
    /// projector, and step counter, plus the refresh RNG and gate
    /// counter. Staging buffers are per-step scratch and restart empty.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_rng(out, &self.rng);
        ser::put_u64(out, self.gate_skips);
        let mut idxs: Vec<usize> = self.states.keys().copied().collect();
        idxs.sort_unstable();
        ser::put_u32(out, idxs.len() as u32);
        for idx in idxs {
            let s = &self.states[&idx];
            ser::put_usize(out, idx);
            ser::put_u64(out, s.t);
            ser::put_matrix(out, &s.m);
            ser::put_matrix(out, &s.v);
            ser::put_matrix(out, &s.p);
        }
    }

    pub fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.rng = r.rng()?;
        self.gate_skips = r.u64()?;
        self.states.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let idx = r.usize()?;
            let Some(&want) = self.expect.get(&idx) else {
                return Err(format!(
                    "fused state for parameter {idx}, which this run's artifact set \
                     does not handle"
                ));
            };
            let t = r.u64()?;
            let m = r.matrix()?;
            let v = r.matrix()?;
            let p = r.matrix()?;
            check_layer_state(idx, &m, &v, &p, want)?;
            self.states.insert(
                idx,
                LayerState {
                    m,
                    v,
                    p,
                    t,
                    g_short: Matrix::zeros(0, 0),
                    w_short: Matrix::zeros(0, 0),
                    pg: Matrix::zeros(0, 0),
                },
            );
        }
        Ok(())
    }

    pub fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| 4 * (s.m.len() + s.v.len() + s.p.len()))
            .sum()
    }

    /// One fused step on parameter `idx`.
    pub fn step(
        &mut self,
        engine: &mut Engine,
        idx: usize,
        w: &mut Matrix,
        grad: &Matrix,
        lr: f32,
    ) -> Result<()> {
        let transposed = grad.rows > grad.cols;
        let (gm, gn) = short_side_first(grad.rows, grad.cols);
        let r = self.rank.min(gm);
        let state = self.states.entry(idx).or_insert_with(|| LayerState {
            m: Matrix::zeros(r, gn),
            v: Matrix::zeros(r, gn),
            p: Matrix::zeros(0, 0),
            t: 0,
            g_short: Matrix::zeros(0, 0),
            w_short: Matrix::zeros(0, 0),
            pg: Matrix::zeros(0, 0),
        });
        // Refresh the projector every T steps (Rust randomized SVD keeps
        // the refresh off the per-step path; an artifact-based refresh is
        // available via `proj_refresh_*` for benchmarking). t == 0 right
        // after creation, so the first step always refreshes.
        let needs_refresh = state.t % self.update_freq == 0;
        state.t += 1;
        if transposed {
            grad.transpose_into(&mut state.g_short);
        }
        if needs_refresh {
            let g_src = if transposed { &state.g_short } else { grad };
            // Lazy-refresh gate (same semantics as the Rust path): skip
            // the SVD when the cached basis still captures the gradient.
            let mut skip = false;
            if self.gate.enabled() && !state.p.is_empty() {
                matmul_at_b_into(&state.p, g_src, &mut state.pg);
                let cos =
                    subspace_cosine(state.pg.frobenius_norm(), g_src.frobenius_norm());
                if self.gate.fires(cos) {
                    skip = true;
                    self.gate_skips += 1;
                }
            }
            if !skip {
                top_r_left_subspace_into(g_src, r, &mut self.rng, &mut self.svd_ws, &mut state.p);
            }
        }
        let g_data: &[f32] = if transposed { &state.g_short.data } else { &grad.data };
        let w_data: &[f32] = if transposed {
            w.transpose_into(&mut state.w_short);
            &state.w_short.data
        } else {
            &w.data
        };
        let artifact = format!("galore_step_{gm}x{gn}_r{r}");
        let t_in = [state.t as f32];
        let la_in = [lr * self.scale];
        let outputs = engine.execute(
            &artifact,
            &[
                Input::F32(w_data),
                Input::F32(&state.m.data),
                Input::F32(&state.v.data),
                Input::F32(g_data),
                Input::F32(&state.p.data),
                Input::F32(&t_in),
                Input::F32(&la_in),
            ],
        )?;
        if transposed {
            // Stage W' short-side-first, then transpose back into the
            // original (tall) weight layout.
            state.w_short.resize(gm, gn);
            state.w_short.data.copy_from_slice(&outputs[0].data);
            state.w_short.transpose_into(w);
        } else {
            w.data.copy_from_slice(&outputs[0].data);
        }
        state.m.data.copy_from_slice(&outputs[1].data);
        state.v.data.copy_from_slice(&outputs[2].data);
        Ok(())
    }
}

fn short_side_first(rows: usize, cols: usize) -> (usize, usize) {
    if rows <= cols {
        (rows, cols)
    } else {
        (cols, rows)
    }
}

/// Cross-check one restored fused layer state against the shapes this
/// run's artifacts were lowered for: compact moments `(r, n)` and
/// projector `(m, r)` with `(m, n, r)` the expected short-side-first
/// shape and effective rank. Every mismatch is named here at restore
/// time; the old check compared M against V only, so a wrong-shape or
/// wrong-rank projector surfaced much later as an opaque artifact
/// input-length error mid-run.
fn check_layer_state(
    idx: usize,
    m: &Matrix,
    v: &Matrix,
    p: &Matrix,
    (gm, gn, r): (usize, usize, usize),
) -> Result<(), String> {
    if m.shape() != (r, gn) {
        return Err(format!(
            "fused param {idx}: M shape {:?} does not match this run's compact shape \
             ({r}, {gn}) — checkpoint from a different rank or model?",
            m.shape()
        ));
    }
    if v.shape() != (r, gn) {
        return Err(format!(
            "fused param {idx}: V shape {:?} does not match this run's compact shape \
             ({r}, {gn})",
            v.shape()
        ));
    }
    if p.shape() != (gm, r) {
        return Err(format!(
            "fused param {idx}: projector shape {:?} does not match this run's \
             ({gm}, {r}) — the galore_step_{gm}x{gn}_r{r} artifact would reject it \
             as an input-length mismatch mid-run",
            p.shape()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_state_shape_checks_name_every_mismatch() {
        let want = (16usize, 64usize, 4usize); // (m, n, r)
        let good_m = Matrix::zeros(4, 64);
        let good_v = Matrix::zeros(4, 64);
        let good_p = Matrix::zeros(16, 4);
        assert!(check_layer_state(0, &good_m, &good_v, &good_p, want).is_ok());
        // Wrong-rank projector: the case that used to slip through (only
        // M/V were cross-checked) and die later inside the artifact call.
        let bad_p = Matrix::zeros(16, 8);
        let err = check_layer_state(3, &good_m, &good_v, &bad_p, want).unwrap_err();
        assert!(err.contains("projector"), "{err}");
        assert!(err.contains("param 3"), "{err}");
        // Wrong-shape moments are still rejected, now against the run's
        // expected shape rather than merely against each other.
        let bad_m = Matrix::zeros(8, 64);
        let err = check_layer_state(1, &bad_m, &good_v, &good_p, want).unwrap_err();
        assert!(err.contains("M shape"), "{err}");
        let bad_v = Matrix::zeros(4, 32);
        let err = check_layer_state(2, &good_m, &bad_v, &good_p, want).unwrap_err();
        assert!(err.contains("V shape"), "{err}");
        // A transposed projector (n×r instead of m×r) is caught too.
        let transposed_p = Matrix::zeros(4, 16);
        assert!(check_layer_state(0, &good_m, &good_v, &transposed_p, want).is_err());
    }
}
