//! Thin artifact-discovery/validation helper for the fused GaLore step.
//!
//! The standalone `FusedGaLore` optimizer that used to live here was
//! retired by the `StepBackend` redesign: the fused Pallas/HLO path is now
//! [`ArtifactBackend`](crate::optim::backend::ArtifactBackend) — a
//! pluggable execution substrate inside the one `GaLore<O>` optimizer —
//! so data parallelism (`dp_compress` included), rank schedules, the
//! cosine refresh gate, and checkpoint v2 compose with the fused kernels
//! through the ordinary [`Optimizer`](crate::optim::Optimizer) surface
//! instead of a parallel implementation. What remains here is the
//! coordinator-side glue: resolve the run's projection-target shapes from
//! the model schema, stand up the backend-owned PJRT engine, and let the
//! backend validate/pre-compile every `galore_step_{m}x{n}_r{r}` artifact
//! before the first step.

use crate::config::RunConfig;
use crate::model::schema;
use crate::optim::ArtifactBackend;
use crate::runtime::Engine;
use anyhow::{anyhow, Result};

/// The short-side-first shapes of a run's projection targets — the shapes
/// the artifact set must cover (tall layers are handled by transposition,
/// so only `m ≤ n` shapes are ever lowered; §4.2).
pub fn target_shapes(cfg: &RunConfig) -> Vec<(usize, usize)> {
    schema(cfg.model)
        .into_iter()
        .filter(|meta| meta.is_projection_target())
        .map(|meta| (meta.rows, meta.cols))
        .collect()
}

/// Build the artifact step backend for a run: its own engine on the run's
/// artifact directory (`cfg.artifact_dir`, falling back to
/// `GALORE_ARTIFACTS`/./artifacts), validated against every
/// projection-target shape at the configured rank. Fails fast — a missing
/// artifact or a broken manifest surfaces here, at construction, not
/// mid-run.
pub fn build_artifact_backend(cfg: &RunConfig) -> Result<ArtifactBackend> {
    build_artifact_backend_with(cfg, Engine::new(cfg.artifacts_dir())?)
}

/// [`build_artifact_backend`] on a caller-supplied engine handle — pass
/// `engine.share()` to have the backend reuse an existing compiled-
/// executable cache (the trainer shares its engine this way; the serve
/// scheduler shares one cache across every job on the same artifact dir).
pub fn build_artifact_backend_with(cfg: &RunConfig, engine: Engine) -> Result<ArtifactBackend> {
    let shapes = target_shapes(cfg);
    ArtifactBackend::new(engine, cfg.galore.rank, &shapes).map_err(|e| anyhow!(e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MethodKind;
    use crate::model::ModelConfig;

    #[test]
    fn target_shapes_cover_projection_targets_only() {
        let cfg = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let shapes = target_shapes(&cfg);
        assert!(!shapes.is_empty(), "nano has attention/FFN targets");
        let metas = schema(cfg.model);
        let n_targets = metas.iter().filter(|m| m.is_projection_target()).count();
        assert_eq!(shapes.len(), n_targets);
        // Every shape is a real 2-D matrix (vectors are never targeted).
        assert!(shapes.iter().all(|&(r, c)| r > 1 && c > 1));
    }
}
