//! The training loop: batches in, gradients from the AOT artifact,
//! optimizer updates out — with §4.3 per-layer weight updates and the
//! paper's full method roster.

use super::fused::FusedGaLore;
use super::metrics::Metrics;
use super::schedule::LrSchedule;
use crate::config::{MethodKind, RunConfig};
use crate::data::{Batch, DataLoader, SyntheticCorpus};
use crate::lowrank::{Factorized, Lora, LoraConfig, ReLora};
use crate::model::{init_params, ParamStore};
use crate::optim::{Adafactor, Adam, Adam8bit, GaLore, Optimizer};
use crate::runtime::{default_dir, Engine, Input};
use crate::tensor::Matrix;
use anyhow::{bail, Context, Result};

/// Build the optimizer for a run. `targets` are the schema indices of the
/// attention/FFN projections (§5.1's low-rank target set).
pub fn build_optimizer(cfg: &RunConfig, targets: &[usize]) -> Box<dyn Optimizer> {
    let t = targets.iter().copied();
    match cfg.method {
        MethodKind::FullRank => Box::new(Adam::default_paper()),
        MethodKind::AdamW => Box::new(Adam::adamw(cfg.weight_decay.max(0.01))),
        MethodKind::Adam8bit => Box::new(Adam8bit::new()),
        MethodKind::Adafactor => Box::new(Adafactor::new()),
        MethodKind::GaLore => Box::new(GaLore::new(cfg.galore, Adam::default_paper()).with_targets(t)),
        MethodKind::GaLore8bit => Box::new(GaLore::new(cfg.galore, Adam8bit::new()).with_targets(t)),
        MethodKind::GaLoreAdafactor => {
            Box::new(GaLore::new(cfg.galore, Adafactor::new()).with_targets(t))
        }
        MethodKind::Lora => Box::new(
            Lora::new(LoraConfig { rank: cfg.lowrank_rank, alpha: 32.0 }).with_targets(t),
        ),
        MethodKind::ReLora => Box::new(
            ReLora::new(
                LoraConfig { rank: cfg.lowrank_rank, alpha: 32.0 },
                cfg.relora_merge_every,
            )
            .with_targets(t),
        ),
        MethodKind::LowRank => Box::new(Factorized::new(cfg.lowrank_rank).with_targets(t)),
    }
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub engine: Engine,
    pub params: ParamStore,
    pub opt: Box<dyn Optimizer>,
    pub loader: DataLoader,
    pub schedule: LrSchedule,
    pub metrics: Metrics,
    pub step: usize,
    /// Peak bytes of gradient tensors held simultaneously (layerwise
    /// accounting — the quantity Fig. 1 calls "weight gradients").
    pub peak_grad_bytes: usize,
    /// Optional fused HLO hot path for GaLore-Adam (uses the Pallas-kernel
    /// artifacts instead of the Rust-side optimizer).
    fused: Option<FusedGaLore>,
}

impl Trainer {
    /// Assemble a trainer from a run config, a ready Engine and a loader.
    pub fn new(cfg: RunConfig, engine: Engine, loader: DataLoader) -> Result<Trainer> {
        let params = init_params(cfg.model, cfg.seed);
        let targets = params.projection_targets();
        let opt = build_optimizer(&cfg, &targets);
        let schedule = LrSchedule::cosine(cfg.lr, cfg.steps, cfg.warmup_frac, cfg.final_lr_frac);
        Ok(Trainer {
            cfg,
            engine,
            params,
            opt,
            loader,
            schedule,
            metrics: Metrics::new(),
            step: 0,
            peak_grad_bytes: 0,
            fused: None,
        })
    }

    /// Standard construction: artifacts from `GALORE_ARTIFACTS`/./artifacts,
    /// synthetic corpus sized to the model's vocab.
    pub fn from_config(cfg: RunConfig) -> Result<Trainer> {
        let engine = Engine::new(default_dir())?;
        let corpus = SyntheticCorpus::new(cfg.model.vocab, cfg.seed ^ 0xDA7A);
        let loader = DataLoader::synthetic(corpus, cfg.batch, cfg.model.seq);
        Self::new(cfg, engine, loader)
    }

    /// Switch the GaLore update onto the fused Pallas/HLO artifacts
    /// (errors if the run is not a GaLore-Adam run or the artifact set
    /// lacks this shape/rank).
    pub fn enable_fused_galore(&mut self) -> Result<()> {
        if self.cfg.method != MethodKind::GaLore {
            bail!("fused path implements GaLore-Adam (method is {:?})", self.cfg.method);
        }
        let targets = self.params.projection_targets();
        let fused = FusedGaLore::new(&self.cfg, &self.params, &targets, &mut self.engine)?;
        self.fused = Some(fused);
        Ok(())
    }

    pub fn is_fused(&self) -> bool {
        self.fused.is_some()
    }

    /// Execute the training artifact on a batch: (loss, grads in schema
    /// order).
    pub fn compute_grads(&mut self, batch: &Batch) -> Result<(f32, Vec<Matrix>)> {
        let artifact = self.cfg.train_artifact();
        let mut inputs: Vec<Input> = Vec::with_capacity(self.params.len() + 2);
        for t in &self.params.tensors {
            inputs.push(Input::F32(&t.data));
        }
        inputs.push(Input::I32(&batch.tokens));
        inputs.push(Input::I32(&batch.targets));
        let t0 = std::time::Instant::now();
        let outputs = self
            .engine
            .execute(&artifact, &inputs)
            .with_context(|| format!("executing {artifact}"))?;
        self.metrics.exec_time += t0.elapsed();
        let loss = outputs[0].scalar();
        let grads: Vec<Matrix> = outputs[1..]
            .iter()
            .zip(self.params.metas.iter())
            .map(|(o, meta)| Matrix::from_vec(meta.rows, meta.cols, o.data.clone()))
            .collect();
        Ok((loss, grads))
    }

    /// Apply optimizer updates. Under §4.3 layerwise mode each gradient is
    /// consumed and dropped immediately (peak grad memory = one layer);
    /// otherwise all gradients are held until every update has been applied
    /// (the conventional "optimizer.step() after backward" pattern).
    pub fn apply_updates(&mut self, grads: Vec<Matrix>, lr: f32) {
        let total_bytes: usize = grads.iter().map(|g| 4 * g.len()).sum();
        if self.cfg.layerwise {
            let mut peak_single = 0usize;
            // Reverse schema order ≈ backprop arrival order.
            for (idx, grad) in grads.into_iter().enumerate().rev() {
                peak_single = peak_single.max(4 * grad.len());
                self.update_one(idx, &grad, lr);
                drop(grad); // freed before the next layer's update
            }
            self.peak_grad_bytes = self.peak_grad_bytes.max(peak_single);
        } else {
            for (idx, grad) in grads.iter().enumerate() {
                self.update_one(idx, grad, lr);
            }
            self.peak_grad_bytes = self.peak_grad_bytes.max(total_bytes);
        }
    }

    fn update_one(&mut self, idx: usize, grad: &Matrix, lr: f32) {
        if let Some(fused) = &mut self.fused {
            if fused.handles(idx) {
                fused
                    .step(&mut self.engine, idx, &mut self.params.tensors[idx], grad, lr)
                    .expect("fused galore step failed");
                return;
            }
        }
        self.opt.step(idx, &mut self.params.tensors[idx], grad, lr);
    }

    /// One full training step. Returns the batch loss.
    pub fn train_step(&mut self) -> Result<f32> {
        self.train_step_accum(1)
    }

    /// One optimizer step over `microbatches` accumulated gradient
    /// computations (token batch = microbatches × batch × seq, the way the
    /// paper reaches its 131K-token batches on fixed-shape artifacts).
    pub fn train_step_accum(&mut self, microbatches: usize) -> Result<f32> {
        assert!(microbatches >= 1);
        let mut acc: Option<Vec<Matrix>> = None;
        let mut loss_sum = 0.0f64;
        let mut tokens = 0usize;
        for _ in 0..microbatches {
            let batch = self.loader.next_batch();
            tokens += batch.n_tokens();
            let (loss, grads) = self.compute_grads(&batch)?;
            loss_sum += loss as f64;
            match &mut acc {
                None => acc = Some(grads),
                Some(acc) => {
                    for (a, g) in acc.iter_mut().zip(grads.iter()) {
                        a.add_assign(g);
                    }
                }
            }
        }
        let mut grads = acc.unwrap();
        if microbatches > 1 {
            let inv = 1.0 / microbatches as f32;
            for g in grads.iter_mut() {
                g.scale(inv);
            }
        }
        let loss = (loss_sum / microbatches as f64) as f32;
        let lr = self.schedule.at(self.step);
        self.apply_updates(grads, lr);
        self.metrics.log_step(self.step, loss, lr, tokens);
        self.step += 1;
        Ok(loss)
    }

    /// Mean eval loss over `n_batches` held-out batches.
    pub fn eval(&mut self, n_batches: usize) -> Result<f32> {
        let artifact = self.cfg.eval_artifact();
        let mut total = 0.0f64;
        for i in 0..n_batches {
            let batch = self.loader.eval_batch(i as u64);
            let mut inputs: Vec<Input> = Vec::with_capacity(self.params.len() + 2);
            for t in &self.params.tensors {
                inputs.push(Input::F32(&t.data));
            }
            inputs.push(Input::I32(&batch.tokens));
            inputs.push(Input::I32(&batch.targets));
            let outputs = self.engine.execute(&artifact, &inputs)?;
            total += outputs[0].scalar() as f64;
        }
        Ok((total / n_batches as f64) as f32)
    }

    /// Run the configured number of steps with periodic eval.
    pub fn run(&mut self) -> Result<()> {
        for _ in self.step..self.cfg.steps {
            self.train_step()?;
            if self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0 {
                let l = self.eval(2)?;
                self.metrics.log_eval(self.step, l);
            }
        }
        let l = self.eval(4)?;
        self.metrics.log_eval(self.step, l);
        Ok(())
    }

    /// Optimizer-state bytes currently held (checked against the
    /// `memory::formulas` predictions by the integration tests).
    pub fn optimizer_state_bytes(&self) -> usize {
        self.opt.state_bytes() + self.fused.as_ref().map_or(0, |f| f.state_bytes())
    }
}
