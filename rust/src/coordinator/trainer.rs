//! The training loop: batches in, gradients from the AOT artifact,
//! optimizer updates out — with §4.3 per-layer weight updates and the
//! paper's full method roster.

use super::checkpoint;
use super::fused::{build_artifact_backend, build_artifact_backend_with};
use super::metrics::{thread_alloc_stats, Metrics};
use super::schedule::LrSchedule;
use crate::config::{BackendKind, MethodKind, RunConfig};
use crate::data::{Batch, DataLoader, SyntheticCorpus};
use crate::lowrank::{Factorized, Lora, LoraConfig, ReLora};
use crate::model::{init_params, ParamMeta, ParamStore};
use crate::optim::{Adafactor, Adam, Adam8bit, GaLore, Optimizer};
use crate::runtime::{pool, Engine, Input, InputStage, Output};
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Context, Result};

/// Build the optimizer for a run. `targets` are the schema indices of the
/// attention/FFN projections (§5.1's low-rank target set). Stochastic
/// optimizer internals (projector sketches, adaptor inits) are seeded from
/// `cfg.seed` so runs are reproducible end to end.
///
/// `cfg.backend` selects the GaLore step backend here, at construction —
/// the only place "fused" exists anymore. `BackendKind::Artifact` stands
/// up a backend-owned PJRT engine and validates every target-shape
/// artifact (fallible: hence the `Result`); everything downstream — the
/// trainer's step/checkpoint paths, the DP worker loop — is
/// backend-agnostic.
pub fn build_optimizer(cfg: &RunConfig, targets: &[usize]) -> Result<Box<dyn Optimizer>> {
    build_optimizer_with(cfg, targets, None)
}

/// [`build_optimizer`] with an optional engine to share: when `engine` is
/// `Some`, `BackendKind::Artifact` attaches a backend that shares the
/// caller's compiled-executable cache (one PJRT client, one cache) instead
/// of standing up its own — the serve scheduler uses this so K jobs with
/// identical layer shapes compile each `galore_step_{m}x{n}_r{r}` kernel
/// once.
pub fn build_optimizer_with(
    cfg: &RunConfig,
    targets: &[usize],
    engine: Option<&Engine>,
) -> Result<Box<dyn Optimizer>> {
    // The artifact backend exists for exactly one method — GaLore-Adam,
    // what its kernels implement. Guarded here for *every* other method
    // (also enforced by `RunConfig::validate`; repeated because benches
    // and tests call `build_optimizer` with hand-rolled configs, and a
    // silently ignored backend would read as a fused run that wasn't).
    if cfg.backend == BackendKind::Artifact && cfg.method != MethodKind::GaLore {
        bail!(
            "backend 'artifact' drives the fused GaLore-Adam kernels; method '{}' \
             runs on the rust backend only",
            cfg.method.label()
        );
    }
    let t = targets.iter().copied();
    Ok(match cfg.method {
        MethodKind::FullRank => Box::new(Adam::default_paper()),
        MethodKind::AdamW => Box::new(Adam::adamw(cfg.weight_decay.max(0.01))),
        MethodKind::Adam8bit => Box::new(Adam8bit::new()),
        MethodKind::Adafactor => Box::new(Adafactor::new()),
        MethodKind::GaLore => {
            let mut g = GaLore::new(cfg.galore, Adam::default_paper())
                .with_targets(t)
                .with_seed(cfg.seed);
            if cfg.backend == BackendKind::Artifact {
                let backend = match engine {
                    Some(e) => build_artifact_backend_with(cfg, e.share())?,
                    None => build_artifact_backend(cfg)?,
                };
                g = g.with_backend(Box::new(backend));
            }
            Box::new(g)
        }
        MethodKind::GaLore8bit => Box::new(
            GaLore::new(cfg.galore, Adam8bit::new()).with_targets(t).with_seed(cfg.seed),
        ),
        MethodKind::GaLoreAdafactor => Box::new(
            GaLore::new(cfg.galore, Adafactor::new()).with_targets(t).with_seed(cfg.seed),
        ),
        MethodKind::Lora => Box::new(
            Lora::new(LoraConfig { rank: cfg.lowrank_rank, alpha: 32.0 })
                .with_targets(t)
                .with_seed(cfg.seed),
        ),
        MethodKind::ReLora => Box::new(
            ReLora::new(
                LoraConfig { rank: cfg.lowrank_rank, alpha: 32.0 },
                cfg.relora_merge_every,
            )
            .with_targets(t)
            .with_seed(cfg.seed),
        ),
        MethodKind::LowRank => {
            Box::new(Factorized::new(cfg.lowrank_rank).with_targets(t).with_seed(cfg.seed))
        }
    })
}

/// Copy artifact outputs into persistent gradient buffers, allocating the
/// buffers only on first use (thereafter a plain memcpy per tensor —
/// EXPERIMENTS.md §Perf). Shape agreement between the artifact outputs
/// and the parameter schema is a *real* error, not a `debug_assert`: a
/// release-mode artifact/schema mismatch used to copy misaligned
/// gradients silently.
fn stage_grads(outputs: &[Output], metas: &[ParamMeta], bufs: &mut Vec<Matrix>) -> Result<()> {
    if outputs.len() != metas.len() {
        bail!(
            "artifact returned {} gradient tensors, parameter schema has {} — \
             artifact set and model schema disagree (re-run `make artifacts`?)",
            outputs.len(),
            metas.len()
        );
    }
    if bufs.is_empty() {
        for (o, meta) in outputs.iter().zip(metas.iter()) {
            if o.data.len() != meta.numel() {
                bail!(
                    "gradient for '{}' has {} elements, schema says {}x{}",
                    meta.name,
                    o.data.len(),
                    meta.rows,
                    meta.cols
                );
            }
            bufs.push(Matrix::from_vec(meta.rows, meta.cols, o.data.clone()));
        }
        return Ok(());
    }
    for ((b, o), meta) in bufs.iter_mut().zip(outputs.iter()).zip(metas.iter()) {
        if b.len() != o.data.len() {
            bail!(
                "gradient for '{}' has {} elements, staged buffer holds {}",
                meta.name,
                o.data.len(),
                b.len()
            );
        }
        b.data.copy_from_slice(&o.data);
    }
    Ok(())
}

pub struct Trainer {
    pub cfg: RunConfig,
    pub engine: Engine,
    pub params: ParamStore,
    pub opt: Box<dyn Optimizer>,
    pub loader: DataLoader,
    pub schedule: LrSchedule,
    pub metrics: Metrics,
    pub step: usize,
    /// Peak bytes of gradient tensors held simultaneously (layerwise
    /// accounting — the quantity Fig. 1 calls "weight gradients").
    pub peak_grad_bytes: usize,
    /// Persistent gradient buffers, reused across `compute_grads` calls
    /// (schema order). Working memory; the §4.3 peak-gradient *accounting*
    /// still models layerwise consumption via `peak_grad_bytes`.
    pub(crate) grad_bufs: Vec<Matrix>,
    /// Staging buffers for gradient accumulation (microbatch > 1 only).
    mb_bufs: Vec<Matrix>,
    /// Persistent artifact-input staging (the `Vec<Input>` the train and
    /// eval paths used to rebuild every call). Working memory.
    input_stage: InputStage,
    /// Filename prefix for periodic checkpoints (default `"step_"`).
    /// Retention (`checkpoint::prune`) sweeps only files under this
    /// prefix, so jobs sharing one `checkpoint_dir` set distinct prefixes
    /// (`job{id}_step_`) and never delete each other's files.
    pub checkpoint_prefix: String,
}

impl Trainer {
    /// Assemble a trainer from a run config, a ready Engine and a loader.
    pub fn new(cfg: RunConfig, engine: Engine, loader: DataLoader) -> Result<Trainer> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        // `threads = 0` means auto: leave the pool at its
        // `GALORE_THREADS`/`available_parallelism` default.
        if cfg.threads > 0 {
            pool::configure(cfg.threads);
        }
        let mut params = init_params(cfg.model, cfg.seed);
        params.seed_rounding(cfg.seed);
        params.set_precision(cfg.weight_precision);
        let targets = params.projection_targets();
        // Share this trainer's engine with the optimizer backend so a
        // trainer and its artifact backend hold ONE compiled cache.
        let opt = build_optimizer_with(&cfg, &targets, Some(&engine))?;
        let schedule = LrSchedule::cosine(cfg.lr, cfg.steps, cfg.warmup_frac, cfg.final_lr_frac);
        Ok(Trainer {
            cfg,
            engine,
            params,
            opt,
            loader,
            schedule,
            metrics: Metrics::new(),
            step: 0,
            peak_grad_bytes: 0,
            grad_bufs: Vec::new(),
            mb_bufs: Vec::new(),
            input_stage: InputStage::new(),
            checkpoint_prefix: "step_".into(),
        })
    }

    /// Standard construction: artifacts from `cfg.artifact_dir` (falling
    /// back to `GALORE_ARTIFACTS`/./artifacts), synthetic corpus sized to
    /// the model's vocab.
    pub fn from_config(cfg: RunConfig) -> Result<Trainer> {
        let engine = Engine::new(cfg.artifacts_dir())?;
        let corpus = SyntheticCorpus::new(cfg.model.vocab, cfg.seed ^ 0xDA7A);
        let loader = DataLoader::synthetic(corpus, cfg.batch, cfg.model.seq);
        Self::new(cfg, engine, loader)
    }

    /// Execute the training artifact on a batch, staging gradients into the
    /// trainer's persistent buffers (schema order, no per-step `Matrix`
    /// allocation). Returns the batch loss; read gradients from
    /// `grad_bufs` / [`Trainer::apply_updates`].
    pub fn compute_grads_into(&mut self, batch: &Batch) -> Result<f32> {
        self.compute_grads_to(batch, false)
    }

    fn compute_grads_to(&mut self, batch: &Batch, staging: bool) -> Result<f32> {
        let artifact = self.cfg.train_artifact();
        let mut inputs = self.input_stage.begin();
        for t in &self.params.tensors {
            inputs.push(Input::F32(&t.data));
        }
        inputs.push(Input::I32(&batch.tokens));
        inputs.push(Input::I32(&batch.targets));
        let t0 = std::time::Instant::now();
        let outputs = self
            .engine
            .execute(&artifact, &inputs)
            .with_context(|| format!("executing {artifact}"));
        // The guard clears the stage on drop — including when `outputs`
        // is an error and the `?` below returns early.
        drop(inputs);
        let outputs = outputs?;
        self.metrics.exec_time += t0.elapsed();
        let loss = outputs[0].scalar();
        let bufs = if staging { &mut self.mb_bufs } else { &mut self.grad_bufs };
        stage_grads(&outputs[1..], &self.params.metas, bufs)?;
        Ok(loss)
    }

    /// Execute the training artifact on a batch: (loss, grads in schema
    /// order). Allocating convenience wrapper over
    /// [`Trainer::compute_grads_into`] — the training loop itself uses the
    /// buffer path.
    pub fn compute_grads(&mut self, batch: &Batch) -> Result<(f32, Vec<Matrix>)> {
        let loss = self.compute_grads_to(batch, false)?;
        Ok((loss, self.grad_bufs.clone()))
    }

    /// Apply optimizer updates. Under §4.3 layerwise mode each gradient is
    /// modeled as consumed immediately (peak grad accounting = one layer);
    /// otherwise all gradients are held until every update has been applied
    /// (the conventional "optimizer.step() after backward" pattern). The
    /// gradient buffers themselves are persistent workspace either way —
    /// note the *actual* resident peak has always been all-layers on this
    /// substrate (the training artifact returns every gradient at once;
    /// the seed also materialized the full set before dropping layer by
    /// layer), so `peak_grad_bytes` is the accelerator-memory *model* of
    /// layerwise backprop, not a measurement of host RSS.
    pub fn apply_updates(&mut self, grads: &[Matrix], lr: f32) -> Result<()> {
        self.apply_updates_inner(grads, None, lr)
    }

    /// Apply updates under a data-parallel communication plan
    /// (`coordinator::parallel::exchange_grads`): parameters the plan
    /// reduced in full take the normal `Trainer::update_one` path;
    /// compact-reduced parameters feed their averaged `Pᵀ G` straight
    /// into `Optimizer::step_compact`. Backend-agnostic: the artifact
    /// backend's compact entry runs the shared Rust tail against the same
    /// moments, so `dp_compress` composes with `--backend artifact`.
    /// Peak-gradient accounting is unchanged — the full gradient was
    /// materialized locally before projection either way.
    pub fn apply_updates_planned(
        &mut self,
        grads: &[Matrix],
        plan: &[crate::optim::GradReduceMode],
        compact: &[Matrix],
        lr: f32,
    ) -> Result<()> {
        if plan.len() != grads.len() || compact.len() < grads.len() {
            bail!(
                "communication plan covers {} of {} parameters ({} compact buffers)",
                plan.len(),
                grads.len(),
                compact.len()
            );
        }
        self.apply_updates_inner(grads, Some((plan, compact)), lr)
    }

    /// Apply one reduced bucket of a data-parallel overlapped exchange:
    /// step parameters `[start, start + grads.len())` under the bucket's
    /// slice of the communication plan, via [`Optimizer::step_planned`]
    /// (bit-identical to the sequential planned walk; GaLore steps the
    /// bucket's layers in parallel on the worker pool). Does **not**
    /// `commit()` the bf16 weight store — the caller commits once after
    /// the step's last bucket, like the barrier walk.
    pub(crate) fn apply_bucket(
        &mut self,
        start: usize,
        grads: &[Matrix],
        plan: &[crate::optim::GradReduceMode],
        compact: &[Matrix],
        lr: f32,
    ) -> Result<()> {
        let end = start + grads.len();
        if end > self.params.tensors.len() {
            bail!(
                "bucket [{start}..{end}) exceeds the {}-parameter schema",
                self.params.tensors.len()
            );
        }
        let weights = &mut self.params.tensors[start..end];
        self.opt
            .step_planned(start, weights, grads, plan, compact, lr)
            .map_err(|e| anyhow!("optimizer step failed in bucket [{start}..{end}): {e}"))
    }

    /// Shared update walk: §4.3 layerwise / dense ordering and the
    /// peak-gradient accounting live here once; the optional plan swaps
    /// compact-reduced parameters onto `Optimizer::step_compact`.
    fn apply_updates_inner(
        &mut self,
        grads: &[Matrix],
        planned: Option<(&[crate::optim::GradReduceMode], &[Matrix])>,
        lr: f32,
    ) -> Result<()> {
        use crate::optim::GradReduceMode;
        let one = |this: &mut Self, idx: usize| -> Result<()> {
            if let Some((plan, compact)) = planned {
                if matches!(plan[idx], GradReduceMode::Compact { .. }) {
                    return this
                        .opt
                        .step_compact(idx, &mut this.params.tensors[idx], &compact[idx], lr)
                        .map_err(|e| {
                            anyhow!(
                                "compact optimizer step failed on parameter {idx} ('{}'): {e}",
                                this.params.metas[idx].name
                            )
                        });
                }
            }
            this.update_one(idx, &grads[idx], lr)
        };
        let total_bytes: usize = grads.iter().map(|g| 4 * g.len()).sum();
        if self.cfg.layerwise {
            let mut peak_single = 0usize;
            // Reverse schema order ≈ backprop arrival order (and the
            // one-layer-at-a-time semantics §4.3 models — inherently
            // sequential, so no cross-layer dispatch here).
            for idx in (0..grads.len()).rev() {
                peak_single = peak_single.max(4 * grads[idx].len());
                one(self, idx)?;
            }
            self.peak_grad_bytes = self.peak_grad_bytes.max(peak_single);
        } else if planned.is_some() {
            for idx in 0..grads.len() {
                one(self, idx)?;
            }
            self.peak_grad_bytes = self.peak_grad_bytes.max(total_bytes);
        } else {
            // Dense path: step whole layers in parallel across the worker
            // pool (`Optimizer::step_many` — bit-identical to this loop
            // run sequentially; optimizers without a parallel plan keep
            // the sequential default).
            self.opt
                .step_many(&mut self.params.tensors, grads, lr)
                .map_err(|e| anyhow!("optimizer step failed: {e}"))?;
            self.peak_grad_bytes = self.peak_grad_bytes.max(total_bytes);
        }
        // bf16 weight store: round every updated tensor through the
        // master store (no-op at f32 precision). Allocation-free once
        // warm; keeps `working == dequant(store)` as the step invariant.
        self.params.commit();
        Ok(())
    }

    /// Apply one parameter's update. Optimizer failures — including
    /// artifact-backend engine faults — surface as errors, never process
    /// aborts (PR 4's "no `.expect` mid-run" policy; the buffers are
    /// restored by the caller so the trainer stays checkpointable).
    fn update_one(&mut self, idx: usize, grad: &Matrix, lr: f32) -> Result<()> {
        self.opt.step(idx, &mut self.params.tensors[idx], grad, lr).map_err(|e| {
            anyhow!(
                "optimizer step failed on parameter {idx} ('{}'): {e}",
                self.params.metas[idx].name
            )
        })
    }

    /// One full training step. Returns the batch loss.
    pub fn train_step(&mut self) -> Result<f32> {
        self.train_step_accum(1)
    }

    /// One optimizer step over `microbatches` accumulated gradient
    /// computations (token batch = microbatches × batch × seq, the way the
    /// paper reaches its 131K-token batches on fixed-shape artifacts).
    /// Gradients accumulate into the persistent buffers — no per-step
    /// `Matrix` allocation — and the optimizer-update phase is wrapped in
    /// allocation-counter snapshots that feed `metrics.allocs_per_step()`.
    pub fn train_step_accum(&mut self, microbatches: usize) -> Result<f32> {
        assert!(microbatches >= 1);
        let mut loss_sum = 0.0f64;
        let mut tokens = 0usize;
        for mb in 0..microbatches {
            let batch = self.loader.next_batch();
            tokens += batch.n_tokens();
            // First microbatch lands in grad_bufs; the rest stage into
            // mb_bufs and are added on.
            let staging = mb > 0;
            let loss = self.compute_grads_to(&batch, staging)?;
            loss_sum += loss as f64;
            if staging {
                for (a, g) in self.grad_bufs.iter_mut().zip(self.mb_bufs.iter()) {
                    a.add_assign(g);
                }
            }
        }
        if microbatches > 1 {
            let inv = 1.0 / microbatches as f32;
            for g in self.grad_bufs.iter_mut() {
                g.scale(inv);
            }
        }
        let loss = (loss_sum / microbatches as f64) as f32;
        let lr = self.schedule.at(self.step);
        let a0 = thread_alloc_stats();
        // `mem::take` detaches the buffers (no allocation) so the borrow
        // checker allows `&mut self` dispatch while reading them. Restore
        // them before surfacing any update error — the trainer must stay
        // usable (e.g. for a checkpoint) after a failed step.
        let bufs = std::mem::take(&mut self.grad_bufs);
        let applied = self.apply_updates(&bufs, lr);
        self.grad_bufs = bufs;
        applied?;
        let a1 = thread_alloc_stats();
        self.metrics.log_step_allocs(a1.allocs - a0.allocs, a1.bytes - a0.bytes);
        self.metrics.log_step(self.step, loss, lr, tokens);
        self.step += 1;
        Ok(loss)
    }

    /// Mean eval loss over `n_batches` held-out batches.
    pub fn eval(&mut self, n_batches: usize) -> Result<f32> {
        let artifact = self.cfg.eval_artifact();
        let mut total = 0.0f64;
        for i in 0..n_batches {
            let batch = self.loader.eval_batch(i as u64);
            let mut inputs = self.input_stage.begin();
            for t in &self.params.tensors {
                inputs.push(Input::F32(&t.data));
            }
            inputs.push(Input::I32(&batch.tokens));
            inputs.push(Input::I32(&batch.targets));
            let outputs = self.engine.execute(&artifact, &inputs);
            drop(inputs);
            total += outputs?[0].scalar() as f64;
        }
        Ok((total / n_batches as f64) as f32)
    }

    /// Run the configured number of steps with periodic eval and (when
    /// `checkpoint_every` is set) periodic full-state checkpoints with
    /// `checkpoint_keep_last` retention. Resume-aware: starts from
    /// `self.step`, and the in-loop eval skips the final step so the
    /// run's last eval is logged exactly once (the old loop logged a
    /// duplicate row when `steps % eval_every == 0`). Every eval —
    /// in-loop and final — uses the same `cfg.eval_batches` window, so
    /// the eval curve's last point is comparable to the rest (the old
    /// loop evaluated 2 batches in-loop but 4 at the end).
    pub fn run(&mut self) -> Result<()> {
        loop {
            self.run_steps(self.cfg.steps.saturating_sub(self.step).max(1))?;
            if self.step >= self.cfg.steps {
                return Ok(());
            }
        }
    }

    /// Run at most `n` training steps of the configured schedule — the
    /// slice entry point the serve scheduler round-robins jobs on. In-loop
    /// eval and periodic checkpoints fire on exactly the same steps as an
    /// uninterrupted [`Trainer::run`] (eval batches are seeded by index,
    /// not drawn from the training stream, so slicing is bit-exact), and
    /// the final eval is logged once when the last step completes —
    /// regardless of which slice completes it. Returns the number of steps
    /// actually run (0 once the run is finished).
    pub fn run_steps(&mut self, n: usize) -> Result<usize> {
        let mut ran = 0;
        while self.step < self.cfg.steps && ran < n {
            self.train_step()?;
            ran += 1;
            if self.cfg.eval_every > 0
                && self.step % self.cfg.eval_every == 0
                && self.step < self.cfg.steps
            {
                let l = self.eval(self.cfg.eval_batches)?;
                self.metrics.log_eval(self.step, l);
            }
            if self.cfg.checkpoint_every > 0 && self.step % self.cfg.checkpoint_every == 0 {
                self.save_periodic_checkpoint()?;
            }
        }
        if self.step >= self.cfg.steps && !self.final_eval_logged() {
            let l = self.eval(self.cfg.eval_batches)?;
            self.metrics.log_eval(self.step, l);
        }
        Ok(ran)
    }

    /// Whether the end-of-run eval row is already in the metrics — keeps
    /// `run_steps` idempotent after completion (a paused-at-the-end job
    /// that is resumed must not log a second final eval).
    fn final_eval_logged(&self) -> bool {
        self.metrics.eval_records.last().map(|&(s, _)| s >= self.cfg.steps).unwrap_or(false)
    }

    /// Optimizer-state bytes currently held (checked against the
    /// `memory::formulas` predictions by the integration tests). Identical
    /// across step backends: the artifact backend keeps no state of its
    /// own — it writes through the inner optimizer's moments.
    pub fn optimizer_state_bytes(&self) -> usize {
        self.opt.state_bytes()
    }

    /// Write a full-state (v2) checkpoint: weights, step, config
    /// fingerprint, optimizer state (moments, projectors, RNG streams —
    /// the *whole* training state on either step backend, through the one
    /// `Optimizer::save_state`), data-loader position, and metrics
    /// counters. Atomic on disk; bit-exact on resume.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut opt_blob = Vec::new();
        self.opt
            .save_state(&mut opt_blob)
            .map_err(|e| anyhow!("cannot checkpoint optimizer state: {e}"))?;
        let mut loader_blob = Vec::new();
        self.loader.save_state(&mut loader_blob);
        let mut metrics_blob = Vec::new();
        self.metrics.save_state(&mut metrics_blob);
        let mut sections: Vec<(&[u8; 4], &[u8])> = vec![
            (checkpoint::SEC_OPTIMIZER, opt_blob.as_slice()),
            (checkpoint::SEC_LOADER, loader_blob.as_slice()),
            (checkpoint::SEC_METRICS, metrics_blob.as_slice()),
        ];
        // Int8 weight runs additionally snapshot the master store: codes,
        // block scales, and the stochastic-rounding RNG. The saved f32
        // params equal the dequantized store, but re-quantizing on load is
        // neither bit-stable nor (with stochastic rounding) deterministic,
        // so the store itself is part of the training state.
        let mut wstore_blob = Vec::new();
        if self.params.precision() == crate::model::WeightPrecision::Int8 {
            self.params.save_store_state(&mut wstore_blob);
            sections.push((checkpoint::SEC_WSTORE, wstore_blob.as_slice()));
        }
        checkpoint::save_v2(
            path,
            &self.params,
            &self.cfg.fingerprint(),
            self.step as u64,
            &sections,
        )?;
        Ok(())
    }

    /// Periodic checkpoint into `cfg.checkpoint_dir` with retention
    /// (`cfg.checkpoint_keep_last`, 0 = keep all). Filenames — and the
    /// retention sweep — are scoped to this trainer's `checkpoint_prefix`,
    /// so concurrent jobs sharing a directory prune independently.
    pub fn save_periodic_checkpoint(&self) -> Result<()> {
        let dir = std::path::Path::new(&self.cfg.checkpoint_dir);
        self.save_checkpoint(dir.join(checkpoint::periodic_name_with(
            &self.checkpoint_prefix,
            self.step,
        )))?;
        checkpoint::prune(dir, &self.checkpoint_prefix, self.cfg.checkpoint_keep_last)?;
        Ok(())
    }

    /// Restore a checkpoint into this trainer. v2 restores the *entire*
    /// training state and requires the stored config fingerprint to match
    /// this run's (a mismatched config would silently diverge from the
    /// uninterrupted trajectory). v1 checkpoints still load — weights and
    /// step only, with a loud warning that optimizer moments cold-start.
    /// Backend-agnostic: artifact-backend runs save and restore through
    /// the same `OPTS` section as everything else.
    pub fn restore_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        match checkpoint::read(path, self.cfg.model)? {
            checkpoint::Checkpoint::V1 { params, step } => {
                eprintln!(
                    "WARNING: {path:?} is a v1 (weights-only) checkpoint: optimizer \
                     moments, projector bases, and the data-loader position are NOT \
                     restored. The resumed run will cold-start its moments and will \
                     not match an uninterrupted trajectory. Re-save with `galore \
                     train --checkpoint-every N` to get full-state (v2) checkpoints."
                );
                self.params = params;
                self.params.seed_rounding(self.cfg.seed);
                self.params.set_precision(self.cfg.weight_precision);
                self.step = step as usize;
                self.opt.reset_state();
                Ok(())
            }
            checkpoint::Checkpoint::V2(d) => {
                let want = self.cfg.fingerprint();
                if d.fingerprint != want {
                    bail!(
                        "checkpoint config mismatch — resuming would diverge from the \
                         uninterrupted trajectory.\n  checkpoint: {}\n  this run:   {want}",
                        d.fingerprint
                    );
                }
                let opt_bytes = d
                    .section(checkpoint::SEC_OPTIMIZER)
                    .ok_or_else(|| anyhow!("checkpoint is missing its optimizer-state section"))?;
                let loader_bytes = d
                    .section(checkpoint::SEC_LOADER)
                    .ok_or_else(|| anyhow!("checkpoint is missing its data-loader section"))?;
                let metrics_bytes = d
                    .section(checkpoint::SEC_METRICS)
                    .ok_or_else(|| anyhow!("checkpoint is missing its metrics section"))?;
                if d.section(checkpoint::SEC_FUSED).is_some() {
                    // Pre-StepBackend fused checkpoints kept the targeted
                    // layers' moments in a separate FUSD section whose
                    // OPTS blob is incomplete; loading one here would
                    // silently cold-start those moments. (Current fused
                    // runs carry everything in OPTS — this only rejects
                    // files from before the backend redesign.)
                    bail!(
                        "checkpoint carries a legacy fused-path (FUSD) section from \
                         before the step-backend redesign; re-train or re-save it \
                         with this binary — its optimizer section does not contain \
                         the fused layers' moments"
                    );
                }
                let mut r = crate::ser::Reader::new(opt_bytes);
                self.opt.load_state(&mut r).map_err(|e| anyhow!("optimizer state: {e}"))?;
                r.expect_end().map_err(|e| anyhow!("optimizer state: {e}"))?;
                let mut r = crate::ser::Reader::new(loader_bytes);
                self.loader.load_state(&mut r).map_err(|e| anyhow!("data-loader state: {e}"))?;
                r.expect_end().map_err(|e| anyhow!("data-loader state: {e}"))?;
                let mut r = crate::ser::Reader::new(metrics_bytes);
                self.metrics.load_state(&mut r).map_err(|e| anyhow!("metrics state: {e}"))?;
                r.expect_end().map_err(|e| anyhow!("metrics state: {e}"))?;
                // Re-establish the weight store at the configured
                // precision. Exact for a checkpoint written by a bf16 run:
                // its weights are bf16-valued f32s, so the rounding
                // round-trips losslessly and resume stays bit-exact. Int8
                // runs instead install the snapshotted WSTR section —
                // codes, scales, and the stochastic-rounding RNG — since
                // re-quantizing here would fork the rounding stream.
                self.params = d.params;
                if self.cfg.weight_precision == crate::model::WeightPrecision::Int8 {
                    let wstore_bytes = d.section(checkpoint::SEC_WSTORE).ok_or_else(|| {
                        anyhow!(
                            "checkpoint is missing its int8 weight-store section \
                             (was it written by an int8-weights run?)"
                        )
                    })?;
                    let mut r = crate::ser::Reader::new(wstore_bytes);
                    self.params
                        .load_store_state(&mut r)
                        .map_err(|e| anyhow!("int8 weight store: {e}"))?;
                    r.expect_end().map_err(|e| anyhow!("int8 weight store: {e}"))?;
                } else {
                    self.params.seed_rounding(self.cfg.seed);
                    self.params.set_precision(self.cfg.weight_precision);
                }
                self.step = d.step as usize;
                Ok(())
            }
        }
    }

    /// Convenience: build a trainer for `cfg` and restore `path` into it.
    pub fn resume(cfg: RunConfig, path: impl AsRef<std::path::Path>) -> Result<Trainer> {
        let mut trainer = Trainer::from_config(cfg)?;
        trainer.restore_checkpoint(path)?;
        Ok(trainer)
    }
}
