//! Job lifecycle for the resident multi-job service (`galore serve`).
//!
//! A [`Job`] is one training run managed by the serve scheduler:
//!
//! ```text
//! Queued ──admit──▶ Admitted ──run_slice──▶ Running ──▶ Done
//!    ▲                                        │  │
//!    └────────────resume──── Paused ◀──pause──┘  └──▶ Failed
//! ```
//!
//! Residency is the point of the state machine: a job holds weights,
//! optimizer moments and projector bases in RAM only while `Admitted`/
//! `Running`. `pause_evict` serializes the *entire* training state into a
//! v2 checkpoint and drops the runner — a paused job costs disk, not
//! memory — and `admit` restores it bit-exactly, so interleaving,
//! pausing and resuming never changes a loss curve (pinned by
//! `tests/serve_props.rs`).
//!
//! Three workloads share the lifecycle:
//!
//! * [`WorkloadKind::Artifact`] — the ordinary pre-training loop
//!   ([`Trainer`] on the AOT artifact engine, synthetic corpus).
//! * [`WorkloadKind::Finetune`] — `exp/finetune`-style fixed-shard
//!   fine-tuning (same [`Trainer`], `DataLoader::fixed` over a
//!   bigram-knobbed corpus).
//! * [`WorkloadKind::Synthetic`] — a pure-Rust quadratic pull toward a
//!   planted parameter set, driven by the *real* optimizer stack
//!   (`build_optimizer`, LR schedule, bf16 store, checkpoint v2). It
//!   exists so the serve scheduler, admission control and evict/restore
//!   paths are exercisable — and CI-testable — on hosts with no compiled
//!   artifact set, where `Engine::new` cannot succeed.

use super::checkpoint;
use super::metrics::{Metrics, StepRecord};
use super::schedule::LrSchedule;
use super::trainer::{build_optimizer, Trainer};
use crate::config::{BackendKind, RunConfig};
use crate::data::{DataLoader, SyntheticCorpus};
use crate::memory::{estimate, estimate_adaptive, Method, TrainOpts};
use crate::model::{init_params, ParamStore};
use crate::optim::Optimizer;
use crate::runtime::Engine;
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};

/// Lifecycle states. `Queued` and `Paused` jobs are non-resident (no
/// runner, no tensors in RAM); `Admitted`/`Running` jobs hold full
/// training state; `Done`/`Failed` are terminal and non-resident.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Admitted,
    Running,
    Paused,
    Done,
    Failed,
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Admitted => "admitted",
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<JobState, String> {
        Ok(match s {
            "queued" => JobState::Queued,
            "admitted" => JobState::Admitted,
            "running" => JobState::Running,
            "paused" => JobState::Paused,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            other => return Err(format!("unknown job state '{other}'")),
        })
    }

    /// Terminal states never leave via the scheduler.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed)
    }
}

/// What a job trains on. Selected by the submit payload's
/// `[job] workload` key.
#[derive(Clone, Debug, PartialEq)]
pub enum WorkloadKind {
    /// Pre-training on the AOT artifact engine (synthetic corpus).
    Artifact,
    /// Fixed-shard fine-tuning on the artifact engine; `p_bigram` is the
    /// task's corpus structure knob (`exp::finetune`'s roster).
    Finetune { p_bigram: f64 },
    /// Pure-Rust quadratic workload on the real optimizer stack — no
    /// artifact set required.
    Synthetic,
}

impl WorkloadKind {
    pub fn label(&self) -> &'static str {
        match self {
            WorkloadKind::Artifact => "artifact",
            WorkloadKind::Finetune { .. } => "finetune",
            WorkloadKind::Synthetic => "synthetic",
        }
    }

    /// Parse the submit payload's `workload` value; `p_bigram` only
    /// applies to `finetune` (defaulting to 0.7).
    pub fn parse(s: &str, p_bigram: Option<f64>) -> Result<WorkloadKind, String> {
        Ok(match s {
            "artifact" => WorkloadKind::Artifact,
            "finetune" => WorkloadKind::Finetune { p_bigram: p_bigram.unwrap_or(0.7) },
            "synthetic" => WorkloadKind::Synthetic,
            other => {
                return Err(format!(
                    "unknown workload '{other}' (expected synthetic|artifact|finetune)"
                ))
            }
        })
    }
}

/// Everything needed to (re)build a job's runner from scratch.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub name: String,
    pub workload: WorkloadKind,
    pub cfg: RunConfig,
}

/// The resident half of a job: something that can advance by a step
/// slice and round-trip its full state through a v2 checkpoint.
/// [`Trainer`] jobs and the pure-Rust synthetic workload implement it.
pub trait JobRunner {
    /// Run at most `n` steps; returns the number actually run.
    fn run_steps(&mut self, n: usize) -> Result<usize>;
    fn step(&self) -> usize;
    fn metrics(&self) -> &Metrics;
    fn metrics_mut(&mut self) -> &mut Metrics;
    fn save_checkpoint(&self, path: &Path) -> Result<()>;
    fn restore_checkpoint(&mut self, path: &Path) -> Result<()>;
}

/// [`Trainer`]-backed runner (artifact + finetune workloads).
struct TrainerRunner {
    t: Trainer,
}

impl JobRunner for TrainerRunner {
    fn run_steps(&mut self, n: usize) -> Result<usize> {
        self.t.run_steps(n)
    }

    fn step(&self) -> usize {
        self.t.step
    }

    fn metrics(&self) -> &Metrics {
        &self.t.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.t.metrics
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        self.t.save_checkpoint(path)
    }

    fn restore_checkpoint(&mut self, path: &Path) -> Result<()> {
        self.t.restore_checkpoint(path)
    }
}

/// Pure-Rust workload: minimize `0.5·Σ‖W − W*‖² / numel` toward a planted
/// parameter set `W*` seeded from the run config. The gradient is simply
/// `W − W*`, so no accelerator artifacts are needed — but the update path
/// is the genuine one: `build_optimizer` (GaLore projectors, adaptive
/// rank schedules, 8-bit moments, …), the cosine LR schedule, the bf16
/// weight store, and checkpoint v2 through `Optimizer::save_state`.
/// Fully deterministic, hence bit-exact across evict/restore.
pub struct SyntheticRunner {
    cfg: RunConfig,
    params: ParamStore,
    target: ParamStore,
    opt: Box<dyn Optimizer>,
    schedule: LrSchedule,
    metrics: Metrics,
    step: usize,
    /// Persistent gradient workspace (schema order).
    grads: Vec<Matrix>,
}

impl SyntheticRunner {
    pub fn new(cfg: RunConfig) -> Result<SyntheticRunner> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        if cfg.backend == BackendKind::Artifact {
            bail!(
                "the synthetic workload computes gradients in pure Rust; \
                 backend 'artifact' has no artifacts to run (use backend 'rust')"
            );
        }
        let mut params = init_params(cfg.model, cfg.seed);
        params.seed_rounding(cfg.seed);
        params.set_precision(cfg.weight_precision);
        let target = init_params(cfg.model, cfg.seed ^ 0x5EED_7A26);
        let targets = params.projection_targets();
        let opt = build_optimizer(&cfg, &targets)?;
        let schedule = LrSchedule::cosine(cfg.lr, cfg.steps, cfg.warmup_frac, cfg.final_lr_frac);
        Ok(SyntheticRunner {
            cfg,
            params,
            target,
            opt,
            schedule,
            metrics: Metrics::new(),
            step: 0,
            grads: Vec::new(),
        })
    }

    /// Current objective value (also the "eval" metric — the objective is
    /// deterministic, so there is no held-out set to sample).
    fn loss(&self) -> f32 {
        let mut sum = 0.0f64;
        for (w, t) in self.params.tensors.iter().zip(self.target.tensors.iter()) {
            for (a, b) in w.data.iter().zip(t.data.iter()) {
                let d = (a - b) as f64;
                sum += d * d;
            }
        }
        (0.5 * sum / self.params.numel() as f64) as f32
    }

    fn train_step(&mut self) -> Result<f32> {
        if self.grads.is_empty() {
            self.grads =
                self.params.metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        }
        let mut sum = 0.0f64;
        for ((g, w), t) in
            self.grads.iter_mut().zip(self.params.tensors.iter()).zip(self.target.tensors.iter())
        {
            for ((gd, a), b) in g.data.iter_mut().zip(w.data.iter()).zip(t.data.iter()) {
                let d = a - b;
                *gd = d;
                sum += (d as f64) * (d as f64);
            }
        }
        let loss = (0.5 * sum / self.params.numel() as f64) as f32;
        let lr = self.schedule.at(self.step);
        // Detach the workspace for the `&mut self` optimizer dispatch;
        // restore it even when the step errors (same pattern as the
        // trainer) so the runner stays checkpointable.
        let bufs = std::mem::take(&mut self.grads);
        let applied = self.opt.step_many(&mut self.params.tensors, &bufs, lr);
        self.grads = bufs;
        applied.map_err(|e| anyhow!("optimizer step failed: {e}"))?;
        self.params.commit();
        self.metrics.log_step(self.step, loss, lr, self.cfg.batch * self.cfg.model.seq);
        self.step += 1;
        Ok(loss)
    }

    fn fingerprint(&self) -> String {
        // Namespaced so a synthetic checkpoint can never restore into a
        // real artifact run of the same config (different gradients).
        format!("synthetic {}", self.cfg.fingerprint())
    }
}

impl JobRunner for SyntheticRunner {
    fn run_steps(&mut self, n: usize) -> Result<usize> {
        let mut ran = 0;
        while self.step < self.cfg.steps && ran < n {
            self.train_step()?;
            ran += 1;
        }
        // Log the end-of-run objective exactly once (mirrors the
        // trainer's final-eval contract).
        let done = self.step >= self.cfg.steps;
        let logged =
            self.metrics.eval_records.last().map(|&(s, _)| s >= self.cfg.steps).unwrap_or(false);
        if done && !logged {
            let l = self.loss();
            self.metrics.log_eval(self.step, l);
        }
        Ok(ran)
    }

    fn step(&self) -> usize {
        self.step
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let mut opt_blob = Vec::new();
        self.opt
            .save_state(&mut opt_blob)
            .map_err(|e| anyhow!("cannot checkpoint optimizer state: {e}"))?;
        let mut metrics_blob = Vec::new();
        self.metrics.save_state(&mut metrics_blob);
        let sections: Vec<(&[u8; 4], &[u8])> = vec![
            (checkpoint::SEC_OPTIMIZER, opt_blob.as_slice()),
            (checkpoint::SEC_METRICS, metrics_blob.as_slice()),
        ];
        checkpoint::save_v2(path, &self.params, &self.fingerprint(), self.step as u64, &sections)?;
        Ok(())
    }

    fn restore_checkpoint(&mut self, path: &Path) -> Result<()> {
        match checkpoint::read(path, self.cfg.model)? {
            checkpoint::Checkpoint::V1 { .. } => {
                bail!("synthetic jobs write full-state (v2) checkpoints; {path:?} is v1")
            }
            checkpoint::Checkpoint::V2(d) => {
                let want = self.fingerprint();
                if d.fingerprint != want {
                    bail!(
                        "checkpoint config mismatch — restoring would diverge.\n  \
                         checkpoint: {}\n  this job:   {want}",
                        d.fingerprint
                    );
                }
                let opt_bytes = d
                    .section(checkpoint::SEC_OPTIMIZER)
                    .ok_or_else(|| anyhow!("checkpoint is missing its optimizer section"))?;
                let mut r = crate::ser::Reader::new(opt_bytes);
                self.opt.load_state(&mut r).map_err(|e| anyhow!("optimizer state: {e}"))?;
                r.expect_end().map_err(|e| anyhow!("optimizer state: {e}"))?;
                let metrics_bytes = d
                    .section(checkpoint::SEC_METRICS)
                    .ok_or_else(|| anyhow!("checkpoint is missing its metrics section"))?;
                let mut r = crate::ser::Reader::new(metrics_bytes);
                self.metrics.load_state(&mut r).map_err(|e| anyhow!("metrics state: {e}"))?;
                r.expect_end().map_err(|e| anyhow!("metrics state: {e}"))?;
                self.params = d.params;
                self.params.set_precision(self.cfg.weight_precision);
                self.step = d.step as usize;
                Ok(())
            }
        }
    }
}

/// Point-in-time progress snapshot kept on the job itself, so `status`
/// answers for evicted (Paused/Done) jobs without touching the runner.
#[derive(Clone, Copy, Debug, Default)]
struct Progress {
    step: usize,
    tail_loss: Option<f32>,
    tokens: u64,
}

/// What the serve API reports for one job.
#[derive(Clone, Debug, PartialEq)]
pub struct JobInfo {
    pub id: u64,
    pub name: String,
    pub state: JobState,
    pub step: usize,
    pub steps_total: usize,
    /// Mean loss over the last 10 logged steps; `None` before any step.
    pub tail_loss: Option<f32>,
    pub tokens: u64,
    /// Admission-control footprint estimate (`memory::breakdown`).
    pub est_bytes: u64,
    /// Whether the job currently holds training state in RAM.
    pub resident: bool,
    pub error: Option<String>,
}

/// One managed training run. See the module docs for the state machine.
pub struct Job {
    pub id: u64,
    pub spec: JobSpec,
    pub state: JobState,
    pub error: Option<String>,
    runner: Option<Box<dyn JobRunner>>,
    /// Where this job's suspend/final checkpoint lives.
    pub ckpt_path: PathBuf,
    progress: Progress,
    /// Step records kept past terminal eviction: completion/failure drops
    /// the runner inside `run_slice`, before the scheduler's JSONL sink
    /// gets to flush the final slice's rows.
    retired_records: Vec<StepRecord>,
}

impl Job {
    /// A new job enters the queue; its runner is built at admission.
    pub fn new(id: u64, spec: JobSpec, job_dir: &Path) -> Job {
        Job {
            id,
            spec,
            state: JobState::Queued,
            error: None,
            runner: None,
            ckpt_path: job_dir.join(format!("job{id:04}.ckpt")),
            progress: Progress::default(),
            retired_records: Vec::new(),
        }
    }

    /// Admission-control footprint: the `memory::breakdown` estimate of
    /// this job's resident training state (weights + optimizer states +
    /// gradients + activations). Adaptive-rank runs are budgeted at their
    /// configured maximum rank — admission must hold at the envelope, not
    /// the decayed steady state. The run's actual weight-store precision
    /// and projector store feed the estimate, so `int8` / `int4` jobs are
    /// admitted against their real (smaller) footprint.
    pub fn estimated_bytes(&self) -> u64 {
        let cfg = &self.spec.cfg;
        let opts = TrainOpts {
            layerwise_updates: cfg.layerwise,
            activation_checkpoint: false,
            token_batch: cfg.batch * cfg.model.seq,
            weight_precision: Some(cfg.weight_precision),
            projector_quant: Some(cfg.galore.projector_quant),
        };
        if cfg.method.is_galore() && cfg.galore.is_adaptive() {
            estimate_adaptive(cfg.model, opts, |_, _| cfg.galore.rank).total()
        } else {
            let rank =
                if cfg.method.is_galore() { cfg.galore.rank } else { cfg.lowrank_rank };
            estimate(cfg.model, Method::for_kind(cfg.method, rank), opts).total()
        }
    }

    /// Whether the job currently holds training state in RAM.
    pub fn is_resident(&self) -> bool {
        self.runner.is_some()
    }

    /// Build (or rebuild) the runner and bring the job resident. Pass the
    /// scheduler's shared engine handle so artifact-backed jobs with
    /// identical layer shapes reuse one compiled-executable cache. A
    /// suspend checkpoint on disk — from `pause_evict`, or from a daemon
    /// restart — is restored, making re-admission bit-exact.
    pub fn admit(&mut self, shared_engine: Option<&Engine>) -> Result<()> {
        if !matches!(self.state, JobState::Queued) {
            bail!("job {} is {}, not queued", self.id, self.state.label());
        }
        let cfg = self.spec.cfg.clone();
        let mut runner: Box<dyn JobRunner> = match self.spec.workload {
            WorkloadKind::Synthetic => Box::new(SyntheticRunner::new(cfg)?),
            WorkloadKind::Artifact | WorkloadKind::Finetune { .. } => {
                let engine = match shared_engine {
                    Some(e) => e.share(),
                    None => Engine::new(cfg.artifacts_dir())?,
                };
                let loader = match self.spec.workload {
                    WorkloadKind::Finetune { p_bigram } => {
                        let corpus = SyntheticCorpus::with_params(
                            cfg.model.vocab,
                            cfg.seed,
                            4,
                            p_bigram,
                            1.05,
                        );
                        DataLoader::fixed(corpus.shard(0, 20_000), cfg.batch, cfg.model.seq, cfg.seed)
                    }
                    _ => DataLoader::synthetic(
                        SyntheticCorpus::new(cfg.model.vocab, cfg.seed ^ 0xDA7A),
                        cfg.batch,
                        cfg.model.seq,
                    ),
                };
                let mut t = Trainer::new(cfg, engine, loader)?;
                // Namespace this job's periodic checkpoints so jobs
                // sharing a checkpoint_dir prune independently.
                t.checkpoint_prefix = format!("job{}_step_", self.id);
                Box::new(TrainerRunner { t })
            }
        };
        if self.ckpt_path.exists() {
            runner.restore_checkpoint(&self.ckpt_path)?;
        }
        runner.metrics_mut().job_id = Some(self.id);
        self.retired_records.clear();
        self.runner = Some(runner);
        self.record_progress();
        self.state = JobState::Admitted;
        Ok(())
    }

    fn record_progress(&mut self) {
        if let Some(r) = &self.runner {
            self.progress = Progress {
                step: r.step(),
                tail_loss: r.metrics().tail_loss(10),
                tokens: r.metrics().total_tokens(),
            };
        }
    }

    /// Advance the job by at most `n` steps (the scheduler's round-robin
    /// quantum). Completion writes the final-state checkpoint and evicts;
    /// a step error moves the job to `Failed` (state dropped, error kept).
    /// Returns the number of steps actually run.
    pub fn run_slice(&mut self, n: usize) -> usize {
        let Some(runner) = self.runner.as_mut() else {
            return 0;
        };
        self.state = JobState::Running;
        match runner.run_steps(n) {
            Err(e) => {
                self.record_progress();
                self.error = Some(format!("{e:#}"));
                self.retire_runner();
                self.state = JobState::Failed;
                0
            }
            Ok(ran) => {
                self.record_progress();
                if self.progress.step >= self.spec.cfg.steps {
                    // Final checkpoint, then release the memory.
                    if let Err(e) = self.save_to_ckpt() {
                        self.error = Some(format!("{e:#}"));
                        self.state = JobState::Failed;
                    } else {
                        self.state = JobState::Done;
                    }
                    self.retire_runner();
                }
                ran
            }
        }
    }

    /// Drop the runner but keep its step records, so the scheduler's log
    /// sink can still flush the rows produced by the terminal slice.
    fn retire_runner(&mut self) {
        if let Some(mut r) = self.runner.take() {
            self.retired_records = std::mem::take(&mut r.metrics_mut().records);
        }
    }

    fn save_to_ckpt(&self) -> Result<()> {
        if let Some(dir) = self.ckpt_path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let runner = self.runner.as_ref().ok_or_else(|| anyhow!("job is not resident"))?;
        runner.save_checkpoint(&self.ckpt_path)
    }

    /// Suspend: serialize full training state to the job's checkpoint and
    /// drop the runner. The job now costs disk, not RAM; `admit` after
    /// `resume_to_queue` restores it bit-exactly.
    pub fn pause_evict(&mut self) -> Result<()> {
        if !self.is_resident() {
            bail!("job {} is {}, nothing to pause", self.id, self.state.label());
        }
        self.record_progress();
        self.save_to_ckpt()?;
        self.runner = None;
        self.state = JobState::Paused;
        Ok(())
    }

    /// Re-enter the admission queue from `Paused`.
    pub fn resume_to_queue(&mut self) -> Result<()> {
        if self.state != JobState::Paused {
            bail!("job {} is {}, not paused", self.id, self.state.label());
        }
        self.state = JobState::Queued;
        Ok(())
    }

    /// Abort: drop any resident state and the suspend checkpoint.
    /// Terminal jobs keep their state (cancelling a `Done` job is a
    /// no-op error, not a retroactive failure).
    pub fn cancel(&mut self) -> Result<()> {
        if self.state.is_terminal() {
            bail!("job {} is already {}", self.id, self.state.label());
        }
        self.runner = None;
        self.error = Some("cancelled".into());
        self.state = JobState::Failed;
        let _ = std::fs::remove_file(&self.ckpt_path);
        Ok(())
    }

    /// Step records for the scheduler's JSONL sink: the resident runner's
    /// history, or the retired copy for a job that just reached a terminal
    /// state (so its final slice still gets flushed). `None` for a job
    /// evicted by `pause_evict` — everything was flushed slice-by-slice
    /// before the pause landed.
    pub fn records(&self) -> Option<&[StepRecord]> {
        match &self.runner {
            Some(r) => Some(r.metrics().records.as_slice()),
            None if !self.retired_records.is_empty() => Some(self.retired_records.as_slice()),
            None => None,
        }
    }

    pub fn info(&self) -> JobInfo {
        JobInfo {
            id: self.id,
            name: self.spec.name.clone(),
            state: self.state,
            step: self.progress.step,
            steps_total: self.spec.cfg.steps,
            tail_loss: self.progress.tail_loss,
            tokens: self.progress.tokens,
            est_bytes: self.estimated_bytes(),
            resident: self.is_resident(),
            error: self.error.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MethodKind;
    use crate::model::ModelConfig;

    fn spec(steps: usize) -> JobSpec {
        let mut cfg = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        cfg.steps = steps;
        cfg.galore.update_freq = 4;
        JobSpec { name: "t".into(), workload: WorkloadKind::Synthetic, cfg }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("galore_test_job_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn lifecycle_queued_admitted_running_done() {
        let dir = tmp_dir("lifecycle");
        let mut job = Job::new(1, spec(6), &dir);
        assert_eq!(job.state, JobState::Queued);
        assert!(!job.is_resident());
        assert!(job.run_slice(4) == 0, "non-resident job cannot run");
        job.admit(None).unwrap();
        assert_eq!(job.state, JobState::Admitted);
        assert!(job.is_resident());
        assert!(job.admit(None).is_err(), "double admission must be rejected");
        assert_eq!(job.run_slice(4), 4);
        assert_eq!(job.state, JobState::Running);
        assert_eq!(job.run_slice(4), 2);
        assert_eq!(job.state, JobState::Done);
        assert!(!job.is_resident(), "completion evicts");
        assert!(job.ckpt_path.exists(), "completion writes the final checkpoint");
        let info = job.info();
        assert_eq!(info.step, 6);
        assert_eq!(info.steps_total, 6);
        assert!(info.tail_loss.is_some());
        assert!(info.tokens > 0);
        assert!(job.cancel().is_err(), "terminal jobs cannot be cancelled");
    }

    #[test]
    fn pause_evict_resume_is_bit_exact() {
        let dir = tmp_dir("bitexact");
        // Uninterrupted reference.
        let mut a = Job::new(1, spec(10), &dir);
        a.admit(None).unwrap();
        a.run_slice(10);
        assert_eq!(a.state, JobState::Done);

        // Same config: run 4 steps, evict, restore, finish. `update_freq
        // = 4` puts the pause right at a projector-refresh boundary and
        // step 4 of 10 mid-schedule.
        let dir2 = tmp_dir("bitexact2");
        let mut b = Job::new(1, spec(10), &dir2);
        b.admit(None).unwrap();
        b.run_slice(4);
        b.pause_evict().unwrap();
        assert!(!b.is_resident());
        assert!(b.ckpt_path.exists());
        b.resume_to_queue().unwrap();
        b.admit(None).unwrap();
        b.run_slice(10);
        assert_eq!(b.state, JobState::Done);

        let (ra, rb) = (&a.progress, &b.progress);
        assert_eq!(ra.step, rb.step);
        assert_eq!(ra.tokens, rb.tokens);
        assert_eq!(
            ra.tail_loss.unwrap().to_bits(),
            rb.tail_loss.unwrap().to_bits(),
            "evict/restore must be bit-exact"
        );
    }

    #[test]
    fn cancel_discards_state_and_checkpoint() {
        let dir = tmp_dir("cancel");
        let mut job = Job::new(2, spec(10), &dir);
        job.admit(None).unwrap();
        job.run_slice(2);
        job.pause_evict().unwrap();
        assert!(job.ckpt_path.exists());
        job.cancel().unwrap();
        assert_eq!(job.state, JobState::Failed);
        assert_eq!(job.error.as_deref(), Some("cancelled"));
        assert!(!job.ckpt_path.exists(), "cancel removes the suspend checkpoint");
    }

    #[test]
    fn estimated_bytes_tracks_method_and_rank() {
        let dir = tmp_dir("estimate");
        let mut s = spec(10);
        let galore = Job::new(1, s.clone(), &dir).estimated_bytes();
        s.cfg.method = MethodKind::FullRank;
        let full = Job::new(2, s, &dir).estimated_bytes();
        assert!(
            galore < full,
            "GaLore admission estimate ({galore}) must undercut full-rank ({full})"
        );
    }

    #[test]
    fn state_labels_roundtrip() {
        for s in [
            JobState::Queued,
            JobState::Admitted,
            JobState::Running,
            JobState::Paused,
            JobState::Done,
            JobState::Failed,
        ] {
            assert_eq!(JobState::parse(s.label()), Ok(s));
        }
        assert!(JobState::parse("nope").is_err());
        assert_eq!(WorkloadKind::parse("finetune", Some(0.8)).unwrap().label(), "finetune");
        assert!(WorkloadKind::parse("x", None).is_err());
    }
}
