//! Learning-rate schedule: linear warmup over the first `warmup_frac` of
//! training, then cosine annealing to `final_frac` of the peak
//! (Appendix C.1 of the paper).

#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub peak: f32,
    pub total_steps: usize,
    pub warmup_steps: usize,
    pub final_frac: f32,
}

impl LrSchedule {
    pub fn cosine(peak: f32, total_steps: usize, warmup_frac: f32, final_frac: f32) -> Self {
        let warmup_steps = ((total_steps as f32 * warmup_frac) as usize).max(1);
        LrSchedule { peak, total_steps: total_steps.max(1), warmup_steps, final_frac }
    }

    /// LR at 0-based step t.
    pub fn at(&self, t: usize) -> f32 {
        if t < self.warmup_steps {
            return self.peak * (t + 1) as f32 / self.warmup_steps as f32;
        }
        let decay_steps = (self.total_steps - self.warmup_steps).max(1);
        let progress = ((t - self.warmup_steps) as f32 / decay_steps as f32).min(1.0);
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        let floor = self.peak * self.final_frac;
        floor + (self.peak - floor) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_cosine_to_floor() {
        let s = LrSchedule::cosine(0.01, 100, 0.1, 0.1);
        assert!(s.at(0) <= 0.01 / 10.0 + 1e-9);
        assert!((s.at(9) - 0.01).abs() < 1e-6); // end of warmup
        assert!((s.at(99) - 0.001).abs() < 2e-4); // ~floor
        // Monotone decreasing after warmup.
        let mut prev = s.at(10);
        for t in 11..100 {
            let cur = s.at(t);
            assert!(cur <= prev + 1e-9);
            prev = cur;
        }
    }

    #[test]
    fn beyond_total_steps_clamps() {
        let s = LrSchedule::cosine(0.01, 50, 0.1, 0.1);
        assert!((s.at(500) - 0.001).abs() < 1e-6);
    }
}
