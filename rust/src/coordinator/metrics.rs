//! Training metrics: loss/perplexity tracking, tokens/s throughput, and a
//! CSV sink under `runs/` consumed by EXPERIMENTS.md and the figure
//! benches.

use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

/// One logged training step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub tokens: usize,
}

pub struct Metrics {
    pub records: Vec<StepRecord>,
    pub eval_records: Vec<(usize, f32)>, // (step, eval loss)
    started: Instant,
    total_tokens: u64,
    /// Wall time spent inside artifact execution (for coordinator-overhead
    /// accounting in §Perf).
    pub exec_time: std::time::Duration,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            records: Vec::new(),
            eval_records: Vec::new(),
            started: Instant::now(),
            total_tokens: 0,
            exec_time: std::time::Duration::ZERO,
        }
    }

    pub fn log_step(&mut self, step: usize, loss: f32, lr: f32, tokens: usize) {
        self.records.push(StepRecord { step, loss, lr, tokens });
        self.total_tokens += tokens as u64;
    }

    pub fn log_eval(&mut self, step: usize, loss: f32) {
        self.eval_records.push((step, loss));
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the final `n` steps (robust final metric).
    pub fn tail_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    pub fn final_eval_loss(&self) -> Option<f32> {
        self.eval_records.last().map(|&(_, l)| l)
    }

    /// exp(loss): the validation-perplexity metric of Tables 2/3.
    pub fn perplexity(loss: f32) -> f32 {
        loss.exp()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.total_tokens as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }

    /// Write `step,loss,lr,tokens` CSV (plus eval rows) for figure benches.
    pub fn write_csv(&self, path: impl Into<PathBuf>) -> std::io::Result<PathBuf> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "step,loss,lr,tokens")?;
        for r in &self.records {
            writeln!(f, "{},{},{},{}", r.step, r.loss, r.lr, r.tokens)?;
        }
        writeln!(f, "# eval")?;
        for (s, l) in &self.eval_records {
            writeln!(f, "{s},{l},,")?;
        }
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_losses_and_tokens() {
        let mut m = Metrics::new();
        m.log_step(0, 5.0, 0.01, 512);
        m.log_step(1, 4.0, 0.01, 512);
        assert_eq!(m.last_loss(), Some(4.0));
        assert_eq!(m.tail_loss(2), Some(4.5));
        assert_eq!(m.total_tokens(), 1024);
        assert!(m.tokens_per_sec() > 0.0);
    }

    #[test]
    fn perplexity_is_exp_loss() {
        assert!((Metrics::perplexity(0.0) - 1.0).abs() < 1e-6);
        assert!((Metrics::perplexity(2.0) - 7.389).abs() < 0.01);
    }

    #[test]
    fn csv_roundtrip() {
        let mut m = Metrics::new();
        m.log_step(0, 5.5, 0.01, 64);
        m.log_eval(0, 5.4);
        let dir = std::env::temp_dir().join("galore_test_metrics");
        let p = m.write_csv(dir.join("run.csv")).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.contains("step,loss,lr,tokens"));
        assert!(text.contains("0,5.5,0.01,64"));
        assert!(text.contains("0,5.4"));
    }
}
