//! Training metrics: loss/perplexity tracking, tokens/s throughput, a
//! CSV sink under `runs/` consumed by EXPERIMENTS.md and the figure
//! benches — and the **allocation counter** behind the hot-path
//! zero-allocation contract (EXPERIMENTS.md §Perf).
//!
//! The crate installs a counting global allocator (thread-local tallies
//! over the system allocator — a pair of TLS adds per allocation, cheap
//! enough to leave on everywhere). [`thread_alloc_stats`] snapshots the
//! current thread's counters; the trainer differences snapshots around the
//! optimizer-update phase to surface a steady-state `allocs_per_step` /
//! `alloc_bytes_per_step`, and the counting-allocator tests pin the
//! "zero allocations after warmup" acceptance criterion.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::Write;
use std::path::PathBuf;
use std::time::Instant;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
    static THREAD_ALLOC_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// System allocator wrapper that tallies allocations per thread.
/// Deallocations are not counted — the hot-path contract is about
/// allocator *traffic*, and a steady-state loop that frees must also have
/// allocated.
pub struct CountingAllocator;

fn record(bytes: usize) {
    // `try_with` so late allocations during thread teardown never panic.
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    let _ = THREAD_ALLOC_BYTES.try_with(|c| c.set(c.get() + bytes as u64));
}

// SAFETY: every method delegates verbatim to `System` after a tally, so
// the wrapper inherits `System`'s GlobalAlloc contract unchanged; the
// tally itself touches only thread-local counters and cannot allocate,
// unwind, or observe the pointers it passes through.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: same `layout` forwarded to `System.alloc`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc(layout)
    }

    // SAFETY: same `layout` forwarded to `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc_zeroed(layout)
    }

    // SAFETY: `ptr`/`layout` pair forwarded untouched — the caller's
    // obligations become `System.realloc`'s preconditions directly.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        record(new_size);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: `ptr` was produced by one of the methods above (all of
    // which return `System` pointers), so handing it back is valid.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL_ALLOCATOR: CountingAllocator = CountingAllocator;

/// Snapshot of the current thread's allocation counters since thread
/// start. Difference two snapshots to measure a region.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocStats {
    pub allocs: u64,
    pub bytes: u64,
}

/// Current thread's allocation tallies (monotone counters; does not
/// allocate).
pub fn thread_alloc_stats() -> AllocStats {
    AllocStats {
        allocs: THREAD_ALLOCS.with(|c| c.get()),
        bytes: THREAD_ALLOC_BYTES.with(|c| c.get()),
    }
}

/// One logged training step.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub tokens: usize,
}

pub struct Metrics {
    /// Owning job's id when this run executes under `galore serve` —
    /// `None` for plain CLI runs. Namespaces the CSV/JSONL sinks (a `job`
    /// column is prepended when set) so K concurrent jobs' rows stay
    /// attributable. Identity, not training state: the scheduler assigns
    /// it at admission, so it is not checkpointed.
    pub job_id: Option<u64>,
    pub records: Vec<StepRecord>,
    pub eval_records: Vec<(usize, f32)>, // (step, eval loss)
    started: Instant,
    total_tokens: u64,
    /// Tokens already counted when this process started — non-zero only
    /// after a checkpoint restore. Throughput is a per-process
    /// measurement, so `tokens_per_sec` excludes pre-resume tokens (the
    /// restored cumulative counter over a fresh wall clock would report
    /// absurd rates).
    resumed_tokens: u64,
    /// Wall time spent inside artifact execution (for coordinator-overhead
    /// accounting in §Perf).
    pub exec_time: std::time::Duration,
    /// Heap allocations performed by the most recent optimizer-update
    /// phase (steady-state target: 0 — EXPERIMENTS.md §Perf).
    pub last_step_allocs: u64,
    /// Bytes requested by those allocations.
    pub last_step_alloc_bytes: u64,
    /// Cumulative f32 elements this worker contributed to gradient
    /// all-reduces (the logical reduced payload, summed over steps).
    /// Observational — like `exec_time` it restarts at resume and is not
    /// checkpointed.
    total_comm_f32s: u64,
    /// Reduced payload of the most recent step (f32 elements).
    pub last_step_comm_f32s: u64,
    /// Wall time this worker spent inside all-reduce collectives (on the
    /// comm thread for the bucketed/overlapped path). Observational like
    /// `exec_time`: restarts at resume, never checkpointed.
    pub comm_time: std::time::Duration,
    /// Wall time the *compute* thread spent blocked waiting on reduced
    /// buckets. For the barrier path this equals `comm_time`; the gap
    /// `comm_time - comm_wait_time` is the communication hidden behind
    /// compute (the overlap-efficiency numerator in `benches/dp_comm.rs`).
    pub comm_wait_time: std::time::Duration,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            job_id: None,
            records: Vec::new(),
            eval_records: Vec::new(),
            started: Instant::now(),
            total_tokens: 0,
            resumed_tokens: 0,
            exec_time: std::time::Duration::ZERO,
            last_step_allocs: 0,
            last_step_alloc_bytes: 0,
            total_comm_f32s: 0,
            last_step_comm_f32s: 0,
            comm_time: std::time::Duration::ZERO,
            comm_wait_time: std::time::Duration::ZERO,
        }
    }

    pub fn log_step(&mut self, step: usize, loss: f32, lr: f32, tokens: usize) {
        self.records.push(StepRecord { step, loss, lr, tokens });
        self.total_tokens += tokens as u64;
    }

    /// Record the allocator traffic of one optimizer-update phase
    /// (difference of two [`thread_alloc_stats`] snapshots).
    pub fn log_step_allocs(&mut self, allocs: u64, bytes: u64) {
        self.last_step_allocs = allocs;
        self.last_step_alloc_bytes = bytes;
    }

    /// Allocations in the most recent optimizer-update phase (0 once the
    /// workspaces are warm).
    pub fn allocs_per_step(&self) -> u64 {
        self.last_step_allocs
    }

    /// Record one step's gradient-exchange payload (f32 elements reduced;
    /// `coordinator::parallel` logs the comm plan's logical size — the
    /// wire traffic per worker is `2·(W−1)/W` of it for a ring).
    pub fn log_step_comm(&mut self, f32s: u64) {
        self.last_step_comm_f32s = f32s;
        self.total_comm_f32s += f32s;
    }

    /// Cumulative reduced payload in f32 elements.
    pub fn comm_f32s_total(&self) -> u64 {
        self.total_comm_f32s
    }

    /// Cumulative reduced payload in bytes.
    pub fn comm_bytes_total(&self) -> u64 {
        4 * self.total_comm_f32s
    }

    pub fn log_eval(&mut self, step: usize, loss: f32) {
        self.eval_records.push((step, loss));
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the final `n` steps (robust final metric). `None`
    /// for an empty window — `n == 0` used to divide by zero and return
    /// NaN, which poisons any comparison downstream.
    pub fn tail_loss(&self, n: usize) -> Option<f32> {
        if n == 0 || self.records.is_empty() {
            return None;
        }
        let tail = &self.records[self.records.len().saturating_sub(n)..];
        Some(tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32)
    }

    pub fn final_eval_loss(&self) -> Option<f32> {
        self.eval_records.last().map(|&(_, l)| l)
    }

    /// exp(loss): the validation-perplexity metric of Tables 2/3.
    pub fn perplexity(loss: f32) -> f32 {
        loss.exp()
    }

    /// Tokens/s of *this process* (tokens restored from a checkpoint are
    /// excluded — they were consumed on someone else's wall clock).
    pub fn tokens_per_sec(&self) -> f64 {
        let session_tokens = self.total_tokens.saturating_sub(self.resumed_tokens);
        session_tokens as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn total_tokens(&self) -> u64 {
        self.total_tokens
    }

    /// Tokens consumed by *this process* (excludes the counter restored
    /// from a checkpoint). `total_tokens() = resumed_tokens() +
    /// session_tokens()` — the split the data-parallel aggregation needs
    /// to attribute restored tokens exactly once per replica.
    pub fn session_tokens(&self) -> u64 {
        self.total_tokens.saturating_sub(self.resumed_tokens)
    }

    /// The token counter as restored from a checkpoint (0 for a fresh
    /// run). Per-replica: under data parallelism this is rank-0's own
    /// pre-interrupt consumption, not the global total.
    pub fn resumed_tokens(&self) -> u64 {
        self.resumed_tokens
    }

    /// Checkpoint v2: token counter plus the full step/eval history, so a
    /// resumed run's CSV and tail metrics match the uninterrupted run's.
    /// Wall-clock fields (`started`, `exec_time`) restart at resume —
    /// throughput is a per-process measurement, not training state.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        crate::ser::put_u64(out, self.total_tokens);
        crate::ser::put_u64(out, self.records.len() as u64);
        for r in &self.records {
            crate::ser::put_u64(out, r.step as u64);
            crate::ser::put_f32(out, r.loss);
            crate::ser::put_f32(out, r.lr);
            crate::ser::put_u64(out, r.tokens as u64);
        }
        crate::ser::put_u64(out, self.eval_records.len() as u64);
        for &(s, l) in &self.eval_records {
            crate::ser::put_u64(out, s as u64);
            crate::ser::put_f32(out, l);
        }
    }

    pub fn load_state(&mut self, r: &mut crate::ser::Reader<'_>) -> Result<(), String> {
        self.total_tokens = r.u64()?;
        // Pre-resume tokens were consumed by another process: exclude
        // them from this process's throughput measurement.
        self.resumed_tokens = self.total_tokens;
        let n = r.u64()? as usize;
        self.records.clear();
        for _ in 0..n {
            let step = r.u64()? as usize;
            let loss = r.f32()?;
            let lr = r.f32()?;
            let tokens = r.u64()? as usize;
            self.records.push(StepRecord { step, loss, lr, tokens });
        }
        let n = r.u64()? as usize;
        self.eval_records.clear();
        for _ in 0..n {
            let step = r.u64()? as usize;
            let loss = r.f32()?;
            self.eval_records.push((step, loss));
        }
        Ok(())
    }

    /// Write `step,loss,lr,tokens` CSV (plus eval rows) for figure
    /// benches. Under a serve job (`job_id` set) every row — header
    /// included — gains a leading `job` column.
    pub fn write_csv(&self, path: impl Into<PathBuf>) -> std::io::Result<PathBuf> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(&path)?;
        match self.job_id {
            Some(id) => {
                writeln!(f, "job,step,loss,lr,tokens")?;
                for r in &self.records {
                    writeln!(f, "{},{},{},{},{}", id, r.step, r.loss, r.lr, r.tokens)?;
                }
                writeln!(f, "# eval")?;
                for (s, l) in &self.eval_records {
                    writeln!(f, "{id},{s},{l},,")?;
                }
            }
            None => {
                writeln!(f, "step,loss,lr,tokens")?;
                for r in &self.records {
                    writeln!(f, "{},{},{},{}", r.step, r.loss, r.lr, r.tokens)?;
                }
                writeln!(f, "# eval")?;
                for (s, l) in &self.eval_records {
                    writeln!(f, "{s},{l},,")?;
                }
            }
        }
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_losses_and_tokens() {
        let mut m = Metrics::new();
        m.log_step(0, 5.0, 0.01, 512);
        m.log_step(1, 4.0, 0.01, 512);
        assert_eq!(m.last_loss(), Some(4.0));
        assert_eq!(m.tail_loss(2), Some(4.5));
        assert_eq!(m.total_tokens(), 1024);
        assert!(m.tokens_per_sec() > 0.0);
    }

    #[test]
    fn tail_loss_zero_window_is_none_not_nan() {
        let mut m = Metrics::new();
        m.log_step(0, 5.0, 0.01, 512);
        assert_eq!(m.tail_loss(0), None, "n=0 used to return NaN");
        assert_eq!(Metrics::new().tail_loss(0), None);
        assert_eq!(Metrics::new().tail_loss(3), None);
    }

    #[test]
    fn state_roundtrip_preserves_history() {
        let mut m = Metrics::new();
        m.log_step(0, 5.0, 0.01, 512);
        m.log_step(1, 4.5, 0.009, 512);
        m.log_eval(1, 4.6);
        let mut blob = Vec::new();
        m.save_state(&mut blob);
        let mut n = Metrics::new();
        let mut r = crate::ser::Reader::new(&blob);
        n.load_state(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(n.total_tokens(), 1024);
        assert_eq!(n.records.len(), 2);
        assert_eq!(n.records[1].loss, 4.5);
        assert_eq!(n.eval_records, vec![(1, 4.6)]);
        assert_eq!(n.tail_loss(2), m.tail_loss(2));
        // Restored tokens were earned on another process's clock: they
        // must not inflate this process's throughput.
        assert_eq!(n.tokens_per_sec(), 0.0);
        n.log_step(2, 4.0, 0.008, 512);
        assert_eq!(n.total_tokens(), 1536);
        assert!(n.tokens_per_sec() > 0.0);
    }

    #[test]
    fn perplexity_is_exp_loss() {
        assert!((Metrics::perplexity(0.0) - 1.0).abs() < 1e-6);
        assert!((Metrics::perplexity(2.0) - 7.389).abs() < 0.01);
    }

    #[test]
    fn alloc_counter_sees_allocations_and_silence() {
        let s0 = thread_alloc_stats();
        let v: Vec<u64> = Vec::with_capacity(1024);
        let s1 = thread_alloc_stats();
        assert!(s1.allocs > s0.allocs, "allocation not counted");
        assert!(s1.bytes >= s0.bytes + 1024 * 8, "bytes under-counted");
        drop(v);
        // Pure arithmetic must not move the counters.
        let s2 = thread_alloc_stats();
        let mut acc = 0u64;
        for i in 0..1000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        let s3 = thread_alloc_stats();
        assert_eq!(s2.allocs, s3.allocs, "arithmetic-only region allocated");
    }

    #[test]
    fn step_alloc_metric_roundtrip() {
        let mut m = Metrics::new();
        assert_eq!(m.allocs_per_step(), 0);
        m.log_step_allocs(5, 1234);
        assert_eq!(m.allocs_per_step(), 5);
        assert_eq!(m.last_step_alloc_bytes, 1234);
    }

    #[test]
    fn comm_counters_accumulate_and_restart_on_resume() {
        let mut m = Metrics::new();
        assert_eq!(m.comm_f32s_total(), 0);
        m.log_step_comm(100);
        m.log_step_comm(40);
        assert_eq!(m.last_step_comm_f32s, 40);
        assert_eq!(m.comm_f32s_total(), 140);
        assert_eq!(m.comm_bytes_total(), 560);
        // Observational counter: a state roundtrip does not carry it.
        let mut blob = Vec::new();
        m.save_state(&mut blob);
        let mut n = Metrics::new();
        let mut r = crate::ser::Reader::new(&blob);
        n.load_state(&mut r).unwrap();
        assert_eq!(n.comm_f32s_total(), 0);
    }

    #[test]
    fn csv_roundtrip() {
        let mut m = Metrics::new();
        m.log_step(0, 5.5, 0.01, 64);
        m.log_eval(0, 5.4);
        let dir = std::env::temp_dir().join("galore_test_metrics");
        let p = m.write_csv(dir.join("run.csv")).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.contains("step,loss,lr,tokens"));
        assert!(text.contains("0,5.5,0.01,64"));
        assert!(text.contains("0,5.4"));
    }

    #[test]
    fn csv_gains_job_column_under_serve() {
        let mut m = Metrics::new();
        m.job_id = Some(7);
        m.log_step(0, 5.5, 0.01, 64);
        m.log_eval(0, 5.4);
        let dir = std::env::temp_dir().join("galore_test_metrics");
        let p = m.write_csv(dir.join("job.csv")).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.contains("job,step,loss,lr,tokens"));
        assert!(text.contains("7,0,5.5,0.01,64"));
        assert!(text.contains("7,0,5.4"));
    }
}
