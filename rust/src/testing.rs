//! Minimal property-based testing harness + convergence guardrails.
//!
//! `proptest` is unavailable in this offline build (see DESIGN.md §4), so
//! the repo carries a small functional subset: seeded generators, a
//! `for_all` runner with failure-case reporting, and a handful of
//! numeric/shape strategies used by the coordinator-invariant tests
//! (routing of layer shapes to artifacts, batching, optimizer state).
//!
//! The second half is the **convergence-regression harness**: integration
//! tests used to check only "doesn't crash"; [`run_lsq`] /
//! [`assert_converges`] give optimizer-level runs a seeded synthetic
//! workload with a held-out eval split and a loss guardrail (pure Rust, no
//! artifacts), and [`assert_run_converges`] does the same for full
//! artifact-backed `RunConfig` trainings.

use crate::optim::Optimizer;
use crate::rng::Rng;
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};

/// Number of cases each property runs (override with GALORE_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("GALORE_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// A generator of random test inputs.
pub trait Strategy {
    type Value;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
}

impl<T, F: Fn(&mut Rng) -> T> Strategy for F {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Run `prop` against `cases` random inputs from `strat`; panic with the
/// seed and debug-printed input on the first failure.
pub fn for_all<S: Strategy>(name: &str, strat: S, prop: impl Fn(&S::Value) -> bool)
where
    S::Value: std::fmt::Debug,
{
    for_all_cases(name, strat, default_cases(), prop)
}

pub fn for_all_cases<S: Strategy>(
    name: &str,
    strat: S,
    cases: usize,
    prop: impl Fn(&S::Value) -> bool,
) where
    S::Value: std::fmt::Debug,
{
    let base_seed: u64 =
        std::env::var("GALORE_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xDECAF);
    for case in 0..cases {
        let mut rng = Rng::new(base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let value = strat.generate(&mut rng);
        if !prop(&value) {
            panic!(
                "property '{name}' failed at case {case} \
                 (GALORE_PROP_SEED={base_seed}):\n  input: {value:?}"
            );
        }
    }
}

// -- common strategies ------------------------------------------------------

/// Integer in [lo, hi].
pub fn int_in(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> usize {
    move |rng| lo + rng.below(hi - lo + 1)
}

/// f32 in [lo, hi).
pub fn f32_in(lo: f32, hi: f32) -> impl Fn(&mut Rng) -> f32 {
    move |rng| lo + (hi - lo) * rng.next_f32()
}

/// Random normal matrix with dims each in [dlo, dhi].
pub fn matrix(dlo: usize, dhi: usize) -> impl Fn(&mut Rng) -> Matrix {
    move |rng| {
        let m = dlo + rng.below(dhi - dlo + 1);
        let n = dlo + rng.below(dhi - dlo + 1);
        Matrix::randn(m, n, 1.0, rng)
    }
}

/// Random token batch: (batch, seq, vocab) -> Vec<i32> ids.
pub fn token_batch(batch: usize, seq: usize, vocab: usize) -> impl Fn(&mut Rng) -> Vec<i32> {
    move |rng| (0..batch * seq).map(|_| rng.below(vocab) as i32).collect()
}

/// Relative-tolerance float comparison used across numeric tests.
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * a.abs().max(b.abs())
}

/// Assert two slices are element-wise close; panics with index context.
pub fn assert_slice_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(close(x, y, rtol, atol), "mismatch at {i}: {x} vs {y}");
    }
}

/// Run `f` on a fresh thread and panic if it has not finished within
/// `dur` — the hard per-test timeout for anything that coordinates
/// multiple threads or processes (DP rings, rendezvous), where the
/// failure mode of a bug is a silent hang rather than an assert. On
/// timeout the worker thread is leaked (it is stuck by hypothesis); a
/// panic inside `f` is relayed to the caller unchanged.
pub fn with_timeout<T: Send + 'static>(
    dur: std::time::Duration,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    let handle = std::thread::spawn(move || {
        let _ = tx.send(std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)));
    });
    match rx.recv_timeout(dur) {
        Ok(Ok(v)) => {
            let _ = handle.join();
            v
        }
        Ok(Err(payload)) => {
            let _ = handle.join();
            std::panic::resume_unwind(payload)
        }
        Err(_) => panic!("test timed out after {dur:?} (worker thread leaked)"),
    }
}

// -- convergence-regression harness -----------------------------------------

/// Seeded synthetic low-rank regression (the Lemma 3.3 setting): inputs
/// confined to a `k_star`-dimensional subspace of R^n, squared loss
/// against a planted `W*`, gradients fed to an [`Optimizer`] under test.
/// Pure Rust — no artifacts — so loss-curve guardrails can run anywhere,
/// including property tests and CI.
#[derive(Clone, Copy, Debug)]
pub struct LsqWorkload {
    /// Weight shape (m, n).
    pub m: usize,
    pub n: usize,
    /// Intrinsic input-subspace dimension (gradients have rank <= k_star).
    pub k_star: usize,
    /// Samples per step.
    pub batch: usize,
    pub lr: f32,
    /// Seeds the planted problem *and* the batch stream — two runs with
    /// the same workload see identical data.
    pub seed: u64,
}

impl Default for LsqWorkload {
    fn default() -> Self {
        LsqWorkload { m: 24, n: 16, k_star: 4, batch: 64, lr: 0.02, seed: 7 }
    }
}

/// What a guardrailed run measured.
#[derive(Clone, Copy, Debug)]
pub struct ConvergenceReport {
    pub first_loss: f32,
    pub final_loss: f32,
    /// Mean loss over held-out batches drawn from seeds disjoint from the
    /// training stream.
    pub eval_loss: f32,
}

/// Train `opt` on the workload for `steps` and report first/final/eval
/// losses. Deterministic given (workload, optimizer state).
pub fn run_lsq(opt: &mut dyn Optimizer, wl: &LsqWorkload, steps: usize) -> ConvergenceReport {
    run_lsq_with_store(opt, wl, steps, crate::model::WeightPrecision::F32)
}

/// Like [`run_lsq`], but round-trips the weight matrix through a
/// `precision` master store after every optimizer step — the same
/// commit `ParamStore` applies in training — so low-precision weight
/// stores face the identical loss guardrail. `F32` is the identity
/// (plain [`run_lsq`]); `Int8` draws its stochastic-rounding stream from
/// a child of `wl.seed`, so two runs of the same workload still see
/// bit-identical rounding.
pub fn run_lsq_with_store(
    opt: &mut dyn Optimizer,
    wl: &LsqWorkload,
    steps: usize,
    precision: crate::model::WeightPrecision,
) -> ConvergenceReport {
    use crate::model::WeightPrecision;
    let mut rng = Rng::new(wl.seed);
    let w_star = Matrix::randn(wl.m, wl.n, 1.0, &mut rng);
    let basis = Matrix::randn(wl.k_star, wl.n, 1.0, &mut rng);
    let mut w = Matrix::zeros(wl.m, wl.n);
    // Per-sample squared error: loss = ‖X Wᵀ − X W*ᵀ‖²_F / B with
    // X = Z·basis, so G = ∂loss/∂W = 2 errᵀ X / B — loss and gradient use
    // the same normalization (loss magnitudes only ever enter guardrails
    // relatively, as fractions of the initial loss).
    let loss_and_grad = |w: &Matrix, batch_rng: &mut Rng| -> (f32, Matrix) {
        let z = Matrix::randn(wl.batch, wl.k_star, 1.0, batch_rng);
        let x = matmul(&z, &basis);
        let mut err = matmul_a_bt(&x, w);
        err.sub_assign(&matmul_a_bt(&x, &w_star));
        let loss = err.frobenius_norm().powi(2) / x.rows as f32;
        let mut g = matmul_at_b(&err, &x);
        g.scale(2.0 / x.rows as f32);
        (loss, g)
    };
    let mut first = 0.0;
    let mut last = 0.0;
    let mut bf16 = crate::quant::Bf16Buf::zeros(wl.m * wl.n);
    let mut int8 = crate::quant::QuantizedBuf::zeros(wl.m * wl.n);
    let mut round_rng = Rng::new(wl.seed).child(0x51C8_0B17);
    for t in 0..steps {
        let (loss, g) = loss_and_grad(&w, &mut rng.child(t as u64));
        if t == 0 {
            first = loss;
        }
        last = loss;
        opt.step(0, &mut w, &g, wl.lr).expect("lsq workload step failed");
        match precision {
            WeightPrecision::F32 => {}
            WeightPrecision::Bf16 => bf16.store_round(&mut w.data),
            WeightPrecision::Int8 => int8.store_round_stochastic(&mut w.data, &mut round_rng),
        }
    }
    let n_eval = 4u64;
    let mut eval = 0.0f64;
    for i in 0..n_eval {
        let (loss, _) = loss_and_grad(&w, &mut rng.child(1_000_000 + i));
        eval += loss as f64;
    }
    ConvergenceReport { first_loss: first, final_loss: last, eval_loss: (eval / n_eval as f64) as f32 }
}

/// Loss-curve guardrail: train on the synthetic workload and assert the
/// held-out eval loss lands at or under `max_loss` (and stays finite).
/// Returns the report so callers can chain comparisons (e.g. adaptive
/// within 5% of fixed-rank).
pub fn assert_converges(
    opt: &mut dyn Optimizer,
    wl: &LsqWorkload,
    steps: usize,
    max_loss: f32,
) -> ConvergenceReport {
    let rep = run_lsq(opt, wl, steps);
    assert!(
        rep.eval_loss.is_finite() && rep.eval_loss <= max_loss,
        "{} did not converge on lsq {}x{} (k*={}): first {} final {} eval {} > max {}",
        opt.name(),
        wl.m,
        wl.n,
        wl.k_star,
        rep.first_loss,
        rep.final_loss,
        rep.eval_loss,
        max_loss
    );
    rep
}

/// Artifact-backed guardrail: train `cfg` for `steps` and require the
/// final eval loss at or under `max_loss`. Integration tests call this
/// after checking the artifacts are present (it errors, like every
/// artifact path, when they are not).
pub fn assert_run_converges(
    cfg: &crate::config::RunConfig,
    steps: usize,
    max_loss: f32,
) -> anyhow::Result<f32> {
    let mut cfg = cfg.clone();
    cfg.steps = steps;
    let mut trainer = crate::coordinator::Trainer::from_config(cfg)?;
    for _ in 0..steps {
        trainer.train_step()?;
    }
    let eval = trainer.eval(trainer.cfg.eval_batches)?;
    if !(eval.is_finite() && eval <= max_loss) {
        anyhow::bail!(
            "run did not converge: eval loss {eval} > max {max_loss} \
             (method {}, {} steps)",
            trainer.cfg.method.label(),
            steps
        );
    }
    Ok(eval)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_passes_trivial_property() {
        for_all("square nonneg", f32_in(-10.0, 10.0), |&x| x * x >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn for_all_reports_failures() {
        for_all("always false", int_in(0, 10), |_| false);
    }

    #[test]
    fn strategies_stay_in_bounds() {
        for_all("int_in bounds", int_in(3, 9), |&v| (3..=9).contains(&v));
        for_all("matrix dims", matrix(2, 6), |m| {
            (2..=6).contains(&m.rows) && (2..=6).contains(&m.cols)
        });
        for_all("tokens in vocab", token_batch(2, 8, 100), |ts| {
            ts.iter().all(|&t| (0..100).contains(&t))
        });
    }

    #[test]
    fn close_edge_cases() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5, 0.0));
        assert!(!close(1.0, 1.1, 1e-5, 0.0));
        assert!(close(0.0, 1e-9, 0.0, 1e-8));
    }

    #[test]
    fn lsq_workload_is_deterministic_and_learnable() {
        use crate::optim::{Adam, AdamConfig};
        let wl = LsqWorkload::default();
        let mut a = Adam::new(AdamConfig::default());
        let r1 = run_lsq(&mut a, &wl, 120);
        let mut b = Adam::new(AdamConfig::default());
        let r2 = run_lsq(&mut b, &wl, 120);
        assert_eq!(r1.final_loss, r2.final_loss, "same seed must reproduce exactly");
        assert_eq!(r1.eval_loss, r2.eval_loss);
        assert!(r1.eval_loss < 0.5 * r1.first_loss, "{r1:?}");
        // The guardrail passes at the achieved loss...
        let mut c = Adam::new(AdamConfig::default());
        assert_converges(&mut c, &wl, 120, r1.eval_loss * 1.01);
    }

    #[test]
    #[should_panic(expected = "did not converge")]
    fn assert_converges_reports_failures() {
        use crate::optim::Sgd;
        // Vanilla SGD for 1 step cannot reach an absurd bound.
        let wl = LsqWorkload::default();
        assert_converges(&mut Sgd::vanilla(), &wl, 1, 1e-12);
    }
}
