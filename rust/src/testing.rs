//! Minimal property-based testing harness.
//!
//! `proptest` is unavailable in this offline build (see DESIGN.md §4), so
//! the repo carries a small functional subset: seeded generators, a
//! `for_all` runner with failure-case reporting, and a handful of
//! numeric/shape strategies used by the coordinator-invariant tests
//! (routing of layer shapes to artifacts, batching, optimizer state).

use crate::rng::Rng;
use crate::tensor::Matrix;

/// Number of cases each property runs (override with GALORE_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("GALORE_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// A generator of random test inputs.
pub trait Strategy {
    type Value;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
}

impl<T, F: Fn(&mut Rng) -> T> Strategy for F {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Run `prop` against `cases` random inputs from `strat`; panic with the
/// seed and debug-printed input on the first failure.
pub fn for_all<S: Strategy>(name: &str, strat: S, prop: impl Fn(&S::Value) -> bool)
where
    S::Value: std::fmt::Debug,
{
    for_all_cases(name, strat, default_cases(), prop)
}

pub fn for_all_cases<S: Strategy>(
    name: &str,
    strat: S,
    cases: usize,
    prop: impl Fn(&S::Value) -> bool,
) where
    S::Value: std::fmt::Debug,
{
    let base_seed: u64 =
        std::env::var("GALORE_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xDECAF);
    for case in 0..cases {
        let mut rng = Rng::new(base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let value = strat.generate(&mut rng);
        if !prop(&value) {
            panic!(
                "property '{name}' failed at case {case} \
                 (GALORE_PROP_SEED={base_seed}):\n  input: {value:?}"
            );
        }
    }
}

// -- common strategies ------------------------------------------------------

/// Integer in [lo, hi].
pub fn int_in(lo: usize, hi: usize) -> impl Fn(&mut Rng) -> usize {
    move |rng| lo + rng.below(hi - lo + 1)
}

/// f32 in [lo, hi).
pub fn f32_in(lo: f32, hi: f32) -> impl Fn(&mut Rng) -> f32 {
    move |rng| lo + (hi - lo) * rng.next_f32()
}

/// Random normal matrix with dims each in [dlo, dhi].
pub fn matrix(dlo: usize, dhi: usize) -> impl Fn(&mut Rng) -> Matrix {
    move |rng| {
        let m = dlo + rng.below(dhi - dlo + 1);
        let n = dlo + rng.below(dhi - dlo + 1);
        Matrix::randn(m, n, 1.0, rng)
    }
}

/// Random token batch: (batch, seq, vocab) -> Vec<i32> ids.
pub fn token_batch(batch: usize, seq: usize, vocab: usize) -> impl Fn(&mut Rng) -> Vec<i32> {
    move |rng| (0..batch * seq).map(|_| rng.below(vocab) as i32).collect()
}

/// Relative-tolerance float comparison used across numeric tests.
pub fn close(a: f32, b: f32, rtol: f32, atol: f32) -> bool {
    (a - b).abs() <= atol + rtol * a.abs().max(b.abs())
}

/// Assert two slices are element-wise close; panics with index context.
pub fn assert_slice_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(close(x, y, rtol, atol), "mismatch at {i}: {x} vs {y}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_passes_trivial_property() {
        for_all("square nonneg", f32_in(-10.0, 10.0), |&x| x * x >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn for_all_reports_failures() {
        for_all("always false", int_in(0, 10), |_| false);
    }

    #[test]
    fn strategies_stay_in_bounds() {
        for_all("int_in bounds", int_in(3, 9), |&v| (3..=9).contains(&v));
        for_all("matrix dims", matrix(2, 6), |m| {
            (2..=6).contains(&m.rows) && (2..=6).contains(&m.cols)
        });
        for_all("tokens in vocab", token_batch(2, 8, 100), |ts| {
            ts.iter().all(|&t| (0..100).contains(&t))
        });
    }

    #[test]
    fn close_edge_cases() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5, 0.0));
        assert!(!close(1.0, 1.1, 1e-5, 0.0));
        assert!(close(0.0, 1e-9, 0.0, 1e-8));
    }
}
