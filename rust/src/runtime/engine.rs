//! PJRT runtime engine: loads HLO-text artifacts, compiles them once, and
//! executes them from the training hot path.
//!
//! Interchange is HLO *text* (see aot.py / DESIGN.md): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` reassigns ids.
//!
//! Hot-path notes (EXPERIMENTS.md §Perf): executables are compiled once and
//! cached; inputs are staged as device buffers via `buffer_from_host_buffer`
//! (avoiding an extra literal copy); outputs come back as one tuple literal
//! that is decomposed without re-marshalling.

use super::manifest::{ArtifactMeta, DType, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A host-side input for one artifact parameter.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Persistent staging for an artifact's input list, so the hot path stops
/// rebuilding a `Vec<Input>` every call (the last per-step allocation the
/// training loop made — the counterpart of the trainer's `grad_bufs`).
/// Usage per call: `begin()` hands out a [`StagedInputs`] guard over the
/// cleared buffer; the caller pushes this call's borrows and passes the
/// guard to the engine. The guard's `Drop` clears the buffer again while
/// the borrowed data is still alive — on the success path, on early `?`
/// returns, and on unwinds alike — so no dangling value ever persists in
/// the warm buffer (staging used to leak across steps when an engine call
/// failed between `begin` and the manual clear).
#[derive(Default)]
pub struct InputStage {
    /// Always empty between guard drops and the next `begin`; the
    /// `'static` here is a placeholder lifetime for the empty buffer,
    /// never the lifetime of any stored value.
    bufs: Vec<Input<'static>>,
}

impl InputStage {
    /// Fresh stage with an empty (but growable, persistent) buffer.
    pub fn new() -> InputStage {
        InputStage { bufs: Vec::new() }
    }

    /// Clear and hand out the staging buffer at the caller's borrow
    /// lifetime, wrapped in an RAII guard. The guard keeps the stage
    /// locked until it is dropped, and its drop clears the staged borrows
    /// on every exit path.
    pub fn begin<'a>(&'a mut self) -> StagedInputs<'a> {
        self.bufs.clear();
        // SAFETY: the Vec is empty, so no existing value is reinterpreted;
        // `Vec<Input<'static>>` and `Vec<Input<'a>>` have identical layout
        // (lifetimes are erased at runtime). Values pushed through the
        // guard borrow data for `'a`; the `&'a mut self` receiver keeps
        // the stage inaccessible until the guard's `Drop` clears the
        // stored borrows — even when the engine call errors or unwinds.
        let bufs = unsafe {
            std::mem::transmute::<&mut Vec<Input<'static>>, &mut Vec<Input<'a>>>(&mut self.bufs)
        };
        StagedInputs { bufs }
    }
}

/// RAII guard over one engine call's staged inputs
/// ([`InputStage::begin`]). Derefs to the underlying `Vec<Input>` for
/// pushing borrows and passing to [`Engine::execute`]; dropping it clears
/// the stage (keeping capacity), so a failed engine call can never leave
/// stale staged buffers behind for the next step.
pub struct StagedInputs<'a> {
    bufs: &'a mut Vec<Input<'a>>,
}

impl<'a> std::ops::Deref for StagedInputs<'a> {
    type Target = Vec<Input<'a>>;

    fn deref(&self) -> &Vec<Input<'a>> {
        self.bufs
    }
}

impl<'a> std::ops::DerefMut for StagedInputs<'a> {
    fn deref_mut(&mut self) -> &mut Vec<Input<'a>> {
        self.bufs
    }
}

impl Drop for StagedInputs<'_> {
    fn drop(&mut self) {
        // `Input` holds only shared borrows (no drop glue): clearing just
        // resets the length, it never touches the borrowed data.
        self.bufs.clear();
    }
}

/// A host-side output tensor (always f32 — every artifact returns floats).
#[derive(Clone, Debug)]
pub struct Output {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Output {
    pub fn scalar(&self) -> f32 {
        self.data[0]
    }
}

/// An engine *handle*: a PJRT client plus a compiled-executable cache,
/// both behind `Arc` so handles created with [`Engine::share`] see one
/// shared cache. A multi-job `galore serve` daemon hands every job a
/// shared handle — N jobs on the same layer shapes compile each
/// `galore_step_{m}x{n}_r{r}` artifact once, not N times — while plain
/// [`Engine::new`] still yields a private cache (each DP worker thread
/// builds its own, exactly as before).
pub struct Engine {
    client: Arc<xla::PjRtClient>,
    pub manifest: Manifest,
    cache: Arc<Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>>,
    /// Cumulative host<->device marshalling + execute time, for the §Perf
    /// coordinator-overhead accounting. Per-handle: a shared engine still
    /// attributes execute calls to the job that made them.
    pub exec_calls: u64,
}

impl Engine {
    /// CPU PJRT client + manifest from `dir`, with a fresh (private)
    /// executable cache.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir).map_err(|e| anyhow!(e))?;
        let client = Arc::new(xla::PjRtClient::cpu()?);
        Ok(Engine {
            client,
            manifest,
            cache: Arc::new(Mutex::new(HashMap::new())),
            exec_calls: 0,
        })
    }

    /// A new handle onto the *same* client and compiled-executable cache.
    /// Anything either handle compiles is visible to the other; the
    /// `exec_calls` counter starts at zero so per-job accounting stays
    /// separate. (Deliberately not `Clone`: sharing an executable cache
    /// is a semantic choice, not a copy.)
    pub fn share(&self) -> Engine {
        Engine {
            client: Arc::clone(&self.client),
            manifest: self.manifest.clone(),
            cache: Arc::clone(&self.cache),
            exec_calls: 0,
        }
    }

    /// Whether two handles share one compiled-executable cache (true for
    /// handles related through [`Engine::share`]).
    pub fn shares_cache_with(&self, other: &Engine) -> bool {
        Arc::ptr_eq(&self.cache, &other.cache)
    }

    fn lock_cache(&self) -> std::sync::MutexGuard<'_, HashMap<String, Arc<xla::PjRtLoadedExecutable>>> {
        // A panic mid-compile poisons the mutex but not the map: entries
        // are inserted only after a successful compile, so the data is
        // always consistent and the lock stays usable.
        self.cache.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Load + compile an artifact (cached; shared-cache handles compile
    /// each artifact at most once between them).
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.lock_cache().contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .with_context(|| format!("loading {:?}", meta.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        // Racing compiles of the same artifact on two handles both succeed;
        // entry() keeps the first and drops the duplicate.
        self.lock_cache().entry(name.to_string()).or_insert_with(|| Arc::new(exe));
        Ok(())
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest.by_name(name).ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Execute an artifact with host inputs; returns its outputs in order.
    pub fn execute(&mut self, name: &str, inputs: &[Input]) -> Result<Vec<Output>> {
        self.prepare(name)?;
        let meta = self.meta(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            bail!("{name}: got {} inputs, artifact takes {}", inputs.len(), meta.inputs.len());
        }
        let device = self.client.devices().into_iter().next();
        let mut buffers = Vec::with_capacity(inputs.len());
        for (i, (input, (shape, dtype))) in inputs
            .iter()
            .zip(meta.inputs.iter().zip(meta.input_dtypes.iter()))
            .enumerate()
        {
            let dims: Vec<usize> = shape.clone();
            let numel: usize = dims.iter().product::<usize>().max(1);
            let buf = match (input, dtype) {
                (Input::F32(data), DType::F32) => {
                    if data.len() != numel {
                        bail!("{name} input {i}: {} elements, want {numel}", data.len());
                    }
                    self.client.buffer_from_host_buffer::<f32>(data, &dims, device.as_ref())?
                }
                (Input::I32(data), DType::I32) => {
                    if data.len() != numel {
                        bail!("{name} input {i}: {} elements, want {numel}", data.len());
                    }
                    self.client.buffer_from_host_buffer::<i32>(data, &dims, device.as_ref())?
                }
                _ => bail!("{name} input {i}: dtype mismatch (artifact wants {dtype:?})"),
            };
            buffers.push(buf);
        }
        let exe = self
            .lock_cache()
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("artifact '{name}' missing from executable cache after prepare"))?;
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        self.exec_calls += 1;
        let tuple = result[0][0].to_literal_sync()?;
        // return_tuple=True at lowering: outputs arrive as one tuple.
        let parts = tuple.to_tuple()?;
        if parts.len() != meta.n_outputs {
            bail!("{name}: {} outputs, manifest says {}", parts.len(), meta.n_outputs);
        }
        let mut outputs = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            outputs.push(Output { dims, data });
        }
        Ok(outputs)
    }

    /// Number of distinct compiled executables resident (in the shared
    /// cache, for handles related through [`Engine::share`]).
    pub fn compiled_count(&self) -> usize {
        self.lock_cache().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staged_inputs_clear_on_early_error_return() {
        let mut stage = InputStage::new();
        let data = vec![1.0f32; 4];
        // Model a trainer step whose engine call fails after staging: the
        // `?`-style early return drops the guard mid-function.
        let r: Result<()> = (|| {
            let mut inputs = stage.begin();
            inputs.push(Input::F32(&data));
            bail!("engine call failed");
        })();
        assert!(r.is_err());
        assert_eq!(stage.bufs.len(), 0, "error path must leave the stage cleared");
        // The stage stays usable for the next step.
        let mut inputs = stage.begin();
        inputs.push(Input::F32(&data));
        assert_eq!(inputs.len(), 1);
        drop(inputs);
        assert_eq!(stage.bufs.len(), 0);
    }

    #[test]
    fn shared_handles_share_one_cache_private_engines_do_not() {
        // Construct engines around a hand-built manifest (no PJRT needed
        // to check cache identity — the stub client may be unavailable,
        // so build the struct directly like `Engine::new` would).
        let manifest =
            Manifest::parse(r#"{"artifacts": []}"#, std::path::PathBuf::from("/tmp/x")).unwrap();
        let mk = || Engine {
            client: Arc::new(xla::PjRtClient {}),
            manifest: manifest.clone(),
            cache: Arc::new(Mutex::new(HashMap::new())),
            exec_calls: 7,
        };
        let a = mk();
        let b = a.share();
        let c = mk();
        assert!(a.shares_cache_with(&b));
        assert!(b.shares_cache_with(&a));
        assert!(!a.shares_cache_with(&c), "independent engines must have private caches");
        assert_eq!(b.exec_calls, 0, "per-handle counter starts fresh on share()");
        assert_eq!(a.compiled_count(), b.compiled_count());
    }

    #[test]
    fn staged_inputs_clear_on_unwind() {
        let mut stage = InputStage::new();
        let data = vec![2.0f32; 4];
        let unwound = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut inputs = stage.begin();
            inputs.push(Input::F32(&data));
            panic!("mid-call panic");
        }));
        assert!(unwound.is_err());
        assert_eq!(stage.bufs.len(), 0, "unwind must leave the stage cleared");
    }
}
