//! PJRT runtime engine: loads HLO-text artifacts, compiles them once, and
//! executes them from the training hot path.
//!
//! Interchange is HLO *text* (see aot.py / DESIGN.md): jax ≥ 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! `HloModuleProto::from_text_file` reassigns ids.
//!
//! Hot-path notes (EXPERIMENTS.md §Perf): executables are compiled once and
//! cached; inputs are staged as device buffers via `buffer_from_host_buffer`
//! (avoiding an extra literal copy); outputs come back as one tuple literal
//! that is decomposed without re-marshalling.

use super::manifest::{ArtifactMeta, DType, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// A host-side input for one artifact parameter.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Persistent staging for an artifact's input list, so the hot path stops
/// rebuilding a `Vec<Input>` every call (the last per-step allocation the
/// training loop made — the counterpart of the trainer's `grad_bufs`).
/// Usage per call: `begin()` hands out the cleared buffer to push this
/// call's borrows into; `finish()` clears it again immediately after the
/// engine call, while the borrowed data is still alive, so no dangling
/// value ever persists in the warm buffer.
#[derive(Default)]
pub struct InputStage {
    /// Always empty between `finish` and the next `begin`; the `'static`
    /// here is a placeholder lifetime for the empty buffer, never the
    /// lifetime of any stored value.
    bufs: Vec<Input<'static>>,
}

impl InputStage {
    pub fn new() -> InputStage {
        InputStage { bufs: Vec::new() }
    }

    /// Clear and hand out the staging buffer at the caller's borrow
    /// lifetime. The returned borrow keeps the stage locked until the
    /// inputs' last use; call [`InputStage::finish`] right after the
    /// engine call to drop the stored borrows.
    pub fn begin<'a>(&'a mut self) -> &'a mut Vec<Input<'a>> {
        self.bufs.clear();
        // SAFETY: the Vec is empty, so no existing value is reinterpreted;
        // `Vec<Input<'static>>` and `Vec<Input<'a>>` have identical layout
        // (lifetimes are erased at runtime). Values pushed through the
        // returned reference borrow data for `'a`, and the `&'a mut self`
        // receiver keeps the stage inaccessible until those borrows end —
        // after which `finish` clears them before they can dangle.
        unsafe {
            std::mem::transmute::<&mut Vec<Input<'static>>, &mut Vec<Input<'a>>>(&mut self.bufs)
        }
    }

    /// Drop this call's borrows (keeps capacity). Must be called after
    /// every `begin` once the engine call returns, while the borrowed
    /// data is still live.
    pub fn finish(&mut self) {
        self.bufs.clear();
    }
}

/// A host-side output tensor (always f32 — every artifact returns floats).
#[derive(Clone, Debug)]
pub struct Output {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Output {
    pub fn scalar(&self) -> f32 {
        self.data[0]
    }
}

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative host<->device marshalling + execute time, for the §Perf
    /// coordinator-overhead accounting.
    pub exec_calls: u64,
}

impl Engine {
    /// CPU PJRT client + manifest from `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: HashMap::new(), exec_calls: 0 })
    }

    /// Load + compile an artifact (cached).
    pub fn prepare(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .by_name(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .with_context(|| format!("loading {:?}", meta.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest.by_name(name).ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Execute an artifact with host inputs; returns its outputs in order.
    pub fn execute(&mut self, name: &str, inputs: &[Input]) -> Result<Vec<Output>> {
        self.prepare(name)?;
        let meta = self.manifest.by_name(name).unwrap().clone();
        if inputs.len() != meta.inputs.len() {
            bail!("{name}: got {} inputs, artifact takes {}", inputs.len(), meta.inputs.len());
        }
        let device = self.client.devices().into_iter().next();
        let mut buffers = Vec::with_capacity(inputs.len());
        for (i, (input, (shape, dtype))) in inputs
            .iter()
            .zip(meta.inputs.iter().zip(meta.input_dtypes.iter()))
            .enumerate()
        {
            let dims: Vec<usize> = shape.clone();
            let numel: usize = dims.iter().product::<usize>().max(1);
            let buf = match (input, dtype) {
                (Input::F32(data), DType::F32) => {
                    if data.len() != numel {
                        bail!("{name} input {i}: {} elements, want {numel}", data.len());
                    }
                    self.client.buffer_from_host_buffer::<f32>(data, &dims, device.as_ref())?
                }
                (Input::I32(data), DType::I32) => {
                    if data.len() != numel {
                        bail!("{name} input {i}: {} elements, want {numel}", data.len());
                    }
                    self.client.buffer_from_host_buffer::<i32>(data, &dims, device.as_ref())?
                }
                _ => bail!("{name} input {i}: dtype mismatch (artifact wants {dtype:?})"),
            };
            buffers.push(buf);
        }
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        self.exec_calls += 1;
        let tuple = result[0][0].to_literal_sync()?;
        // return_tuple=True at lowering: outputs arrive as one tuple.
        let parts = tuple.to_tuple()?;
        if parts.len() != meta.n_outputs {
            bail!("{name}: {} outputs, manifest says {}", parts.len(), meta.n_outputs);
        }
        let mut outputs = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>()?;
            outputs.push(Output { dims, data });
        }
        Ok(outputs)
    }

    /// Number of distinct compiled executables resident.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}
