//! Persistent worker pool for the threaded hot-path kernels.
//!
//! The seed code spawned scoped threads per above-threshold matmul
//! (`std::thread::scope` in `tensor/ops.rs`), paying a clone/spawn/join
//! round trip of tens of microseconds per call — EXPERIMENTS.md §Perf
//! iteration 3 names it as the dominant remaining per-step cost. This
//! module replaces those spawns with a process-wide pool of long-lived
//! workers and a `run(n_tasks, f)` barrier with the same semantics as a
//! scope: when `run` returns, every task has finished and all writes made
//! by the tasks are visible to the caller (the mutex hand-offs provide
//! the happens-before edges).
//!
//! Design constraints, in order:
//!
//! * **Allocation-free dispatch.** The optimizer step must stay at zero
//!   heap allocations once warm (the CI alloc gate counts the calling
//!   thread). A job is published as a raw `(*const (), unsafe fn)` pair
//!   pointing at the caller's stack-held closure — no boxing, no channel
//!   nodes. Lock/wait/notify on Linux are futex-based and do not
//!   allocate.
//! * **No dangling-job races.** Workers claim task indices *under the
//!   job mutex* and only touch the closure pointer for a claim they made
//!   while the job was the active one; the submitting thread cannot
//!   return (and pop its closure off the stack) before `done == n_tasks`.
//! * **Caller participation.** The submitter claims tasks like any
//!   worker, so `run` completes even on a pool of size 1 (no workers at
//!   all) and the pool never deadlocks on its own barrier.
//! * **No nested oversubscription.** A thread-local flag marks pool
//!   threads and threads already inside `run`; a nested `run` (e.g. a
//!   threaded matmul issued from inside a cross-layer parallel optimizer
//!   step) executes inline on that thread instead of re-entering the
//!   pool. Per-task arithmetic is chunking-independent (each output row
//!   is computed with one fixed FMA order), so inlining changes nothing
//!   bit-wise — only the parallel grain.
//! * **Panic containment.** A panicking task must not wedge the pool:
//!   the job mutex is process-wide state, and a panic that unwound
//!   through a locked section would poison it, turning every later
//!   kernel call's `lock().unwrap()` into a panic cascade. Task calls
//!   run under `catch_unwind` on workers and submitter alike; the first
//!   payload cancels the job's unclaimed tasks, the barrier drains the
//!   in-flight ones, and the panic is rethrown on the submitting thread
//!   once the slot is reset — the same observable behavior as a
//!   scoped-thread join. Every lock/wait site additionally recovers from
//!   poisoning (the critical sections only do counter bookkeeping, so
//!   the state is consistent even after an unexpected unwind).
//!
//! Sizing: `GALORE_THREADS` (env var, ≥ 1) overrides the default of
//! `available_parallelism().min(16)`; `configure()` resizes at runtime
//! (used by the `threads` RunConfig knob and the parity tests, which
//! sweep 1/2/N threads in one process). One job runs at a time —
//! concurrent submitters queue on the job slot, which is exactly the
//! serialization the scoped-thread version had.

use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// The single job slot plus pool lifecycle flags, all under one mutex.
struct JobState {
    /// Borrowed pointer to the submitter's closure; valid exactly while
    /// `active` (the submitter blocks in `run` for that whole window).
    data: *const (),
    /// Monomorphized trampoline that calls `data` as its concrete `Fn`.
    call: unsafe fn(*const (), usize),
    n_tasks: usize,
    /// Next unclaimed task index (claims happen under the mutex).
    next: usize,
    /// Completed task count; `done == n_tasks` releases the submitter.
    done: usize,
    active: bool,
    shutdown: bool,
    /// First panic payload caught from a task of the current job. Set
    /// under the lock (first panic wins, later ones are dropped), taken
    /// by the submitter after the barrier and rethrown on its thread.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

// SAFETY: `data` is only dereferenced by `call` for task claims made
// while the job is active, and the closure it points to is `Sync` (bound
// enforced by `run`) and outlives the job (the submitter blocks in `run`
// until `done == n_tasks`).
unsafe impl Send for JobState {}

struct Inner {
    state: Mutex<JobState>,
    /// Workers wait here for a job (or shutdown).
    work_cv: Condvar,
    /// Submitters wait here for task completion / the job slot.
    done_cv: Condvar,
}

/// A pool of `threads - 1` long-lived workers; the submitting thread is
/// the remaining participant. `threads <= 1` means no workers — `run`
/// executes inline.
pub struct Pool {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

thread_local! {
    /// True on pool workers (always) and on any thread currently inside
    /// `Pool::run`'s parallel branch — a nested `run` sees it and
    /// executes inline instead of deadlocking on the busy job slot.
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// SAFETY: calls `data` as `&F`. Only instantiated and published by
/// `run<F>`, which keeps `F` alive and `Sync` for the job's lifetime.
unsafe fn call_as<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    unsafe { (*(data as *const F))(i) }
}

unsafe fn call_never(_: *const (), _: usize) {
    unreachable!("pool job invoked with no active closure")
}

/// Lock the job state, recovering from poisoning. Poisoning can only
/// happen if a thread unwinds while holding the lock; every critical
/// section in this module does plain counter/pointer bookkeeping, so the
/// state is consistent regardless — recovery keeps one panicking task
/// from turning the process-wide pool into a panic cascade.
fn lock_recover(m: &Mutex<JobState>) -> std::sync::MutexGuard<'_, JobState> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar wait with the same poisoning recovery as [`lock_recover`].
fn wait_recover<'a>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, JobState>,
) -> std::sync::MutexGuard<'a, JobState> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Record a task panic under the lock: keep the first payload, cancel
/// every unclaimed task (the in-flight claims still drain through the
/// barrier, which is what keeps the submitter's closure borrow sound).
fn record_panic(st: &mut JobState, payload: Box<dyn std::any::Any + Send>) {
    if st.panic.is_none() {
        st.panic = Some(payload);
    }
    st.n_tasks = st.next;
}

fn worker_loop(inner: Arc<Inner>) {
    IN_POOL.with(|f| f.set(true));
    let mut st = lock_recover(&inner.state);
    loop {
        if st.active && st.next < st.n_tasks {
            let i = st.next;
            st.next += 1;
            let (data, call) = (st.data, st.call);
            drop(st);
            // SAFETY: claimed under the lock while the job was active, so
            // the submitter is still parked in `run` and `data` is live.
            // The catch_unwind keeps a panicking task from killing this
            // worker (and from unwinding past the borrowed closure).
            let res =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe { call(data, i) }));
            st = lock_recover(&inner.state);
            if let Err(payload) = res {
                record_panic(&mut st, payload);
            }
            st.done += 1;
            if st.done >= st.n_tasks {
                inner.done_cv.notify_all();
            }
        } else if st.shutdown {
            // An active job's tasks were drained above before this arm
            // can be reached, so shutdown never strands a submitter.
            return;
        } else {
            st = wait_recover(&inner.work_cv, st);
        }
    }
}

impl Pool {
    /// Build a pool that computes with `threads` total threads (the
    /// submitter plus `threads - 1` spawned workers).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(JobState {
                data: std::ptr::null(),
                call: call_never,
                n_tasks: 0,
                next: 0,
                done: 0,
                active: false,
                shutdown: false,
                panic: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("galore-pool-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { inner, workers, threads }
    }

    /// Total computing threads (submitter included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), …, f(n_tasks - 1)` across the pool and wait for
    /// all of them — a scope-style join barrier. Tasks must write to
    /// disjoint data (same contract as the scoped-thread chunking this
    /// replaces). Dispatch performs no heap allocation.
    ///
    /// If a task panics, the job's unclaimed tasks are cancelled, the
    /// in-flight ones drain, and the first panic payload is rethrown on
    /// this thread after the slot is reset — the pool itself stays
    /// usable, exactly like a scoped-thread join.
    pub fn run<F: Fn(usize) + Sync>(&self, n_tasks: usize, f: F) {
        if n_tasks <= 1 || self.threads <= 1 || IN_POOL.with(|g| g.get()) {
            for i in 0..n_tasks {
                f(i);
            }
            return;
        }
        IN_POOL.with(|g| g.set(true));
        let inner = &*self.inner;
        let mut st = lock_recover(&inner.state);
        // One job at a time: queue for the slot like the scoped version
        // serialized on spawn/join.
        while st.active {
            st = wait_recover(&inner.done_cv, st);
        }
        st.data = &f as *const F as *const ();
        st.call = call_as::<F>;
        st.n_tasks = n_tasks;
        st.next = 0;
        st.done = 0;
        st.panic = None;
        st.active = true;
        inner.work_cv.notify_all();
        // Participate: claim tasks alongside the workers. The submitter's
        // own task calls are caught too — unwinding out of `run` while
        // workers hold claims into `f` would pop the closure from under
        // them; instead the panic is re-raised after the barrier.
        loop {
            if st.next < st.n_tasks {
                let i = st.next;
                st.next += 1;
                drop(st);
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
                st = lock_recover(&inner.state);
                if let Err(payload) = res {
                    record_panic(&mut st, payload);
                }
                st.done += 1;
            } else {
                break;
            }
        }
        while st.done < st.n_tasks {
            st = wait_recover(&inner.done_cv, st);
        }
        let panicked = st.panic.take();
        st.active = false;
        st.data = std::ptr::null();
        st.call = call_never;
        drop(st);
        // Hand the job slot to any queued submitter.
        inner.done_cv.notify_all();
        IN_POOL.with(|g| g.set(false));
        if let Some(payload) = panicked {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.inner.state);
            st.shutdown = true;
            self.inner.work_cv.notify_all();
            drop(st);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// -- process-wide pool -----------------------------------------------------

static GLOBAL: OnceLock<Mutex<Arc<Pool>>> = OnceLock::new();

fn env_threads() -> Option<usize> {
    let v = std::env::var("GALORE_THREADS").ok()?;
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => {
            eprintln!("WARNING: ignoring GALORE_THREADS={v:?} (want an integer >= 1)");
            None
        }
    }
}

/// Pool width used when nothing overrides it: `GALORE_THREADS` if set
/// (and >= 1), else `available_parallelism()` capped at 16 (the seed's
/// cap — beyond that the bandwidth-bound kernels stop scaling).
pub fn default_threads() -> usize {
    env_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
        .min(16)
        .max(1)
}

fn global() -> &'static Mutex<Arc<Pool>> {
    GLOBAL.get_or_init(|| Mutex::new(Arc::new(Pool::new(default_threads()))))
}

/// Resize the process-wide pool (no-op if already `threads` wide). Jobs
/// already submitted to the old pool finish on it; its workers drain and
/// exit once the last reference drops. Used by the `threads` run-config
/// knob and the thread-count parity tests.
pub fn configure(threads: usize) {
    let threads = threads.max(1);
    let mut g = global().lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    if g.threads() != threads {
        *g = Arc::new(Pool::new(threads));
    }
}

/// Width of the process-wide pool — what the kernels in `tensor/ops.rs`
/// split their row ranges by.
pub fn num_threads() -> usize {
    global().lock().unwrap_or_else(std::sync::PoisonError::into_inner).threads()
}

/// Run `n_tasks` tasks on the process-wide pool (see [`Pool::run`]).
/// Allocation-free on the calling thread once the pool exists.
pub fn run<F: Fn(usize) + Sync>(n_tasks: usize, f: F) {
    let pool = global().lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone();
    pool.run(n_tasks, f);
}

/// A `Send + Sync` raw-pointer wrapper for handing a mutable base pointer
/// to pool tasks that write disjoint regions (the row-chunked kernels).
/// The caller asserts disjointness; the pool's join barrier provides the
/// synchronization.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

// SAFETY: SendPtr is a plain address; the tasks sharing it write disjoint
// ranges and the submitter only reads the data after `run` returns.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = Pool::new(4);
        for n in [0usize, 1, 2, 3, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "n={n}");
        }
    }

    #[test]
    fn writes_are_visible_after_join() {
        let pool = Pool::new(3);
        let mut out = vec![0u64; 257];
        let base = SendPtr(out.as_mut_ptr());
        let chunk = 13usize;
        let n_chunks = out.len().div_ceil(chunk);
        let len = out.len();
        pool.run(n_chunks, move |t| {
            let i0 = t * chunk;
            let i1 = (i0 + chunk).min(len);
            // SAFETY: chunks are disjoint; `out` outlives the barrier.
            let dst = unsafe { std::slice::from_raw_parts_mut(base.0.add(i0), i1 - i0) };
            for (off, v) in dst.iter_mut().enumerate() {
                *v = (i0 + off) as u64 * 3 + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 * 3 + 1);
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        pool.run(4, |_| {
            // Nested: must not deadlock on the busy job slot.
            pool.run(4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        let mut hits = vec![false; 9];
        let base = SendPtr(hits.as_mut_ptr());
        pool.run(9, move |i| {
            // SAFETY: one writer per index.
            unsafe { *base.0.add(i) = true };
        });
        assert!(hits.iter().all(|&h| h));
    }

    #[test]
    fn back_to_back_jobs_reuse_the_slot() {
        let pool = Pool::new(4);
        let total = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run(8, |i| {
                total.fetch_add(i + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 100 * 36);
    }

    #[test]
    fn configure_resizes_global_pool() {
        configure(2);
        assert_eq!(num_threads(), 2);
        let total = AtomicUsize::new(0);
        run(10, |i| {
            total.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 45);
        configure(3);
        assert_eq!(num_threads(), 3);
        configure(default_threads());
    }

    #[test]
    fn panicking_task_propagates_and_pool_stays_usable() {
        let pool = Pool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
            });
        }));
        let payload = r.expect_err("the task panic must reach the submitter");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "task 3 exploded", "original payload must survive the relay");
        // The pool — and its job mutex — must stay fully usable: no
        // poisoning, no stranded workers, no stale panic payload.
        let total = AtomicUsize::new(0);
        pool.run(8, |i| {
            total.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 36);
    }

    #[test]
    fn repeated_panics_do_not_wedge_the_pool() {
        // Several jobs in a row where *every* task panics: each run must
        // rethrow exactly once and leave the slot clean for the next.
        let pool = Pool::new(3);
        for round in 0..5 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(6, |_| panic!("round {round}"));
            }));
            assert!(r.is_err(), "round {round} must surface a panic");
        }
        let total = AtomicUsize::new(0);
        pool.run(10, |i| {
            total.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn warm_dispatch_is_allocation_free() {
        let pool = Pool::new(4);
        let sink = AtomicUsize::new(0);
        pool.run(8, |i| {
            sink.fetch_add(i, Ordering::Relaxed);
        });
        let s0 = crate::coordinator::thread_alloc_stats();
        for _ in 0..10 {
            pool.run(8, |i| {
                sink.fetch_add(i, Ordering::Relaxed);
            });
        }
        let s1 = crate::coordinator::thread_alloc_stats();
        assert_eq!(s1.allocs - s0.allocs, 0, "pool dispatch allocated");
    }
}
