//! Persistent worker pool for the threaded hot-path kernels.
//!
//! The seed code spawned scoped threads per above-threshold matmul
//! (`std::thread::scope` in `tensor/ops.rs`), paying a clone/spawn/join
//! round trip of tens of microseconds per call — EXPERIMENTS.md §Perf
//! iteration 3 names it as the dominant remaining per-step cost. This
//! module replaces those spawns with a process-wide pool of long-lived
//! workers and a `run(n_tasks, f)` barrier with the same semantics as a
//! scope: when `run` returns, every task has finished and all writes made
//! by the tasks are visible to the caller (the mutex hand-offs provide
//! the happens-before edges).
//!
//! Design constraints, in order:
//!
//! * **Allocation-free dispatch.** The optimizer step must stay at zero
//!   heap allocations once warm (the CI alloc gate counts the calling
//!   thread). A job is published as a raw `(*const (), unsafe fn)` pair
//!   pointing at the caller's stack-held closure — no boxing, no channel
//!   nodes. Lock/wait/notify on Linux are futex-based and do not
//!   allocate.
//! * **No dangling-job races.** Workers claim task indices *under the
//!   job mutex* and only touch the closure pointer for a claim they made
//!   while the job was the active one; the submitting thread cannot
//!   return (and pop its closure off the stack) before `done == n_tasks`.
//! * **Caller participation.** The submitter claims tasks like any
//!   worker, so `run` completes even on a pool of size 1 (no workers at
//!   all) and the pool never deadlocks on its own barrier.
//! * **No nested oversubscription.** A thread-local flag marks pool
//!   threads and threads already inside `run`; a nested `run` (e.g. a
//!   threaded matmul issued from inside a cross-layer parallel optimizer
//!   step) executes inline on that thread instead of re-entering the
//!   pool. Per-task arithmetic is chunking-independent (each output row
//!   is computed with one fixed FMA order), so inlining changes nothing
//!   bit-wise — only the parallel grain.
//! * **Panic containment.** A panicking task must not wedge the pool:
//!   the job mutex is process-wide state, and a panic that unwound
//!   through a locked section would poison it, turning every later
//!   kernel call's `lock().unwrap()` into a panic cascade. Task calls
//!   run under `catch_unwind` on workers and submitter alike; the first
//!   payload cancels the job's unclaimed tasks, the barrier drains the
//!   in-flight ones, and the panic is rethrown on the submitting thread
//!   once the slot is reset — the same observable behavior as a
//!   scoped-thread join. Every lock/wait site additionally recovers from
//!   poisoning (the critical sections only do counter bookkeeping, so
//!   the state is consistent even after an unexpected unwind).
//!
//! Sizing: `GALORE_THREADS` (env var, ≥ 1) overrides the default of
//! `available_parallelism().min(16)`; `configure()` resizes at runtime
//! (used by the `threads` RunConfig knob and the parity tests, which
//! sweep 1/2/N threads in one process). One job runs at a time —
//! concurrent submitters queue on the job slot, which is exactly the
//! serialization the scoped-thread version had.
//!
//! Debug builds additionally run the [`sanitizer`]: tasks declare the
//! byte ranges they write (`sanitizer::claim_mut`) and the pool panics
//! if two tasks of one job claim overlapping ranges — the "tasks write
//! disjoint data" contract of `run` as an executed assertion instead of
//! a comment. Release builds compile the claims to nothing.

use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Debug-build aliasing sanitizer for pool jobs.
///
/// `Pool::run`'s soundness rests on every task writing disjoint data —
/// the contract behind [`SendPtr`] and the cross-layer parallel
/// optimizer step's per-parameter raw pointers. This module turns that
/// contract into an executed check: inside a task, call
/// [`sanitizer::claim_mut`] for each region the task writes; the claim
/// is recorded against the task's index and compared with every claim
/// made by *other* tasks of the same job, and any overlap panics with
/// both ranges named (the pool's normal panic relay carries it to the
/// submitter). Bookkeeping rules:
///
/// * Claims are per job: the registry is cleared when a job starts.
///   Parallel jobs are serialized by the single job slot, so one global
///   registry suffices; top-level *inline* jobs (1 task or a 1-thread
///   pool) use a thread-local registry so unrelated threads running
///   inline jobs concurrently cannot cross-talk.
/// * Only claims made directly inside a top-level task count. A nested
///   inline `run` (say a threaded matmul issued from inside an optimizer
///   task) operates on sub-ranges of state its enclosing task already
///   claimed; recording those would self-collide, so claims at task
///   depth > 1 are ignored.
///
/// In release builds `claim_mut` is an empty `#[inline(always)]` stub —
/// the hot path pays nothing.
#[cfg(debug_assertions)]
pub mod sanitizer {
    use std::cell::{Cell, RefCell};
    use std::sync::Mutex;

    #[derive(Clone, Copy)]
    struct Claim {
        task: usize,
        start: usize,
        end: usize,
    }

    /// Claims of the in-flight parallel job (one at a time process-wide:
    /// the job slot serializes submitters).
    static PARALLEL: Mutex<Vec<Claim>> = Mutex::new(Vec::new());

    thread_local! {
        /// Claims of this thread's current top-level inline job.
        static INLINE: RefCell<Vec<Claim>> = const { RefCell::new(Vec::new()) };
        /// 0 outside any task, 1 inside a top-level task, >1 inside a
        /// task of a nested inline job.
        static TASK_DEPTH: Cell<usize> = const { Cell::new(0) };
        /// (task index, is-parallel-job) of the enclosing top-level task.
        static CURRENT: Cell<(usize, bool)> = const { Cell::new((0, false)) };
    }

    /// Called by the submitter once the job slot is acquired (so no other
    /// parallel job's claims can still be in flight).
    pub(super) fn begin_parallel_job() {
        PARALLEL.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clear();
    }

    /// Called before an inline job's task loop. Only a *top-level* inline
    /// job (not a nested `run` inside a task) owns the thread-local
    /// registry.
    pub(super) fn begin_inline_job() {
        if TASK_DEPTH.with(|d| d.get()) == 0 {
            INLINE.with(|r| r.borrow_mut().clear());
        }
    }

    /// RAII marker for one task invocation; claims are attributed to the
    /// innermost *top-level* task. Dropped during unwinding too, so a
    /// panicking task leaves the depth consistent.
    pub(super) struct TaskScope;

    impl TaskScope {
        pub(super) fn enter(task: usize, parallel: bool) -> TaskScope {
            let depth = TASK_DEPTH.with(|d| {
                let v = d.get();
                d.set(v + 1);
                v
            });
            if depth == 0 {
                CURRENT.with(|c| c.set((task, parallel)));
            }
            TaskScope
        }
    }

    impl Drop for TaskScope {
        fn drop(&mut self) {
            TASK_DEPTH.with(|d| d.set(d.get() - 1));
        }
    }

    /// Declare that the current task writes `len` elements starting at
    /// `ptr`. Panics if the byte range overlaps a range claimed by a
    /// different task of the same job. No-op outside a top-level task
    /// (claims from nested inline jobs cover state the enclosing task
    /// already claimed) and in release builds.
    pub fn claim_mut<T>(ptr: *const T, len: usize) {
        if len == 0 || TASK_DEPTH.with(|d| d.get()) != 1 {
            return;
        }
        let (task, parallel) = CURRENT.with(|c| c.get());
        let start = ptr as usize;
        let end = start + len * std::mem::size_of::<T>();
        let check_and_push = |claims: &mut Vec<Claim>| {
            for c in claims.iter() {
                if c.task != task && start < c.end && c.start < end {
                    // PANIC-OK: the sanitizer's entire purpose — an
                    // aliasing bug must stop the debug run at the claim,
                    // not corrupt state silently. Debug builds only.
                    panic!(
                        "pool sanitizer: task {task} claims bytes {start:#x}..{end:#x} \
                         overlapping task {}'s claim {:#x}..{:#x} — tasks of one job \
                         must write disjoint state",
                        c.task, c.start, c.end
                    );
                }
            }
            claims.push(Claim { task, start, end });
        };
        if parallel {
            let mut g = PARALLEL.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            check_and_push(&mut g);
        } else {
            INLINE.with(|r| check_and_push(&mut r.borrow_mut()));
        }
    }
}

/// Release-build stub of the aliasing sanitizer: claims cost nothing.
#[cfg(not(debug_assertions))]
pub mod sanitizer {
    pub(super) fn begin_parallel_job() {}
    pub(super) fn begin_inline_job() {}
    pub(super) struct TaskScope;
    impl TaskScope {
        pub(super) fn enter(_task: usize, _parallel: bool) -> TaskScope {
            TaskScope
        }
    }
    /// See the debug-build documentation; compiles to nothing here.
    #[inline(always)]
    pub fn claim_mut<T>(_ptr: *const T, _len: usize) {}
}

/// The single job slot plus pool lifecycle flags, all under one mutex.
struct JobState {
    /// Borrowed pointer to the submitter's closure; valid exactly while
    /// `active` (the submitter blocks in `run` for that whole window).
    data: *const (),
    /// Monomorphized trampoline that calls `data` as its concrete `Fn`.
    call: unsafe fn(*const (), usize),
    n_tasks: usize,
    /// Next unclaimed task index (claims happen under the mutex).
    next: usize,
    /// Completed task count; `done == n_tasks` releases the submitter.
    done: usize,
    active: bool,
    shutdown: bool,
    /// First panic payload caught from a task of the current job. Set
    /// under the lock (first panic wins, later ones are dropped), taken
    /// by the submitter after the barrier and rethrown on its thread.
    panic: Option<Box<dyn std::any::Any + Send>>,
}

// SAFETY: `data` is only dereferenced by `call` for task claims made
// while the job is active, and the closure it points to is `Sync` (bound
// enforced by `run`) and outlives the job (the submitter blocks in `run`
// until `done == n_tasks`).
unsafe impl Send for JobState {}

struct Inner {
    state: Mutex<JobState>,
    /// Workers wait here for a job (or shutdown).
    work_cv: Condvar,
    /// Submitters wait here for task completion / the job slot.
    done_cv: Condvar,
}

/// A pool of `threads - 1` long-lived workers; the submitting thread is
/// the remaining participant. `threads <= 1` means no workers — `run`
/// executes inline.
pub struct Pool {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

thread_local! {
    /// True on pool workers (always) and on any thread currently inside
    /// `Pool::run`'s parallel branch — a nested `run` sees it and
    /// executes inline instead of deadlocking on the busy job slot.
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// SAFETY: calls `data` as `&F`. Only instantiated and published by
/// `run<F>`, which keeps `F` alive and `Sync` for the job's lifetime.
unsafe fn call_as<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    unsafe { (*(data as *const F))(i) }
}

/// SAFETY: never dereferences its argument — it exists so the idle job
/// slot holds a valid `unsafe fn` pointer instead of a dangling one, and
/// unconditionally aborts the task if reached (workers only load the
/// slot for claims made while a job is active, so it never is).
unsafe fn call_never(_: *const (), _: usize) {
    unreachable!("pool job invoked with no active closure")
}

/// Lock the job state, recovering from poisoning. Poisoning can only
/// happen if a thread unwinds while holding the lock; every critical
/// section in this module does plain counter/pointer bookkeeping, so the
/// state is consistent regardless — recovery keeps one panicking task
/// from turning the process-wide pool into a panic cascade.
fn lock_recover(m: &Mutex<JobState>) -> std::sync::MutexGuard<'_, JobState> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar wait with the same poisoning recovery as [`lock_recover`].
fn wait_recover<'a>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, JobState>,
) -> std::sync::MutexGuard<'a, JobState> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Record a task panic under the lock: keep the first payload, cancel
/// every unclaimed task (the in-flight claims still drain through the
/// barrier, which is what keeps the submitter's closure borrow sound).
fn record_panic(st: &mut JobState, payload: Box<dyn std::any::Any + Send>) {
    if st.panic.is_none() {
        st.panic = Some(payload);
    }
    st.n_tasks = st.next;
}

fn worker_loop(inner: Arc<Inner>) {
    IN_POOL.with(|f| f.set(true));
    let mut st = lock_recover(&inner.state);
    loop {
        if st.active && st.next < st.n_tasks {
            let i = st.next;
            st.next += 1;
            let (data, call) = (st.data, st.call);
            drop(st);
            // SAFETY: claimed under the lock while the job was active, so
            // the submitter is still parked in `run` and `data` is live.
            // The catch_unwind keeps a panicking task from killing this
            // worker (and from unwinding past the borrowed closure).
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _task = sanitizer::TaskScope::enter(i, true);
                unsafe { call(data, i) }
            }));
            st = lock_recover(&inner.state);
            if let Err(payload) = res {
                record_panic(&mut st, payload);
            }
            st.done += 1;
            if st.done >= st.n_tasks {
                inner.done_cv.notify_all();
            }
        } else if st.shutdown {
            // An active job's tasks were drained above before this arm
            // can be reached, so shutdown never strands a submitter.
            return;
        } else {
            st = wait_recover(&inner.work_cv, st);
        }
    }
}

impl Pool {
    /// Build a pool that computes with `threads` total threads (the
    /// submitter plus `threads - 1` spawned workers).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(JobState {
                data: std::ptr::null(),
                call: call_never,
                n_tasks: 0,
                next: 0,
                done: 0,
                active: false,
                shutdown: false,
                panic: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("galore-pool-{i}"))
                    .spawn(move || worker_loop(inner))
                    // PANIC-OK: pool construction happens at process/run
                    // startup (or an explicit `configure`), before any
                    // job state exists to lose; a host that cannot spawn
                    // threads cannot train.
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { inner, workers, threads }
    }

    /// Total computing threads (submitter included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), …, f(n_tasks - 1)` across the pool and wait for
    /// all of them — a scope-style join barrier. Tasks must write to
    /// disjoint data (same contract as the scoped-thread chunking this
    /// replaces). Dispatch performs no heap allocation.
    ///
    /// If a task panics, the job's unclaimed tasks are cancelled, the
    /// in-flight ones drain, and the first panic payload is rethrown on
    /// this thread after the slot is reset — the pool itself stays
    /// usable, exactly like a scoped-thread join.
    pub fn run<F: Fn(usize) + Sync>(&self, n_tasks: usize, f: F) {
        if n_tasks <= 1 || self.threads <= 1 || IN_POOL.with(|g| g.get()) {
            sanitizer::begin_inline_job();
            for i in 0..n_tasks {
                let _task = sanitizer::TaskScope::enter(i, false);
                f(i);
            }
            return;
        }
        IN_POOL.with(|g| g.set(true));
        let inner = &*self.inner;
        let mut st = lock_recover(&inner.state);
        // One job at a time: queue for the slot like the scoped version
        // serialized on spawn/join.
        while st.active {
            st = wait_recover(&inner.done_cv, st);
        }
        // Slot acquired: the previous parallel job fully drained, so its
        // sanitizer claims can be discarded.
        sanitizer::begin_parallel_job();
        st.data = &f as *const F as *const ();
        st.call = call_as::<F>;
        st.n_tasks = n_tasks;
        st.next = 0;
        st.done = 0;
        st.panic = None;
        st.active = true;
        inner.work_cv.notify_all();
        // Participate: claim tasks alongside the workers. The submitter's
        // own task calls are caught too — unwinding out of `run` while
        // workers hold claims into `f` would pop the closure from under
        // them; instead the panic is re-raised after the barrier.
        loop {
            if st.next < st.n_tasks {
                let i = st.next;
                st.next += 1;
                drop(st);
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let _task = sanitizer::TaskScope::enter(i, true);
                    f(i)
                }));
                st = lock_recover(&inner.state);
                if let Err(payload) = res {
                    record_panic(&mut st, payload);
                }
                st.done += 1;
            } else {
                break;
            }
        }
        while st.done < st.n_tasks {
            st = wait_recover(&inner.done_cv, st);
        }
        let panicked = st.panic.take();
        st.active = false;
        st.data = std::ptr::null();
        st.call = call_never;
        drop(st);
        // Hand the job slot to any queued submitter.
        inner.done_cv.notify_all();
        IN_POOL.with(|g| g.set(false));
        if let Some(payload) = panicked {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.inner.state);
            st.shutdown = true;
            self.inner.work_cv.notify_all();
            drop(st);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// -- process-wide pool -----------------------------------------------------

static GLOBAL: OnceLock<Mutex<Arc<Pool>>> = OnceLock::new();

fn env_threads() -> Option<usize> {
    let v = std::env::var("GALORE_THREADS").ok()?;
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n),
        _ => {
            eprintln!("WARNING: ignoring GALORE_THREADS={v:?} (want an integer >= 1)");
            None
        }
    }
}

/// Pool width used when nothing overrides it: `GALORE_THREADS` if set
/// (and >= 1), else `available_parallelism()` capped at 16 (the seed's
/// cap — beyond that the bandwidth-bound kernels stop scaling).
pub fn default_threads() -> usize {
    env_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
        .min(16)
        .max(1)
}

fn global() -> &'static Mutex<Arc<Pool>> {
    GLOBAL.get_or_init(|| Mutex::new(Arc::new(Pool::new(default_threads()))))
}

/// Resize the process-wide pool (no-op if already `threads` wide). Jobs
/// already submitted to the old pool finish on it; its workers drain and
/// exit once the last reference drops. Used by the `threads` run-config
/// knob and the thread-count parity tests.
pub fn configure(threads: usize) {
    let threads = threads.max(1);
    let mut g = global().lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    if g.threads() != threads {
        *g = Arc::new(Pool::new(threads));
    }
}

/// Width of the process-wide pool — what the kernels in `tensor/ops.rs`
/// split their row ranges by.
pub fn num_threads() -> usize {
    global().lock().unwrap_or_else(std::sync::PoisonError::into_inner).threads()
}

/// Run `n_tasks` tasks on the process-wide pool (see [`Pool::run`]).
/// Allocation-free on the calling thread once the pool exists.
pub fn run<F: Fn(usize) + Sync>(n_tasks: usize, f: F) {
    let pool = global().lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone();
    pool.run(n_tasks, f);
}

/// A `Send + Sync` raw-pointer wrapper for handing a mutable base pointer
/// to pool tasks that write disjoint regions (the row-chunked kernels).
/// The caller asserts disjointness; the pool's join barrier provides the
/// synchronization.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(pub *mut T);

// SAFETY: SendPtr is a plain address; the tasks sharing it write disjoint
// ranges and the submitter only reads the data after `run` returns.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = Pool::new(4);
        for n in [0usize, 1, 2, 3, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "n={n}");
        }
    }

    #[test]
    fn writes_are_visible_after_join() {
        let pool = Pool::new(3);
        let mut out = vec![0u64; 257];
        let base = SendPtr(out.as_mut_ptr());
        let chunk = 13usize;
        let n_chunks = out.len().div_ceil(chunk);
        let len = out.len();
        pool.run(n_chunks, move |t| {
            let i0 = t * chunk;
            let i1 = (i0 + chunk).min(len);
            // SAFETY: chunks are disjoint; `out` outlives the barrier.
            let dst = unsafe { std::slice::from_raw_parts_mut(base.0.add(i0), i1 - i0) };
            for (off, v) in dst.iter_mut().enumerate() {
                *v = (i0 + off) as u64 * 3 + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 * 3 + 1);
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = Pool::new(2);
        let total = AtomicUsize::new(0);
        pool.run(4, |_| {
            // Nested: must not deadlock on the busy job slot.
            pool.run(4, |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        let mut hits = vec![false; 9];
        let base = SendPtr(hits.as_mut_ptr());
        pool.run(9, move |i| {
            // SAFETY: one writer per index.
            unsafe { *base.0.add(i) = true };
        });
        assert!(hits.iter().all(|&h| h));
    }

    #[test]
    fn back_to_back_jobs_reuse_the_slot() {
        let pool = Pool::new(4);
        let total = AtomicUsize::new(0);
        for _ in 0..100 {
            pool.run(8, |i| {
                total.fetch_add(i + 1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 100 * 36);
    }

    #[test]
    fn configure_resizes_global_pool() {
        configure(2);
        assert_eq!(num_threads(), 2);
        let total = AtomicUsize::new(0);
        run(10, |i| {
            total.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 45);
        configure(3);
        assert_eq!(num_threads(), 3);
        configure(default_threads());
    }

    #[test]
    fn panicking_task_propagates_and_pool_stays_usable() {
        let pool = Pool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
            });
        }));
        let payload = r.expect_err("the task panic must reach the submitter");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "task 3 exploded", "original payload must survive the relay");
        // The pool — and its job mutex — must stay fully usable: no
        // poisoning, no stranded workers, no stale panic payload.
        let total = AtomicUsize::new(0);
        pool.run(8, |i| {
            total.fetch_add(i + 1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 36);
    }

    #[test]
    fn repeated_panics_do_not_wedge_the_pool() {
        // Several jobs in a row where *every* task panics: each run must
        // rethrow exactly once and leave the slot clean for the next.
        let pool = Pool::new(3);
        for round in 0..5 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(6, |_| panic!("round {round}"));
            }));
            assert!(r.is_err(), "round {round} must surface a panic");
        }
        let total = AtomicUsize::new(0);
        pool.run(10, |i| {
            total.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 45);
    }

    /// The debug aliasing sanitizer: overlapping claims from different
    /// tasks of one job must panic, in both the parallel and the inline
    /// dispatch paths; disjoint, nested, and cross-job claims must not.
    #[cfg(debug_assertions)]
    mod sanitizer_checks {
        use super::super::{sanitizer, Pool, SendPtr};

        fn catches_overlap(pool: &Pool, n_tasks: usize) -> bool {
            let mut buf = vec![0f32; 64];
            let base = SendPtr(buf.as_mut_ptr());
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.run(n_tasks, move |_| {
                    // Every task claims the SAME range: a deliberate
                    // violation of the disjointness contract.
                    sanitizer::claim_mut(base.0, 64);
                });
            }));
            match r {
                Ok(()) => false,
                Err(p) => {
                    let msg = p
                        .downcast_ref::<String>()
                        .cloned()
                        .unwrap_or_else(|| p.downcast_ref::<&str>().unwrap_or(&"").to_string());
                    assert!(msg.contains("pool sanitizer"), "unexpected panic: {msg}");
                    true
                }
            }
        }

        #[test]
        fn overlapping_tasks_are_caught_parallel() {
            let pool = Pool::new(4);
            assert!(catches_overlap(&pool, 8));
            // ...and the pool survives the sanitizer panic like any other.
            let total = std::sync::atomic::AtomicUsize::new(0);
            pool.run(4, |i| {
                total.fetch_add(i, std::sync::atomic::Ordering::Relaxed);
            });
            assert_eq!(total.load(std::sync::atomic::Ordering::Relaxed), 6);
        }

        #[test]
        fn overlapping_tasks_are_caught_inline() {
            // threads = 1: every job runs inline on the submitter, where
            // the thread-local registry does the checking.
            let pool = Pool::new(1);
            assert!(catches_overlap(&pool, 3));
        }

        #[test]
        fn disjoint_claims_pass() {
            for threads in [1usize, 2, 4] {
                let pool = Pool::new(threads);
                let mut buf = vec![0f32; 64];
                let base = SendPtr(buf.as_mut_ptr());
                pool.run(4, move |t| {
                    sanitizer::claim_mut(unsafe { base.0.add(16 * t) }, 16);
                    // SAFETY: 16-element chunks at disjoint offsets.
                    let dst = unsafe { std::slice::from_raw_parts_mut(base.0.add(16 * t), 16) };
                    dst.fill(t as f32);
                });
            }
        }

        #[test]
        fn nested_inline_claims_are_ignored() {
            // A task claims its whole range, then a nested run's tasks
            // touch sub-ranges of it (the threaded-matmul-inside-
            // optimizer-step shape). The nested claims must not
            // self-collide with the enclosing task's claim.
            let pool = Pool::new(2);
            let mut buf = vec![0f32; 32];
            let base = SendPtr(buf.as_mut_ptr());
            pool.run(2, |t| {
                // SAFETY: in-bounds offset — 16-element chunks of a
                // 32-element buffer for t in {0, 1}.
                sanitizer::claim_mut(unsafe { base.0.add(16 * t) }, 16);
                pool.run(4, |c| {
                    sanitizer::claim_mut(unsafe { base.0.add(16 * t + 4 * c) }, 4);
                    // SAFETY: disjoint 4-element sub-chunks of this
                    // task's 16-element region.
                    let dst =
                        unsafe { std::slice::from_raw_parts_mut(base.0.add(16 * t + 4 * c), 4) };
                    dst.fill((t * 4 + c) as f32);
                });
            });
        }

        #[test]
        fn claims_reset_between_jobs() {
            // Task 0 of job A and task 1 of job B may touch the same
            // range: the registry is per job, not per pool lifetime.
            for threads in [1usize, 4] {
                let pool = Pool::new(threads);
                let mut buf = vec![0f32; 8];
                let base = SendPtr(buf.as_mut_ptr());
                pool.run(2, move |t| {
                    if t == 0 {
                        sanitizer::claim_mut(base.0, 8);
                    }
                });
                pool.run(2, move |t| {
                    if t == 1 {
                        sanitizer::claim_mut(base.0, 8);
                    }
                });
            }
        }

        #[test]
        fn claims_outside_any_task_are_ignored() {
            let x = 7u64;
            sanitizer::claim_mut(&x, 1);
            sanitizer::claim_mut(&x, 1);
        }
    }

    #[test]
    fn warm_dispatch_is_allocation_free() {
        let pool = Pool::new(4);
        let sink = AtomicUsize::new(0);
        pool.run(8, |i| {
            sink.fetch_add(i, Ordering::Relaxed);
        });
        let s0 = crate::coordinator::thread_alloc_stats();
        for _ in 0..10 {
            pool.run(8, |i| {
                sink.fetch_add(i, Ordering::Relaxed);
            });
        }
        let s1 = crate::coordinator::thread_alloc_stats();
        assert_eq!(s1.allocs - s0.allocs, 0, "pool dispatch allocated");
    }
}
