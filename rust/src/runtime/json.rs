//! Minimal JSON parser (recursive descent) for the artifact manifest.
//! `serde` is unavailable offline; this covers the JSON subset aot.py
//! emits (objects, arrays, strings, numbers, bools, null) plus escapes.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("bad object at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(format!("bad array at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => s.push(c as char),
                None => return Err("eof in string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
 "artifacts": [
  {"name": "train_nano_b8", "file": "train_nano_b8.hlo.txt",
   "inputs": [[256, 64], [8, 64]], "input_dtypes": ["f32", "i32"],
   "n_outputs": 22, "kind": "train", "config": "nano", "batch": 8}
 ]
}"#;
        let j = Json::parse(doc).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        let a = &arts[0];
        assert_eq!(a.get("name").unwrap().as_str().unwrap(), "train_nano_b8");
        assert_eq!(a.get("n_outputs").unwrap().as_usize().unwrap(), 22);
        let inputs = a.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].as_arr().unwrap()[0].as_usize().unwrap(), 256);
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nbA""#).unwrap(), Json::Str("a\nbA".into()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }
}
