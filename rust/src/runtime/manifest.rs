//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Describes every HLO artifact's input shapes/dtypes and
//! output arity so the engine can marshal literals without guessing.

use super::json::Json;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    fn from_str(s: &str) -> Result<DType, String> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "i8" => Ok(DType::I8),
            other => Err(format!("unknown dtype {other}")),
        }
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub inputs: Vec<Vec<usize>>,
    pub input_dtypes: Vec<DType>,
    pub n_outputs: usize,
    /// Optional fields by kind: model config name / batch, or (m, n, r).
    pub config: Option<String>,
    pub batch: Option<usize>,
    pub m: Option<usize>,
    pub n: Option<usize>,
    pub r: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {path:?}: {e} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let gets = |k: &str| a.get(k).and_then(Json::as_str).map(str::to_string);
            let getu = |k: &str| a.get(k).and_then(Json::as_usize);
            let name = gets("name").ok_or("artifact missing name")?;
            let file = gets("file").ok_or("artifact missing file")?;
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or("artifact missing inputs")?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .map(|dims| dims.iter().filter_map(Json::as_usize).collect())
                        .ok_or("bad shape")
                })
                .collect::<Result<Vec<Vec<usize>>, _>>()?;
            let input_dtypes = a
                .get("input_dtypes")
                .and_then(Json::as_arr)
                .ok_or("artifact missing input_dtypes")?
                .iter()
                .map(|d| DType::from_str(d.as_str().unwrap_or("?")))
                .collect::<Result<Vec<_>, _>>()?;
            if inputs.len() != input_dtypes.len() {
                return Err(format!("{name}: inputs/input_dtypes length mismatch"));
            }
            artifacts.push(ArtifactMeta {
                path: dir.join(&file),
                name,
                kind: gets("kind").unwrap_or_default(),
                inputs,
                input_dtypes,
                n_outputs: getu("n_outputs").ok_or("artifact missing n_outputs")?,
                config: gets("config"),
                batch: getu("batch"),
                m: getu("m"),
                n: getu("n"),
                r: getu("r"),
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// The training artifact for a model config (any batch if unspecified).
    pub fn train_for(&self, config: &str) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "train" && a.config.as_deref() == Some(config))
    }

    pub fn eval_for(&self, config: &str) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "eval" && a.config.as_deref() == Some(config))
    }

    /// The fused GaLore-step artifact matching a (short-side m, long-side
    /// n, rank) triple.
    pub fn galore_step_for(&self, m: usize, n: usize, r: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.kind == "galore_step" && a.m == Some(m) && a.n == Some(n) && a.r == Some(r)
        })
    }

    pub fn adam_step_for(&self, m: usize, n: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "adam_step" && a.m == Some(m) && a.n == Some(n))
    }
}

/// Default artifacts directory: `$GALORE_ARTIFACTS` (historical spelling),
/// else `$GALORE_ARTIFACT_DIR` (the spelling that matches the
/// `--artifact-dir` CLI flag and `artifact_dir` config key), else
/// `./artifacts`. An explicit `RunConfig::artifact_dir` overrides all of
/// these — this is only the fallback for configs that leave it empty.
pub fn default_dir() -> PathBuf {
    std::env::var("GALORE_ARTIFACTS")
        .or_else(|_| std::env::var("GALORE_ARTIFACT_DIR"))
        .map(PathBuf::from)
        .unwrap_or_else(|_| "artifacts".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
 "artifacts": [
  {"name": "train_nano_b8", "file": "train_nano_b8.hlo.txt",
   "inputs": [[256, 64], [8, 64], [8, 64]], "input_dtypes": ["f32", "i32", "i32"],
   "n_outputs": 22, "kind": "train", "config": "nano", "batch": 8},
  {"name": "galore_step_64x172_r16", "file": "galore_step_64x172_r16.hlo.txt",
   "inputs": [[64, 172]], "input_dtypes": ["f32"],
   "n_outputs": 3, "kind": "galore_step", "m": 64, "n": 172, "r": 16}
 ]
}"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(DOC, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert!(m.by_name("train_nano_b8").is_some());
        assert!(m.train_for("nano").is_some());
        assert!(m.train_for("7b").is_none());
        let g = m.galore_step_for(64, 172, 16).unwrap();
        assert_eq!(g.path, PathBuf::from("/tmp/a/galore_step_64x172_r16.hlo.txt"));
        assert!(m.galore_step_for(64, 172, 99).is_none());
    }

    #[test]
    fn rejects_inconsistent_entries() {
        let bad = r#"{"artifacts": [{"name": "x", "file": "x.hlo.txt",
            "inputs": [[2]], "input_dtypes": ["f32", "f32"], "n_outputs": 1}]}"#;
        assert!(Manifest::parse(bad, PathBuf::from(".")).is_err());
    }
}
