//! PJRT runtime: the bridge between the Rust coordinator and the AOT HLO
//! artifacts produced by `python/compile/aot.py`.
//!
//! * [`json`] — minimal JSON parser (offline substitute for serde).
//! * [`manifest`] — the artifact manifest contract with aot.py.
//! * [`engine`] — PJRT CPU client, executable cache, literal marshalling.
//! * [`pool`] — persistent worker pool behind the threaded kernels.
//!
//! Integration tests live in `rust/tests/` (they need `make artifacts`).

pub mod engine;
pub mod json;
pub mod manifest;
pub mod pool;

pub use engine::{Engine, Input, InputStage, Output, StagedInputs};
pub use manifest::{default_dir, ArtifactMeta, DType, Manifest};
