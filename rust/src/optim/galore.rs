//! GaLore: Gradient Low-Rank Projection (the paper's core contribution,
//! §3.3/§4, Algorithms 1–2).
//!
//! [`Projector`] holds the per-parameter low-rank basis P (refreshed every
//! `T` steps from an SVD of the current gradient, Eqn. 12–13) and performs
//! `project` / `project_back`. Following §4.2, only *one* projection matrix
//! is kept: the short side of the gradient is projected (`Pᵀ G` when
//! m ≤ n, `G Q` otherwise), so state is `r·min(m,n)` for P plus the inner
//! optimizer's compact statistics.
//!
//! [`GaLore<O>`] wraps **any** [`Optimizer`] (Algorithm 1: it is optimizer-
//! agnostic): gradients of targeted parameters are projected into the
//! compact space, the inner optimizer runs there, and the normalized update
//! is projected back and applied with scale α. Untargeted parameters
//! (embeddings, norms, lm_head — matching §5.1) pass through at full rank.
//!
//! **Step backends** (`optim::backend`): `GaLore<O>` owns every subspace
//! decision — refresh cadence, randomized SVD, rank schedules, the
//! lazy-refresh gate — and delegates the compact update itself to a
//! pluggable [`StepBackend`]: the pure-Rust tail by default
//! ([`RustBackend`]), or the fused Pallas/HLO AOT kernels
//! ([`backend::ArtifactBackend`](super::backend::ArtifactBackend)) via
//! [`GaLore::with_backend`]. Both substrates update the *same* inner
//! moments, so the one `step`/`step_compact`/`save_state`/`remap_state`/
//! `grad_reduce_mode` surface composes identically on either — there is
//! no separate "fused optimizer" type.
//!
//! Hot-path contract (EXPERIMENTS.md §Perf): the steady-state `step` on a
//! targeted parameter performs **zero heap allocations**. Every per-step
//! matrix (`Pᵀ G`, the inner-optimizer scratch, `P N`) lives in a
//! per-parameter `Workspace`; the basis is exposed by borrow (the Quant8
//! store keeps a dequantized cache that is invalidated only on subspace
//! refresh); and the periodic refresh itself runs through a shared
//! [`SvdWorkspace`] so even the every-`T`-steps path stops allocating once
//! warm.

use super::adaptive::{basis_transition_into, RankState, StateRemap};
use super::backend::{RustBackend, StepBackend, StepCtx, StepScratch};
use super::rank::{subspace_cosine, RankSchedule, RankScheduleKind, RefreshGate};
use super::{Adam, AdamConfig, GradReduceMode, Optimizer};
use crate::linalg::{
    extract_left_subspace_into, randomized_svd, sketch_left_subspace_into,
    top_r_left_subspace_into, SvdWorkspace, SKETCH_OVERSAMPLE,
};
use crate::quant::DynQuantBuf;
use crate::rng::Rng;
use crate::runtime::pool;
use crate::ser;
use crate::tensor::{matmul_a_bt_into, matmul_at_b_into, matmul_into, Matrix};
use std::collections::{HashMap, HashSet};

/// How the projection basis P is stored (the §7 future-work item (2),
/// "low-memory projection matrices", generalized): full precision, the
/// linear absmax int8 grid (`quant::block8`), or the dynamic-tree int8
/// code (`quant::dynamic`) that spends bits logarithmically and keeps the
/// small entries of a near-orthonormal basis at fine relative precision,
/// or the packed int4 grid (`quant::int4`) Q-GaLore trains with.
/// All variants cost the same per step: projections run against a
/// dequantized cache rebuilt only at subspace refresh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectorQuant {
    /// 4 bytes/element (the paper's setting).
    F32,
    /// 1 byte/element, linear absmax blocks.
    Block8,
    /// 1 byte/element, dynamic (logarithmic) code — Q-GaLore-style.
    Dyn8,
    /// 0.5 byte/element packed nibbles — the Q-GaLore INT4 projector.
    Int4,
}

impl ProjectorQuant {
    pub fn parse(s: &str) -> Option<ProjectorQuant> {
        Some(match s {
            "f32" | "none" => ProjectorQuant::F32,
            "block8" | "q8" | "int8" => ProjectorQuant::Block8,
            "dyn8" | "dynamic8" => ProjectorQuant::Dyn8,
            "int4" | "q4" => ProjectorQuant::Int4,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            ProjectorQuant::F32 => "f32",
            ProjectorQuant::Block8 => "block8",
            ProjectorQuant::Dyn8 => "dyn8",
            ProjectorQuant::Int4 => "int4",
        }
    }
}

/// Which side of the gradient is projected (§4.2: always the short one).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjSide {
    /// R = Pᵀ G, P ∈ R^{m×r} (used when m ≤ n). Compact shape (r, n).
    Left,
    /// R = G Q, Q ∈ R^{n×r} (used when m > n). Compact shape (m, r).
    Right,
}

/// Storage for the projection basis. `Quant8` implements the paper's §7
/// future-work item (2) — "further enhancing memory efficiency by
/// employing low-memory projection matrices": P is held block-quantized at
/// 1 byte/element (Theorem 3.8 tolerates the perturbation since it holds
/// for any fixed near-orthonormal P). The dequantized values are cached in
/// `cache` so the per-step projections never re-dequantize; the cache is
/// rebuilt only when the subspace is refreshed. `cache` is working memory
/// (excluded from `nbytes`, like the per-call dequantized temporary the
/// allocating path used to create).
#[derive(Clone, Debug)]
enum BasisStore {
    F32(Matrix),
    Quant8 { buf: crate::quant::QuantizedBuf, cache: Matrix },
    Dyn8 { buf: DynQuantBuf, cache: Matrix },
    Int4 { buf: crate::quant::Int4Buf, cache: Matrix },
}

/// The low-rank projector for one parameter.
#[derive(Clone, Debug)]
pub struct Projector {
    pub side: ProjSide,
    store: BasisStore,
    pub rank: usize,
}

impl Projector {
    /// Compute a fresh projector from the current gradient via randomized
    /// truncated SVD (Eqn. 12–13). Chooses the side by shape and clamps the
    /// rank to min(m, n).
    pub fn compute(grad: &Matrix, rank: usize, rng: &mut Rng) -> Projector {
        Self::compute_with(grad, rank, rng, ProjectorQuant::F32)
    }

    /// As `compute`, choosing how the basis is stored.
    pub fn compute_with(
        grad: &Matrix,
        rank: usize,
        rng: &mut Rng,
        quant: ProjectorQuant,
    ) -> Projector {
        let (m, n) = grad.shape();
        let r = rank.min(m).min(n).max(1);
        let (side, basis) = if m <= n {
            (ProjSide::Left, randomized_svd(grad, r, 2, rng).u)
        } else {
            // Right projector: top-r *right* singular vectors = top-r left
            // singular vectors of Gᵀ.
            (ProjSide::Right, randomized_svd(&grad.transpose(), r, 2, rng).u)
        };
        let store = match quant {
            ProjectorQuant::F32 => BasisStore::F32(basis),
            ProjectorQuant::Block8 => {
                let buf = crate::quant::quantize(&basis.data);
                // The cache must hold the *dequantized* values — projections
                // see exactly what the quantized store represents.
                let cache =
                    Matrix::from_vec(basis.rows, basis.cols, crate::quant::dequantize(&buf));
                BasisStore::Quant8 { buf, cache }
            }
            ProjectorQuant::Dyn8 => {
                let mut buf = DynQuantBuf::zeros(basis.len(), true);
                buf.quantize_from(&basis.data);
                let mut cache = basis;
                buf.dequantize_into(&mut cache.data);
                BasisStore::Dyn8 { buf, cache }
            }
            ProjectorQuant::Int4 => {
                let buf = crate::quant::quantize4(&basis.data);
                let mut cache = basis;
                crate::quant::dequantize4_into(&buf, &mut cache.data);
                BasisStore::Int4 { buf, cache }
            }
        };
        Projector { side, store, rank: r }
    }

    /// Recompute the subspace from the current gradient **in place**,
    /// reusing the stored basis buffers and the caller's SVD workspace
    /// (`scratch_t` stages Gᵀ for Right-side parameters). This is the
    /// steady-state refresh path: zero allocations once everything is warm.
    /// For the Quant8 store this is the only point where the dequantized
    /// cache is rebuilt (cache invalidation on subspace refresh).
    pub fn refresh_with(
        &mut self,
        grad: &Matrix,
        rank: usize,
        rng: &mut Rng,
        ws: &mut SvdWorkspace,
        scratch_t: &mut Matrix,
    ) {
        let (m, n) = grad.shape();
        let r = rank.min(m).min(n).max(1);
        self.rank = r;
        self.side = if m <= n { ProjSide::Left } else { ProjSide::Right };
        let target = match &mut self.store {
            BasisStore::F32(b) => b,
            BasisStore::Quant8 { cache, .. }
            | BasisStore::Dyn8 { cache, .. }
            | BasisStore::Int4 { cache, .. } => cache,
        };
        match self.side {
            ProjSide::Left => top_r_left_subspace_into(grad, r, rng, ws, target),
            ProjSide::Right => {
                grad.transpose_into(scratch_t);
                top_r_left_subspace_into(scratch_t, r, rng, ws, target);
            }
        }
        self.requantize_cache();
    }

    /// Adaptive refresh (`optim::rank` policies): re-sketch the subspace
    /// at the current rank plus the standard oversampling, let `schedule`
    /// pick the new rank from the sketch's squared singular spectrum, and
    /// materialize the basis at that rank — all in place. Zero heap
    /// allocations once warm: rank growth is bounded by the schedule's
    /// ceiling, the basis buffer was created at that ceiling, shrinking
    /// never reallocates, and `GaLore::step` pre-warms the remap and
    /// extraction buffers at their worst-case shapes before the first
    /// adaptive refresh. Returns the rank selected.
    pub fn refresh_ranked_with(
        &mut self,
        grad: &Matrix,
        schedule: &RankSchedule,
        rng: &mut Rng,
        ws: &mut SvdWorkspace,
        scratch_t: &mut Matrix,
    ) -> usize {
        let (m, n) = grad.shape();
        let min_dim = m.min(n);
        let cur = schedule.clamp(self.rank.max(1), min_dim);
        let k = (cur + SKETCH_OVERSAMPLE).min(min_dim);
        self.side = if m <= n { ProjSide::Left } else { ProjSide::Right };
        match self.side {
            ProjSide::Left => sketch_left_subspace_into(grad, k, rng, ws),
            ProjSide::Right => {
                grad.transpose_into(scratch_t);
                sketch_left_subspace_into(scratch_t, k, rng, ws);
            }
        }
        let r_new = schedule.next_rank(cur, min_dim, ws.sq_spectrum()).min(k).max(1);
        let target = match &mut self.store {
            BasisStore::F32(b) => b,
            BasisStore::Quant8 { cache, .. }
            | BasisStore::Dyn8 { cache, .. }
            | BasisStore::Int4 { cache, .. } => cache,
        };
        extract_left_subspace_into(r_new, ws, target);
        self.rank = r_new;
        self.requantize_cache();
        r_new
    }

    /// Re-quantize the basis cache into the 8-bit store after a refresh,
    /// resizing the quantized buffer in place when the rank changed
    /// (shrinking never reallocates). The round-trip through the store
    /// keeps the cache holding exactly what the store represents.
    fn requantize_cache(&mut self) {
        match &mut self.store {
            BasisStore::F32(_) => {}
            BasisStore::Quant8 { buf, cache } => {
                if buf.len != cache.len() {
                    buf.resize(cache.len());
                }
                crate::quant::quantize_into(&cache.data, buf);
                crate::quant::dequantize_into(buf, &mut cache.data);
            }
            BasisStore::Dyn8 { buf, cache } => {
                if buf.len != cache.len() {
                    buf.resize(cache.len());
                }
                buf.quantize_from(&cache.data);
                buf.dequantize_into(&mut cache.data);
            }
            BasisStore::Int4 { buf, cache } => {
                if buf.len != cache.len() {
                    buf.resize(cache.len());
                }
                crate::quant::quantize4_into(&cache.data, buf);
                crate::quant::dequantize4_into(buf, &mut cache.data);
            }
        }
    }

    /// The materialized basis, by borrow: (m, r) for Left, (n, r) for
    /// Right. For the Quant8 store this is the dequantized cache — valid
    /// until the next subspace refresh; no per-call dequantization.
    pub fn basis(&self) -> &Matrix {
        match &self.store {
            BasisStore::F32(b) => b,
            BasisStore::Quant8 { cache, .. }
            | BasisStore::Dyn8 { cache, .. }
            | BasisStore::Int4 { cache, .. } => cache,
        }
    }

    pub fn is_quantized(&self) -> bool {
        !matches!(self.store, BasisStore::F32(_))
    }

    /// How the basis is stored.
    pub fn quant(&self) -> ProjectorQuant {
        match &self.store {
            BasisStore::F32(_) => ProjectorQuant::F32,
            BasisStore::Quant8 { .. } => ProjectorQuant::Block8,
            BasisStore::Dyn8 { .. } => ProjectorQuant::Dyn8,
            BasisStore::Int4 { .. } => ProjectorQuant::Int4,
        }
    }

    /// Project the full gradient into the compact space (allocating
    /// wrapper over [`Projector::project_into`]).
    pub fn project(&self, grad: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.project_into(grad, &mut out);
        out
    }

    /// Project into a caller-provided buffer — allocation-free once warm.
    pub fn project_into(&self, grad: &Matrix, out: &mut Matrix) {
        let basis = self.basis();
        match self.side {
            ProjSide::Left => matmul_at_b_into(basis, grad, out), // (r, n)
            ProjSide::Right => matmul_into(grad, basis, out),     // (m, r)
        }
    }

    /// Expand a compact update back to the full weight shape (allocating
    /// wrapper over [`Projector::project_back_into`]).
    pub fn project_back(&self, compact: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.project_back_into(compact, &mut out);
        out
    }

    /// Expand into a caller-provided buffer — allocation-free once warm.
    pub fn project_back_into(&self, compact: &Matrix, out: &mut Matrix) {
        let basis = self.basis();
        match self.side {
            ProjSide::Left => matmul_into(basis, compact, out), // (m, n)
            ProjSide::Right => matmul_a_bt_into(compact, basis, out), // (m, n)
        }
    }

    /// Compact-space shape for a full gradient of shape (m, n).
    pub fn compact_shape(&self, m: usize, n: usize) -> (usize, usize) {
        match self.side {
            ProjSide::Left => (self.rank, n),
            ProjSide::Right => (m, self.rank),
        }
    }

    /// Bytes held by the projection matrix itself.
    pub fn nbytes(&self) -> usize {
        match &self.store {
            BasisStore::F32(b) => 4 * b.len(),
            BasisStore::Quant8 { buf, .. } => buf.nbytes(),
            BasisStore::Dyn8 { buf, .. } => buf.nbytes(),
            BasisStore::Int4 { buf, .. } => buf.nbytes(),
        }
    }

    /// Checkpoint v2: side, rank, and the basis store. Quantized stores
    /// serialize the int8 codes + scales only; the dequantized cache is
    /// rebuilt on load and is bit-identical because the live cache always
    /// holds exactly `dequantize(store)` (see `requantize_cache`).
    pub fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_u8(out, match self.side {
            ProjSide::Left => 0,
            ProjSide::Right => 1,
        });
        ser::put_u64(out, self.rank as u64);
        match &self.store {
            BasisStore::F32(b) => {
                ser::put_u8(out, 0);
                ser::put_matrix(out, b);
            }
            BasisStore::Quant8 { buf, cache } => {
                ser::put_u8(out, 1);
                ser::put_u32(out, cache.rows as u32);
                ser::put_u32(out, cache.cols as u32);
                ser::put_quant_buf(out, buf);
            }
            BasisStore::Dyn8 { buf, cache } => {
                ser::put_u8(out, 2);
                ser::put_u32(out, cache.rows as u32);
                ser::put_u32(out, cache.cols as u32);
                ser::put_dyn_quant_buf(out, buf);
            }
            BasisStore::Int4 { buf, cache } => {
                ser::put_u8(out, 3);
                ser::put_u32(out, cache.rows as u32);
                ser::put_u32(out, cache.cols as u32);
                ser::put_int4_buf(out, buf);
            }
        }
    }

    /// Rebuild a projector from [`Projector::save_state`] bytes.
    pub fn load_state(r: &mut ser::Reader<'_>) -> Result<Projector, String> {
        let side = match r.u8()? {
            0 => ProjSide::Left,
            1 => ProjSide::Right,
            other => return Err(format!("bad projector side tag {other}")),
        };
        let rank = r.u64()? as usize;
        let store = match r.u8()? {
            0 => BasisStore::F32(r.matrix()?),
            1 => {
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let buf = r.quant_buf()?;
                if buf.len != rows * cols {
                    return Err(format!(
                        "quant8 basis has {} elements for a {rows}x{cols} cache",
                        buf.len
                    ));
                }
                let cache = Matrix::from_vec(rows, cols, crate::quant::dequantize(&buf));
                BasisStore::Quant8 { buf, cache }
            }
            2 => {
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let buf = r.dyn_quant_buf()?;
                if buf.len != rows * cols {
                    return Err(format!(
                        "dyn8 basis has {} elements for a {rows}x{cols} cache",
                        buf.len
                    ));
                }
                let mut cache = Matrix::zeros(rows, cols);
                buf.dequantize_into(&mut cache.data);
                BasisStore::Dyn8 { buf, cache }
            }
            3 => {
                let rows = r.u32()? as usize;
                let cols = r.u32()? as usize;
                let buf = r.int4_buf()?;
                if buf.len != rows * cols {
                    return Err(format!(
                        "int4 basis has {} elements for a {rows}x{cols} cache",
                        buf.len
                    ));
                }
                let mut cache = Matrix::zeros(rows, cols);
                crate::quant::dequantize4_into(&buf, &mut cache.data);
                BasisStore::Int4 { buf, cache }
            }
            other => return Err(format!("bad projector store tag {other}")),
        };
        Ok(Projector { side, store, rank })
    }
}

#[derive(Clone, Copy, Debug)]
pub struct GaLoreConfig {
    /// Subspace rank r — the initial rank and the ceiling for adaptive
    /// schedules. Must not exceed `min(m, n)` of any targeted parameter
    /// (`RunConfig::validate` rejects it; projector construction clamps
    /// defensively).
    pub rank: usize,
    /// Subspace change frequency T (§4.1; paper default 200). Must be >= 1
    /// — validated by `RunConfig::validate` and asserted in `GaLore::new`.
    pub update_freq: u64,
    /// Scale factor α on the projected-back update (§4.4; paper 0.25).
    pub scale: f32,
    /// How the projection basis is stored (§7 future work (2): low-memory
    /// projection matrices). The 8-bit stores quarter the projector
    /// memory; dequantization happens once per subspace refresh, not per
    /// step.
    pub projector_quant: ProjectorQuant,
    /// Per-layer rank policy applied at subspace-refresh boundaries
    /// (`optim::rank` — see its module docs for choosing one).
    pub rank_schedule: RankScheduleKind,
    /// Lower rank bound for the adaptive schedules.
    pub rank_floor: usize,
    /// Multiplicative rank factor per refresh (`decay` schedule).
    pub rank_decay: f32,
    /// Cumulative-energy target in (0, 1] (`spectral` schedule).
    pub rank_energy: f32,
    /// Cosine threshold for the lazy-refresh gate (0 disables): at a
    /// refresh boundary, skip the SVD when the cached subspace still
    /// captures this fraction of the gradient norm (Q-GaLore-style).
    pub refresh_gate_cos: f32,
}

impl Default for GaLoreConfig {
    fn default() -> Self {
        GaLoreConfig {
            rank: 128,
            update_freq: 200,
            scale: 0.25,
            projector_quant: ProjectorQuant::F32,
            rank_schedule: RankScheduleKind::Fixed,
            rank_floor: 4,
            rank_decay: 0.5,
            rank_energy: 0.99,
            refresh_gate_cos: 0.0,
        }
    }
}

impl GaLoreConfig {
    /// Reject configs that would fault at step time (`t % update_freq`
    /// divides by zero when `update_freq == 0`) or drive the rank
    /// policies out of their domains.
    pub fn validate(&self) -> Result<(), String> {
        if self.update_freq == 0 {
            return Err(
                "galore.update_freq must be >= 1 (the subspace refresh period T; \
                 0 would divide by zero in GaLore::step)"
                    .into(),
            );
        }
        if self.rank == 0 {
            return Err("galore.rank must be >= 1".into());
        }
        if self.rank_floor == 0 {
            return Err("galore.rank_floor must be >= 1".into());
        }
        if self.rank_floor > self.rank {
            return Err(format!(
                "galore.rank_floor = {} exceeds galore.rank = {} (the floor must sit \
                 at or below the initial rank)",
                self.rank_floor, self.rank
            ));
        }
        if !(self.rank_decay > 0.0 && self.rank_decay <= 1.0) {
            return Err(format!(
                "galore.rank_decay = {} must be in (0, 1]",
                self.rank_decay
            ));
        }
        if !(self.rank_energy > 0.0 && self.rank_energy <= 1.0) {
            return Err(format!(
                "galore.rank_energy = {} must be in (0, 1]",
                self.rank_energy
            ));
        }
        if !(0.0..1.0).contains(&self.refresh_gate_cos) {
            return Err(format!(
                "galore.refresh_gate_cos = {} must be in [0, 1) (0 disables the gate; \
                 cosines never exceed 1, so a threshold of 1 would disable refresh \
                 detection silently)",
                self.refresh_gate_cos
            ));
        }
        Ok(())
    }

    /// Reject a rank that exceeds the short side of a target matrix
    /// (called by `RunConfig::validate` with every projection target; the
    /// projector also clamps defensively at construction).
    pub fn validate_for_shape(&self, rows: usize, cols: usize, name: &str) -> Result<(), String> {
        let min_dim = rows.min(cols);
        if self.rank > min_dim {
            return Err(format!(
                "galore.rank = {} exceeds min(m, n) = {min_dim} for target parameter \
                 '{name}' ({rows}x{cols}); the projector rank cannot exceed the short \
                 side — use rank <= {min_dim}",
                self.rank
            ));
        }
        Ok(())
    }

    /// The rank schedule this config describes.
    pub fn schedule(&self) -> RankSchedule {
        RankSchedule {
            kind: self.rank_schedule,
            max_rank: self.rank,
            floor: self.rank_floor.min(self.rank).max(1),
            decay: self.rank_decay,
            energy: self.rank_energy,
        }
    }

    /// The lazy-refresh gate this config describes.
    pub fn refresh_gate(&self) -> RefreshGate {
        RefreshGate { threshold: self.refresh_gate_cos }
    }

    pub fn is_adaptive(&self) -> bool {
        self.rank_schedule != RankScheduleKind::Fixed
    }
}

/// Per-parameter reusable buffers for the projected step: the backend's
/// [`StepScratch`] (`Pᵀ G`, the inner-optimizer scratch weight, the
/// projected-back update), (for tall parameters) the Gᵀ staging used by
/// the refresh, and the rank-adaptation buffers (outgoing-basis copy,
/// basis-transition matrices, moment-remap scratch). Working memory, not
/// optimizer state.
struct Workspace {
    step: StepScratch,
    grad_t: Matrix,
    prev_basis: Matrix,
    trans: Matrix,
    trans_sq: Matrix,
    remap_scratch: Matrix,
    /// Rank-adaptation buffers warmed at worst-case shapes (set once).
    adaptive_warm: bool,
}

impl Workspace {
    fn new() -> Self {
        Workspace {
            step: StepScratch::new(),
            grad_t: Matrix::zeros(0, 0),
            prev_basis: Matrix::zeros(0, 0),
            trans: Matrix::zeros(0, 0),
            trans_sq: Matrix::zeros(0, 0),
            remap_scratch: Matrix::zeros(0, 0),
            adaptive_warm: false,
        }
    }

    /// Warm the rank-adaptation buffers at their worst-case shapes, once
    /// per parameter: a schedule that shrinks the rank and later *grows*
    /// it back (spectral) then stays allocation-free, because `Vec`
    /// capacity persists across the shrinks in between. Contents are
    /// scratch; every user overwrites via `resize`/`copy_from`.
    fn warm_adaptive(&mut self, short: usize, long: usize, max_rank: usize) {
        self.prev_basis.resize(short, max_rank);
        self.trans.resize(max_rank, max_rank);
        self.trans_sq.resize(max_rank, max_rank);
        self.remap_scratch.resize(max_rank, long);
        self.adaptive_warm = true;
    }
}

/// How a queued parallel-step entry executes ([`GaLore::step_many`] /
/// `step_planned` pass A → pass B).
#[derive(Clone, Copy, PartialEq, Eq)]
enum ParKind {
    /// Steady-state target: project the full gradient inside the task.
    Targeted,
    /// Untargeted parameter: full-rank `Adam::step` replication.
    FullRank,
    /// Steady-state target whose gradient arrives *already projected*
    /// (the DP compact-reduce path through `step_planned`): the task
    /// skips the projection and runs the `step_compact` tail.
    PreProjected,
}

/// One queued parameter update for the cross-layer parallel step
/// ([`GaLore::step_many`]): raw pointers into state that the caller's
/// `&mut self` / `&mut [Matrix]` borrows keep exclusively owned for the
/// whole pool barrier. Every queued task touches a distinct parameter
/// index, so all pointees are pairwise disjoint; the pool's join barrier
/// provides the happens-before edge back to the submitter.
struct ParTask {
    w: *mut Matrix,
    grad: *const Matrix,
    /// Null for an untargeted (full-rank pass-through) parameter.
    proj: *const Projector,
    /// Null exactly when `proj` is null.
    scratch: *mut StepScratch,
    m: *mut Matrix,
    v: *mut Matrix,
    upd: *mut Matrix,
    t: *mut u64,
    /// Signed factor on the update applied to `w`: `lr * scale` for a
    /// targeted parameter (the scratch holds `-N_t`), `-lr` full-rank.
    lr_apply: f32,
    /// `grad` already holds the compact (projected, DP-averaged)
    /// gradient; skip the projection ([`ParKind::PreProjected`]).
    pre_projected: bool,
}

// SAFETY: the pointers are captured from `&mut` borrows the submitter
// holds across the barrier, tasks are per-parameter disjoint, and nothing
// is dereferenced after `run` returns (`par_tasks` is cleared next call).
unsafe impl Send for ParTask {}
unsafe impl Sync for ParTask {}

impl ParTask {
    /// Apply this parameter's update — the paper-default-Adam replication
    /// of the shared `optim::backend::compact_tail` (targeted) or of
    /// `Adam::step` (full-rank), call-for-call so the result is
    /// bit-identical to the sequential path. Only sound to call while the
    /// submitting `step_many` is parked on the pool barrier.
    fn run(&self) {
        // Debug-build aliasing sanitizer: declare every state object this
        // task writes (the Matrix/StepScratch headers and the step
        // counter — stable addresses for the whole task, unlike the heap
        // buffers, which resize at refreshes). Two tasks handed the same
        // parameter state panic here instead of racing. Free in release.
        pool::sanitizer::claim_mut(self.w, 1);
        pool::sanitizer::claim_mut(self.m, 1);
        pool::sanitizer::claim_mut(self.v, 1);
        pool::sanitizer::claim_mut(self.upd, 1);
        pool::sanitizer::claim_mut(self.t, 1);
        if !self.scratch.is_null() {
            pool::sanitizer::claim_mut(self.scratch, 1);
        }
        // SAFETY: see the struct docs — exclusive, disjoint, live for the
        // duration of the barrier this runs under.
        unsafe {
            let w = &mut *self.w;
            let grad = &*self.grad;
            let (m, v, upd) = (&mut *self.m, &mut *self.v, &mut *self.upd);
            let t = &mut *self.t;
            *t += 1;
            if self.proj.is_null() {
                // Full-rank pass-through: exactly `Adam::step` (the
                // moments borrow asserts paper defaults, no decay).
                Adam::normalized_update_into(m, v, grad, *t, &AdamConfig::default(), upd);
                w.axpy(self.lr_apply, upd);
            } else if self.pre_projected {
                // DP compact path (`step_planned`): `grad` *is* the
                // already-averaged compact gradient, so skip the
                // projection and run the same tail `step_compact`
                // reaches through the Rust backend — call-for-call.
                let proj = &*self.proj;
                let scr = &mut *self.scratch;
                Adam::normalized_update_into(m, v, grad, *t, &AdamConfig::default(), upd);
                scr.scratch.resize(grad.rows, grad.cols);
                scr.scratch.data.fill(0.0);
                scr.scratch.axpy(-1.0, upd);
                proj.project_back_into(&scr.scratch, &mut scr.full_update);
                w.axpy(self.lr_apply, &scr.full_update);
            } else {
                let proj = &*self.proj;
                let scr = &mut *self.scratch;
                // `compact_tail` with `inner.step(…, lr=1)` inlined: the
                // zeroed scratch then holds -N_t, projected back and
                // applied with +lr·α — the same axpy call sequence.
                proj.project_into(grad, &mut scr.compact_grad);
                Adam::normalized_update_into(
                    m,
                    v,
                    &scr.compact_grad,
                    *t,
                    &AdamConfig::default(),
                    upd,
                );
                scr.scratch.resize(scr.compact_grad.rows, scr.compact_grad.cols);
                scr.scratch.data.fill(0.0);
                scr.scratch.axpy(-1.0, upd);
                proj.project_back_into(&scr.scratch, &mut scr.full_update);
                w.axpy(self.lr_apply, &scr.full_update);
            }
        }
    }
}

/// GaLore wrapper around an arbitrary inner optimizer.
pub struct GaLore<O: Optimizer> {
    pub cfg: GaLoreConfig,
    inner: O,
    /// Parameters to project. Empty set => project every 2-D parameter
    /// whose min dimension exceeds the rank (test convenience); trainers
    /// always set this explicitly to attention+FFN weights.
    targets: HashSet<usize>,
    explicit_targets: bool,
    projectors: HashMap<usize, Projector>,
    steps: HashMap<usize, u64>,
    workspaces: HashMap<usize, Workspace>,
    rank_states: HashMap<usize, RankState>,
    svd_ws: SvdWorkspace,
    rng: Rng,
    /// Execution substrate for the compact update (`optim::backend`):
    /// pure Rust by default, the AOT artifacts via [`GaLore::with_backend`].
    /// Backends are stateless by contract (they write through the inner
    /// optimizer's moments), so this field never appears in `save_state`.
    backend: Box<dyn StepBackend>,
    /// Cross-layer parallel-step bookkeeping ([`GaLore::step_many`] and
    /// the `step_planned` bucket path): queued `(index, kind)` entries
    /// and the raw-pointer task records handed to the worker pool.
    /// Working memory — cleared every call, capacity persists, so the
    /// parallel step allocates nothing once warm. Never serialized (the
    /// pointers are only live inside one call).
    par_plan: Vec<(usize, ParKind)>,
    par_tasks: Vec<ParTask>,
}

/// Default projector-RNG seed tag; mixed with the run seed in
/// [`GaLore::with_seed`] so refresh sketches are reproducible per run.
const PROJECTOR_SEED_TAG: u64 = 0x6A10E;

/// Under an *adaptive* schedule the lazy-refresh gate may not starve the
/// rank policy: a gradient that stays inside the cached subspace keeps
/// the cosine high even after its spectral rank collapses, and only a
/// real sketch can see that. After this many back-to-back skips a refresh
/// (and rank decision) is forced — Q-GaLore-style bounded laziness. Fixed
/// schedules are unaffected (a collinear basis is all they need).
const MAX_ADAPTIVE_GATE_SKIPS: u64 = 3;

impl<O: Optimizer> GaLore<O> {
    pub fn new(cfg: GaLoreConfig, inner: O) -> Self {
        assert!(
            cfg.update_freq >= 1,
            "GaLoreConfig.update_freq must be >= 1 (subspace refresh period T)"
        );
        GaLore {
            cfg,
            inner,
            targets: HashSet::new(),
            explicit_targets: false,
            projectors: HashMap::new(),
            steps: HashMap::new(),
            workspaces: HashMap::new(),
            rank_states: HashMap::new(),
            svd_ws: SvdWorkspace::new(),
            rng: Rng::new(PROJECTOR_SEED_TAG),
            backend: Box::new(RustBackend),
            par_plan: Vec::new(),
            par_tasks: Vec::new(),
        }
    }

    /// Select the execution substrate for the compact update (the
    /// [`StepBackend`] contract): `RustBackend` (the default) or
    /// `ArtifactBackend` for the fused AOT kernels. Everything else —
    /// targets, schedules, gating, checkpoints, the DP plan — is backend-
    /// independent, so this is the *only* line that differs between a
    /// "fused" and an unfused run.
    pub fn with_backend(mut self, backend: Box<dyn StepBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// The active backend's name ("rust" / "artifact").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Restrict projection to these parameter ids (attention + FFN weights,
    /// per §5.1 — embeddings / norms / lm_head stay full-rank).
    pub fn with_targets(mut self, targets: impl IntoIterator<Item = usize>) -> Self {
        self.targets = targets.into_iter().collect();
        self.explicit_targets = true;
        self
    }

    /// Seed the projector-refresh RNG from the run seed (`RunConfig.seed`),
    /// so subspace sketches — and therefore whole runs — are reproducible.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.rng = Rng::new(seed ^ PROJECTOR_SEED_TAG);
        self
    }

    fn is_target(&self, param: usize, grad: &Matrix) -> bool {
        if self.explicit_targets {
            return self.targets.contains(&param);
        }
        grad.rows > 1 && grad.cols > 1 && grad.rows.min(grad.cols) > self.cfg.rank
    }

    /// Current projector for a parameter (None until its first step).
    pub fn projector(&self, param: usize) -> Option<&Projector> {
        self.projectors.get(&param)
    }

    /// Rank-adaptation bookkeeping for a parameter (None until its first
    /// step; gate/refresh counters stay zero for non-adaptive runs).
    pub fn rank_state(&self, param: usize) -> Option<&RankState> {
        self.rank_states.get(&param)
    }

    pub fn inner(&self) -> &O {
        &self.inner
    }
}

impl<O: Optimizer> Optimizer for GaLore<O> {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        if !self.is_target(param, grad) {
            // Full-rank pass-through (embeddings, norms, scalars).
            return self.inner.step(param, w, grad, lr);
        }
        let t = self.steps.entry(param).or_insert(0);
        let needs_refresh = *t % self.cfg.update_freq == 0 || !self.projectors.contains_key(&param);
        *t += 1;
        let ws = self.workspaces.entry(param).or_insert_with(Workspace::new);
        // True when the step scratch already holds Pᵀ G for the basis the
        // step will use (the gate computed it and kept the basis).
        let mut compact_ready = false;
        // Refresh the subspace every T steps (including step 0).
        if needs_refresh {
            let schedule = self.cfg.schedule();
            let gate = self.cfg.refresh_gate();
            match self.projectors.get_mut(&param) {
                // Steady-state refresh: reuse basis + SVD buffers in place.
                Some(p) => {
                    let rs = self.rank_states.entry(param).or_default();
                    // Lazy-refresh gate (Q-GaLore-style): when the cached
                    // subspace still captures the current gradient, the new
                    // basis would be nearly collinear with it — skip the
                    // SVD and keep projecting through the cached basis.
                    let mut skip = false;
                    if gate.enabled() {
                        p.project_into(grad, &mut ws.step.compact_grad);
                        let cos = subspace_cosine(
                            ws.step.compact_grad.frobenius_norm(),
                            grad.frobenius_norm(),
                        );
                        rs.last_cosine = cos;
                        let starving = schedule.is_adaptive()
                            && rs.consecutive_skips >= MAX_ADAPTIVE_GATE_SKIPS;
                        if gate.fires(cos) && !starving {
                            skip = true;
                            rs.gate_skips += 1;
                            rs.consecutive_skips += 1;
                            // Basis unchanged: the projection computed for
                            // the cosine IS this step's compact gradient.
                            compact_ready = true;
                        }
                    }
                    if !skip {
                        rs.consecutive_skips = 0;
                        if schedule.is_adaptive() {
                            if !ws.adaptive_warm {
                                // Worst-case warm-up so later rank *growth*
                                // (not just shrink) stays allocation-free.
                                let min_dim = grad.rows.min(grad.cols);
                                let long = grad.rows.max(grad.cols);
                                let rmax = schedule.max_rank.min(min_dim).max(1);
                                ws.warm_adaptive(min_dim, long, rmax);
                                self.svd_ws
                                    .warm_extract((rmax + SKETCH_OVERSAMPLE).min(min_dim), rmax);
                            }
                            // Save the outgoing basis, refresh at the
                            // schedule-chosen rank, then — only when the
                            // rank actually changed — carry the inner
                            // optimizer's moments into the new coordinates
                            // (AdaRankGrad-style projection) so a rank
                            // change does not cold-start the EMAs. Same-
                            // rank refreshes keep the fixed-rank semantics
                            // (moments reinterpreted in the new basis), so
                            // drop-state inners (Adam8bit, Adafactor) are
                            // not wiped at every stable-rank boundary.
                            let old_rank = p.rank;
                            ws.prev_basis.copy_from(p.basis());
                            let new_rank = p.refresh_ranked_with(
                                grad,
                                &schedule,
                                &mut self.rng,
                                &mut self.svd_ws,
                                &mut ws.grad_t,
                            );
                            if new_rank != old_rank {
                                basis_transition_into(
                                    &ws.prev_basis,
                                    p.basis(),
                                    p.side,
                                    &mut ws.trans,
                                    &mut ws.trans_sq,
                                );
                                let mut remap = StateRemap::new(
                                    p.side,
                                    &ws.trans,
                                    &ws.trans_sq,
                                    &mut ws.remap_scratch,
                                );
                                self.inner.remap_state(param, &mut remap);
                            }
                            rs.rank = new_rank;
                        } else {
                            p.refresh_with(
                                grad,
                                self.cfg.rank,
                                &mut self.rng,
                                &mut self.svd_ws,
                                &mut ws.grad_t,
                            );
                            rs.rank = p.rank;
                        }
                        rs.refreshes += 1;
                    }
                }
                None => {
                    let p = Projector::compute_with(
                        grad,
                        self.cfg.rank,
                        &mut self.rng,
                        self.cfg.projector_quant,
                    );
                    self.rank_states.insert(
                        param,
                        RankState { rank: p.rank, refreshes: 1, ..Default::default() },
                    );
                    self.projectors.insert(param, p);
                }
            }
            // NOTE: like the official implementation, a refresh that keeps
            // the rank does *not* reset optimizer state — the moments'
            // coordinates are reinterpreted in the new basis (§4.1
            // discusses the fidelity trade-off). Adaptive schedules remap
            // the moments explicitly only when the rank — and therefore
            // the compact shape — changed.
        }
        let proj = match self.projectors.get(&param) {
            Some(p) => p,
            None => {
                // Impossible by construction (the refresh above inserts
                // it), but a resident process must degrade to a failed
                // step — with the standard counter rollback — not abort.
                if let Some(t) = self.steps.get_mut(&param) {
                    *t -= 1;
                }
                return Err(format!("step: parameter {param} has no projector after refresh"));
            }
        };
        let lr_scale = lr * self.cfg.scale;
        let res = if compact_ready {
            // The gate's cosine projection IS this step's compact gradient:
            // detach it (empty-matrix swap, no allocation) and feed the
            // backend's compact entry.
            let compact = std::mem::replace(&mut ws.step.compact_grad, Matrix::zeros(0, 0));
            let res = self.backend.step_compact_into(
                StepCtx {
                    param,
                    w,
                    proj,
                    lr_scale,
                    inner: &mut self.inner,
                    scratch: &mut ws.step,
                },
                &compact,
            );
            ws.step.compact_grad = compact;
            res
        } else {
            // Full-gradient entry: the Rust backend projects into the
            // scratch; the artifact backend ships G to the fused kernel.
            self.backend.step_into(
                StepCtx {
                    param,
                    w,
                    proj,
                    lr_scale,
                    inner: &mut self.inner,
                    scratch: &mut ws.step,
                },
                grad,
            )
        };
        if res.is_err() {
            // Roll the step counter back: the refresh cadence and the DP
            // communication plan are both functions of `t % T`, so a step
            // whose update never applied must not advance them — a
            // checkpoint taken after a failed step (the reason `step` is
            // fallible at all) stays consistent with the applied state.
            // Deliberately NOT rolled back: a refresh that already ran at
            // this boundary (basis, rank decision, moment remap, RNG
            // draw). It is a valid subspace decision on its own, and
            // unwinding it would mean snapshotting basis + rank state +
            // moments every boundary step just for the error path. The
            // sole caller-visible effect is that retrying the failed step
            // re-runs the refresh with a fresh sketch — current callers
            // abort-and-resume from a checkpoint instead of retrying.
            if let Some(t) = self.steps.get_mut(&param) {
                *t -= 1;
            }
        }
        res
    }

    /// Cross-layer parallel stepping: whole layers step concurrently on
    /// the worker pool (`runtime::pool`), bit-identical to the sequential
    /// sweep at any thread count (pinned by `tests/hotpath_props.rs`).
    ///
    /// A parameter is *queued* for the pool when its step is pure
    /// per-parameter arithmetic on disjoint state: a targeted parameter
    /// between refresh boundaries, or an untargeted pass-through — in both
    /// cases only when the inner optimizer exposes paper-default Adam
    /// moments ([`Optimizer::moments_mut`]) at the expected shape, which
    /// is the same contract the fused artifacts rely on to replicate the
    /// update away from `&mut self`. Everything else — refresh-boundary
    /// steps (RNG sketch draws, rank decisions, moment remaps, the lazy-
    /// refresh gate) and non-Adam inners — runs inline in ascending
    /// parameter order, exactly as the sequential loop would, so the RNG
    /// stream is untouched by the restructuring. Queued tasks replicate
    /// the shared compact tail call-for-call (see [`ParTask::run`]);
    /// nested threaded matmuls inside a task execute inline on that
    /// worker (the pool's re-entrancy rule), and every output row keeps
    /// one fixed FMA order, so results are bit-exact at 1, 2, or N
    /// threads.
    ///
    /// On an inline-step error the already-queued (strictly earlier)
    /// parameters still execute before the error is returned, preserving
    /// the sequential loop's partial-progress semantics. Gated on
    /// [`StepBackend::supports_parallel_step`]: the artifact backend
    /// serializes through one PJRT engine and keeps the sequential sweep.
    fn step_many(
        &mut self,
        weights: &mut [Matrix],
        grads: &[Matrix],
        lr: f32,
    ) -> Result<(), String> {
        if weights.len() != grads.len() {
            return Err(format!(
                "step_many: {} weights vs {} gradients",
                weights.len(),
                grads.len()
            ));
        }
        if !self.backend.supports_parallel_step() {
            for (idx, (w, g)) in weights.iter_mut().zip(grads.iter()).enumerate() {
                self.step(idx, w, g, lr)?;
            }
            return Ok(());
        }
        // Pass A: classify in ascending order. Queueable steps only mark
        // the plan (plus the step-counter bump the sequential path would
        // do); boundary/fallback steps run inline *now* so refresh RNG
        // draws happen in exactly the sequential order. Every map entry a
        // queued task needs (workspace, moments) is created here, before
        // pass B captures pointers — later insertions may rehash the maps
        // and move earlier values.
        self.par_plan.clear();
        let mut first_err = None;
        for idx in 0..weights.len() {
            let grad = &grads[idx];
            if self.is_target(idx, grad) {
                let t = self.steps.get(&idx).copied().unwrap_or(0);
                let boundary =
                    t % self.cfg.update_freq == 0 || !self.projectors.contains_key(&idx);
                if !boundary {
                    let (rows, cols) = grad.shape();
                    // `boundary` checked `contains_key`, so the lookups
                    // below cannot miss; if they ever do, fail the batch
                    // through `first_err` like any inline step failure.
                    let Some((cm, cn)) =
                        self.projectors.get(&idx).map(|p| p.compact_shape(rows, cols))
                    else {
                        first_err =
                            Some(format!("step_many: steady target {idx} lost its projector"));
                        break;
                    };
                    let queued = matches!(
                        self.inner.moments_mut(idx, cm, cn),
                        Some(mom) if mom.m.shape() == (cm, cn) && mom.v.shape() == (cm, cn)
                    );
                    if queued {
                        let Some(t) = self.steps.get_mut(&idx) else {
                            first_err = Some(format!(
                                "step_many: steady target {idx} lost its step count"
                            ));
                            break;
                        };
                        *t += 1;
                        self.workspaces.entry(idx).or_insert_with(Workspace::new);
                        self.par_plan.push((idx, ParKind::Targeted));
                        continue;
                    }
                }
            } else {
                let (rows, cols) = grad.shape();
                let queued = matches!(
                    self.inner.moments_mut(idx, rows, cols),
                    Some(mom) if mom.m.shape() == (rows, cols) && mom.v.shape() == (rows, cols)
                );
                if queued {
                    self.par_plan.push((idx, ParKind::FullRank));
                    continue;
                }
            }
            if let Err(e) = self.step(idx, &mut weights[idx], grad, lr) {
                first_err = Some(e);
                break;
            }
        }
        // Pass B: capture pointers. All entries exist; nothing below
        // inserts into any map, so the addresses stay stable until the
        // barrier completes.
        self.par_tasks.clear();
        for &(idx, kind) in &self.par_plan {
            let grad = &grads[idx];
            let (rows, cols) = grad.shape();
            if kind == ParKind::Targeted {
                // Pass A created every entry captured here, so these
                // lookups are infallible; propagate rather than abort if
                // that invariant is ever broken.
                let proj = self
                    .projectors
                    .get(&idx)
                    .ok_or_else(|| format!("step_many: queued target {idx} has no projector"))?;
                let (cm, cn) = proj.compact_shape(rows, cols);
                let proj: *const Projector = proj;
                let scratch: *mut StepScratch = {
                    let ws = self
                        .workspaces
                        .get_mut(&idx)
                        .ok_or_else(|| format!("step_many: queued target {idx} has no workspace"))?;
                    &mut ws.step
                };
                let mom = self
                    .inner
                    .moments_mut(idx, cm, cn)
                    .ok_or_else(|| format!("step_many: queued target {idx} exposes no moments"))?;
                self.par_tasks.push(ParTask {
                    w: &mut weights[idx],
                    grad,
                    proj,
                    scratch,
                    m: mom.m,
                    v: mom.v,
                    upd: mom.upd,
                    t: mom.t,
                    lr_apply: lr * self.cfg.scale,
                    pre_projected: false,
                });
            } else {
                let mom = self
                    .inner
                    .moments_mut(idx, rows, cols)
                    .ok_or_else(|| format!("step_many: queued parameter {idx} exposes no moments"))?;
                self.par_tasks.push(ParTask {
                    w: &mut weights[idx],
                    grad,
                    proj: std::ptr::null(),
                    scratch: std::ptr::null_mut(),
                    m: mom.m,
                    v: mom.v,
                    upd: mom.upd,
                    t: mom.t,
                    lr_apply: -lr,
                    pre_projected: false,
                });
            }
        }
        // Detach the task list so the pool closure borrows no part of
        // `self` (`mem::take` moves the buffer, no allocation).
        let tasks = std::mem::take(&mut self.par_tasks);
        if !tasks.is_empty() {
            pool::run(tasks.len(), |i| tasks[i].run());
        }
        self.par_tasks = tasks;
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
            + self.projectors.values().map(|p| p.nbytes()).sum::<usize>()
            + self.backend.state_bytes()
    }

    fn name(&self) -> &'static str {
        "galore"
    }

    fn reset_state(&mut self) {
        self.inner.reset_state();
        self.projectors.clear();
        self.steps.clear();
        self.workspaces.clear();
        self.rank_states.clear();
    }

    fn rank_profile(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> =
            self.projectors.iter().map(|(&p, proj)| (p, proj.rank)).collect();
        v.sort_unstable();
        v
    }

    fn gate_skips(&self) -> u64 {
        self.rank_states.values().map(|r| r.gate_skips).sum()
    }

    /// Communication plan for data parallelism: between subspace refreshes
    /// a targeted parameter needs only `Pᵀ G`, so replicas can exchange
    /// the `r×n` compact gradient. At a refresh boundary (`t % T == 0`) —
    /// including a boundary the lazy-refresh gate may end up skipping,
    /// since the gate's cosine itself needs `‖G_avg‖` — and before the
    /// first step, the full gradient must be reduced so the randomized
    /// SVD (and the rank schedule, and the gate) see the *averaged*
    /// gradient and replicas keep bit-identical projectors.
    fn grad_reduce_mode(&self, param: usize, rows: usize, cols: usize) -> GradReduceMode {
        let Some(p) = self.projectors.get(&param) else {
            return GradReduceMode::Full;
        };
        let t = self.steps.get(&param).copied().unwrap_or(0);
        if t % self.cfg.update_freq == 0 {
            return GradReduceMode::Full;
        }
        let (r, c) = p.compact_shape(rows, cols);
        GradReduceMode::Compact { rows: r, cols: c }
    }

    fn project_grad_into(&self, param: usize, grad: &Matrix, out: &mut Matrix) -> bool {
        let Some(p) = self.projectors.get(&param) else {
            return false;
        };
        let t = self.steps.get(&param).copied().unwrap_or(0);
        if t % self.cfg.update_freq == 0 {
            return false;
        }
        p.project_into(grad, out);
        true
    }

    /// The non-refresh tail of `GaLore::step`, fed an already-projected
    /// compact gradient through the active backend's compact entry:
    /// identical arithmetic on the Rust backend (same scratch, same inner
    /// step, same project-back), so a data-parallel step that averaged
    /// compact gradients is bit-identical to one that averaged full
    /// gradients and projected — up to the all-reduce's own summation
    /// order. (The artifact backend's compact entry runs the same shared
    /// tail against the same moments; see `optim::backend`.)
    fn step_compact(
        &mut self,
        param: usize,
        w: &mut Matrix,
        compact: &Matrix,
        lr: f32,
    ) -> Result<(), String> {
        let Some(t) = self.steps.get_mut(&param) else {
            return Err(format!(
                "step_compact on parameter {param} before its first full step — the \
                 projector does not exist yet (grad_reduce_mode returns Full there)"
            ));
        };
        if *t % self.cfg.update_freq == 0 {
            return Err(
                "step_compact at a refresh boundary — the caller must reduce the full \
                 gradient there (grad_reduce_mode returns Full at boundaries)"
                    .into(),
            );
        }
        *t += 1;
        let ws = self.workspaces.entry(param).or_insert_with(Workspace::new);
        let proj = match self.projectors.get(&param) {
            Some(p) => p,
            None => {
                // `steps` has an off-boundary count for `param` (checked
                // above), so the projector must exist — but if it ever
                // does not, fail the step with the standard counter
                // rollback instead of aborting the process.
                if let Some(t) = self.steps.get_mut(&param) {
                    *t -= 1;
                }
                return Err(format!(
                    "step_compact: parameter {param} has no projector between refreshes"
                ));
            }
        };
        let res = self.backend.step_compact_into(
            StepCtx {
                param,
                w,
                proj,
                lr_scale: lr * self.cfg.scale,
                inner: &mut self.inner,
                scratch: &mut ws.step,
            },
            compact,
        );
        if res.is_err() {
            // Same counter rollback as `step`: a failed compact step must
            // not shift the refresh cadence or the DP plan.
            if let Some(t) = self.steps.get_mut(&param) {
                *t -= 1;
            }
        }
        res
    }

    /// Plan-driven bucket step, parallelized like [`GaLore::step_many`]:
    /// steady-state entries — `Compact`-planned targets (already-averaged
    /// compact gradients, applied through the `step_compact` tail) and
    /// full-rank pass-throughs — fan out across the worker pool, while
    /// refresh boundaries and anything the fast path cannot prove safe
    /// run inline in ascending order, preserving the sequential walk's
    /// RNG draws and partial-progress semantics. Bit-identical to the
    /// default sequential walk by the same argument as `step_many`:
    /// every queued task replicates its sequential counterpart
    /// call-for-call.
    fn step_planned(
        &mut self,
        base: usize,
        weights: &mut [Matrix],
        grads: &[Matrix],
        plan: &[GradReduceMode],
        compact: &[Matrix],
        lr: f32,
    ) -> Result<(), String> {
        if weights.len() != grads.len()
            || plan.len() != grads.len()
            || compact.len() != grads.len()
        {
            return Err(format!(
                "step_planned: {} weights vs {} gradients ({} plan entries, {} compact buffers)",
                weights.len(),
                grads.len(),
                plan.len(),
                compact.len()
            ));
        }
        if !self.backend.supports_parallel_step() {
            for i in 0..weights.len() {
                match plan[i] {
                    GradReduceMode::Full => self.step(base + i, &mut weights[i], &grads[i], lr)?,
                    GradReduceMode::Compact { .. } => {
                        self.step_compact(base + i, &mut weights[i], &compact[i], lr)?
                    }
                }
            }
            return Ok(());
        }
        // Pass A (see `step_many`): classify in ascending order, queueing
        // steady entries and running everything else inline *now*.
        self.par_plan.clear();
        let mut first_err = None;
        for i in 0..weights.len() {
            let param = base + i;
            match plan[i] {
                GradReduceMode::Compact { .. } => {
                    // Queue iff the inline `step_compact` would reach the
                    // backend tail: off-boundary step count, projector
                    // present, paper-default moments at the compact
                    // shape. Anything else falls through to the inline
                    // call (which is also where the contract-violation
                    // errors come from).
                    let steady = matches!(
                        self.steps.get(&param).copied(),
                        Some(t) if t % self.cfg.update_freq != 0
                    ) && self.projectors.contains_key(&param);
                    if steady {
                        let (cm, cn) = compact[i].shape();
                        let queued = matches!(
                            self.inner.moments_mut(param, cm, cn),
                            Some(mom) if mom.m.shape() == (cm, cn) && mom.v.shape() == (cm, cn)
                        );
                        if queued {
                            let Some(t) = self.steps.get_mut(&param) else {
                                first_err = Some(format!(
                                    "step_planned: steady target {param} lost its step count"
                                ));
                                break;
                            };
                            *t += 1;
                            self.workspaces.entry(param).or_insert_with(Workspace::new);
                            self.par_plan.push((i, ParKind::PreProjected));
                            continue;
                        }
                    }
                    if let Err(e) = self.step_compact(param, &mut weights[i], &compact[i], lr) {
                        first_err = Some(e);
                        break;
                    }
                }
                GradReduceMode::Full => {
                    let grad = &grads[i];
                    if self.is_target(param, grad) {
                        // A Full plan entry for a target is a refresh
                        // boundary or a `dp_compress`-off run: boundaries
                        // run inline (sequential RNG order); steady
                        // targets queue with the projection inside the
                        // task, exactly as in `step_many`.
                        let t = self.steps.get(&param).copied().unwrap_or(0);
                        let boundary =
                            t % self.cfg.update_freq == 0 || !self.projectors.contains_key(&param);
                        if !boundary {
                            let (rows, cols) = grad.shape();
                            // `boundary` checked `contains_key`; a miss
                            // here fails the batch via `first_err`.
                            let Some((cm, cn)) = self
                                .projectors
                                .get(&param)
                                .map(|p| p.compact_shape(rows, cols))
                            else {
                                first_err = Some(format!(
                                    "step_planned: steady target {param} lost its projector"
                                ));
                                break;
                            };
                            let queued = matches!(
                                self.inner.moments_mut(param, cm, cn),
                                Some(mom) if mom.m.shape() == (cm, cn) && mom.v.shape() == (cm, cn)
                            );
                            if queued {
                                let Some(t) = self.steps.get_mut(&param) else {
                                    first_err = Some(format!(
                                        "step_planned: steady target {param} lost its step count"
                                    ));
                                    break;
                                };
                                *t += 1;
                                self.workspaces.entry(param).or_insert_with(Workspace::new);
                                self.par_plan.push((i, ParKind::Targeted));
                                continue;
                            }
                        }
                    } else {
                        let (rows, cols) = grad.shape();
                        let queued = matches!(
                            self.inner.moments_mut(param, rows, cols),
                            Some(mom) if mom.m.shape() == (rows, cols) && mom.v.shape() == (rows, cols)
                        );
                        if queued {
                            self.par_plan.push((i, ParKind::FullRank));
                            continue;
                        }
                    }
                    if let Err(e) = self.step(param, &mut weights[i], grad, lr) {
                        first_err = Some(e);
                        break;
                    }
                }
            }
        }
        // Pass B: capture pointers. All map entries exist (pass A created
        // them, and nothing below inserts, so the addresses stay stable
        // until the barrier); a miss is propagated, never an abort.
        self.par_tasks.clear();
        for &(i, kind) in &self.par_plan {
            let param = base + i;
            match kind {
                ParKind::PreProjected => {
                    let proj: *const Projector = self
                        .projectors
                        .get(&param)
                        .ok_or_else(|| format!("step_planned: queued target {param} has no projector"))?;
                    let scratch: *mut StepScratch = {
                        let ws = self.workspaces.get_mut(&param).ok_or_else(|| {
                            format!("step_planned: queued target {param} has no workspace")
                        })?;
                        &mut ws.step
                    };
                    let c = &compact[i];
                    let (cm, cn) = c.shape();
                    let mom = self.inner.moments_mut(param, cm, cn).ok_or_else(|| {
                        format!("step_planned: queued target {param} exposes no moments")
                    })?;
                    self.par_tasks.push(ParTask {
                        w: &mut weights[i],
                        grad: c,
                        proj,
                        scratch,
                        m: mom.m,
                        v: mom.v,
                        upd: mom.upd,
                        t: mom.t,
                        lr_apply: lr * self.cfg.scale,
                        pre_projected: true,
                    });
                }
                ParKind::Targeted => {
                    let grad = &grads[i];
                    let (rows, cols) = grad.shape();
                    let proj = self
                        .projectors
                        .get(&param)
                        .ok_or_else(|| format!("step_planned: queued target {param} has no projector"))?;
                    let (cm, cn) = proj.compact_shape(rows, cols);
                    let proj: *const Projector = proj;
                    let scratch: *mut StepScratch = {
                        let ws = self.workspaces.get_mut(&param).ok_or_else(|| {
                            format!("step_planned: queued target {param} has no workspace")
                        })?;
                        &mut ws.step
                    };
                    let mom = self.inner.moments_mut(param, cm, cn).ok_or_else(|| {
                        format!("step_planned: queued target {param} exposes no moments")
                    })?;
                    self.par_tasks.push(ParTask {
                        w: &mut weights[i],
                        grad,
                        proj,
                        scratch,
                        m: mom.m,
                        v: mom.v,
                        upd: mom.upd,
                        t: mom.t,
                        lr_apply: lr * self.cfg.scale,
                        pre_projected: false,
                    });
                }
                ParKind::FullRank => {
                    let grad = &grads[i];
                    let (rows, cols) = grad.shape();
                    let mom = self.inner.moments_mut(param, rows, cols).ok_or_else(|| {
                        format!("step_planned: queued parameter {param} exposes no moments")
                    })?;
                    self.par_tasks.push(ParTask {
                        w: &mut weights[i],
                        grad,
                        proj: std::ptr::null(),
                        scratch: std::ptr::null_mut(),
                        m: mom.m,
                        v: mom.v,
                        upd: mom.upd,
                        t: mom.t,
                        lr_apply: -lr,
                        pre_projected: false,
                    });
                }
            }
        }
        let tasks = std::mem::take(&mut self.par_tasks);
        if !tasks.is_empty() {
            pool::run(tasks.len(), |i| tasks[i].run());
        }
        self.par_tasks = tasks;
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Checkpoint v2: projector RNG, the inner optimizer's state (nested,
    /// length-prefixed so the two formats stay separable), per-parameter
    /// step counters, rank-adaptation bookkeeping, and the projector bases
    /// themselves. Workspaces and the SVD scratch are working memory —
    /// rebuilt lazily after load with identical arithmetic.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        ser::put_rng(out, &self.rng);
        let mut inner = Vec::new();
        self.inner.save_state(&mut inner)?;
        ser::put_bytes(out, &inner);
        let mut params: Vec<usize> = self.steps.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in &params {
            ser::put_usize(out, *p);
            ser::put_u64(out, self.steps[p]);
        }
        let mut params: Vec<usize> = self.rank_states.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in &params {
            let rs = &self.rank_states[p];
            ser::put_usize(out, *p);
            ser::put_usize(out, rs.rank);
            ser::put_u64(out, rs.refreshes);
            ser::put_u64(out, rs.gate_skips);
            ser::put_u64(out, rs.consecutive_skips);
            ser::put_f32(out, rs.last_cosine);
        }
        let mut params: Vec<usize> = self.projectors.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in &params {
            ser::put_usize(out, *p);
            self.projectors[p].save_state(out);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.rng = r.rng()?;
        let inner_bytes = r.bytes()?;
        let mut ir = ser::Reader::new(inner_bytes);
        self.inner.load_state(&mut ir)?;
        ir.expect_end()?;
        self.steps.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let t = r.u64()?;
            self.steps.insert(p, t);
        }
        self.rank_states.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let rs = RankState {
                rank: r.usize()?,
                refreshes: r.u64()?,
                gate_skips: r.u64()?,
                consecutive_skips: r.u64()?,
                last_cosine: r.f32()?,
            };
            self.rank_states.insert(p, rs);
        }
        self.projectors.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let proj = Projector::load_state(r)?;
            self.projectors.insert(p, proj);
        }
        // Workspaces are scratch; drop any stale shapes and re-warm lazily.
        self.workspaces.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adam, AdamConfig};
    use crate::tensor::{matmul, matmul_a_bt, matmul_at_b};
    use crate::testing::assert_slice_close;

    fn adam() -> Adam {
        Adam::new(AdamConfig::default())
    }

    #[test]
    fn projector_roundtrip_energy() {
        // For a nearly-rank-r gradient, project+back must preserve ~all energy.
        let mut rng = Rng::new(0);
        let u = Matrix::randn(40, 4, 1.0, &mut rng);
        let v = Matrix::randn(4, 60, 1.0, &mut rng);
        let g = matmul(&u, &v);
        let proj = Projector::compute(&g, 4, &mut rng);
        let back = proj.project_back(&proj.project(&g));
        let mut err = g.clone();
        err.sub_assign(&back);
        assert!(err.frobenius_norm() < 1e-2 * g.frobenius_norm());
    }

    #[test]
    fn side_follows_short_dimension() {
        let mut rng = Rng::new(1);
        let wide = Matrix::randn(8, 32, 1.0, &mut rng);
        let tall = Matrix::randn(32, 8, 1.0, &mut rng);
        assert_eq!(Projector::compute(&wide, 4, &mut rng).side, ProjSide::Left);
        assert_eq!(Projector::compute(&tall, 4, &mut rng).side, ProjSide::Right);
    }

    #[test]
    fn compact_shapes() {
        let mut rng = Rng::new(2);
        let wide = Matrix::randn(8, 32, 1.0, &mut rng);
        let p = Projector::compute(&wide, 4, &mut rng);
        assert_eq!(p.project(&wide).shape(), (4, 32));
        assert_eq!(p.compact_shape(8, 32), (4, 32));
        let tall = Matrix::randn(32, 8, 1.0, &mut rng);
        let q = Projector::compute(&tall, 4, &mut rng);
        assert_eq!(q.project(&tall).shape(), (32, 4));
    }

    #[test]
    fn rank_clamped_to_min_dim() {
        let mut rng = Rng::new(3);
        let g = Matrix::randn(6, 100, 1.0, &mut rng);
        let p = Projector::compute(&g, 64, &mut rng);
        assert_eq!(p.rank, 6);
    }

    #[test]
    fn full_rank_projection_matches_plain_adam() {
        // §3.3: with r = min(m, n) (orthonormal square-ish P) and α = 1,
        // GaLore follows the exact Adam trajectory.
        let mut rng = Rng::new(4);
        let cfg = GaLoreConfig { rank: 8, update_freq: 1000, scale: 1.0, ..Default::default() };
        let mut gal = GaLore::new(cfg, adam());
        let mut plain = adam();
        let mut wg = Matrix::randn(8, 24, 1.0, &mut rng);
        let mut wp = wg.clone();
        for s in 0..25 {
            let g = Matrix::randn(8, 24, 1.0, &mut rng.child(s));
            gal.step(0, &mut wg, &g, 0.01).unwrap();
            plain.step(0, &mut wp, &g, 0.01).unwrap();
        }
        // P is an orthonormal 8x8 basis: updates agree up to rotation of
        // the Adam nonlinearity — for exact agreement the *element-wise*
        // statistics must match, which holds only when P = I. So compare
        // loosely: the trajectories stay within a few percent.
        let mut d = wg.clone();
        d.sub_assign(&wp);
        assert!(
            d.frobenius_norm() < 0.15 * wp.frobenius_norm(),
            "relative divergence {}",
            d.frobenius_norm() / wp.frobenius_norm()
        );
    }

    #[test]
    fn update_stays_in_subspace() {
        // Definition 3.6: between refreshes, ΔW ∈ span(P).
        let mut rng = Rng::new(5);
        let cfg = GaLoreConfig { rank: 4, update_freq: 100, scale: 0.25, ..Default::default() };
        let mut gal = GaLore::new(cfg, adam());
        let mut w = Matrix::randn(32, 48, 1.0, &mut rng);
        let w0 = w.clone();
        for s in 0..10 {
            let g = Matrix::randn(32, 48, 1.0, &mut rng.child(s));
            gal.step(0, &mut w, &g, 0.01).unwrap();
        }
        let p = gal.projector(0).unwrap().basis().clone();
        let mut dw = w.clone();
        dw.sub_assign(&w0);
        // Residual orthogonal to span(P) must vanish: dw - P (P^T dw) = 0.
        let ptdw = matmul_at_b(&p, &dw);
        let back = matmul(&p, &ptdw);
        let mut resid = dw.clone();
        resid.sub_assign(&back);
        assert!(resid.frobenius_norm() < 1e-4 * dw.frobenius_norm().max(1.0));
    }

    #[test]
    fn subspace_switches_at_update_freq() {
        let mut rng = Rng::new(6);
        let cfg = GaLoreConfig { rank: 4, update_freq: 5, scale: 0.25, ..Default::default() };
        let mut gal = GaLore::new(cfg, adam());
        let mut w = Matrix::randn(16, 24, 1.0, &mut rng);
        let g0 = Matrix::randn(16, 24, 1.0, &mut rng);
        gal.step(0, &mut w, &g0, 0.01).unwrap();
        let basis0 = gal.projector(0).unwrap().basis().clone();
        for s in 1..5 {
            let g = Matrix::randn(16, 24, 1.0, &mut rng.child(s));
            gal.step(0, &mut w, &g, 0.01).unwrap();
            // Unchanged within the window.
            assert_slice_close(&gal.projector(0).unwrap().basis().data, &basis0.data, 0.0, 0.0);
        }
        let g5 = Matrix::randn(16, 24, 1.0, &mut rng.child(99));
        gal.step(0, &mut w, &g5, 0.01).unwrap();
        let basis1 = gal.projector(0).unwrap().basis().clone();
        let mut diff = basis1;
        diff.sub_assign(&basis0);
        assert!(diff.frobenius_norm() > 1e-3, "projector did not refresh");
    }

    #[test]
    fn memory_matches_paper_formula() {
        // Table 1: GaLore optim state = mr + 2nr for (m<=n) Adam.
        let (m, n, r) = (32usize, 64usize, 8usize);
        let cfg = GaLoreConfig { rank: r, update_freq: 100, scale: 0.25, ..Default::default() };
        let mut gal = GaLore::new(cfg, adam());
        let mut w = Matrix::zeros(m, n);
        let g = Matrix::ones(m, n);
        gal.step(0, &mut w, &g, 0.01).unwrap();
        let expect = 4 * (m * r + 2 * r * n); // P + (M, V) compact
        assert_eq!(gal.state_bytes(), expect);
    }

    #[test]
    fn untargeted_params_full_rank() {
        let cfg = GaLoreConfig { rank: 4, update_freq: 10, scale: 0.25, ..Default::default() };
        let mut gal = GaLore::new(cfg, adam()).with_targets([1usize]);
        let mut w = Matrix::zeros(16, 16);
        let g = Matrix::ones(16, 16);
        gal.step(0, &mut w, &g, 0.01).unwrap(); // param 0: not targeted
        assert!(gal.projector(0).is_none());
        // Full-rank Adam state: 2 * 16 * 16 floats.
        assert_eq!(gal.state_bytes(), 4 * 2 * 16 * 16);
    }

    #[test]
    fn quantized_projector_quarters_memory_and_still_trains() {
        // §7 future work (2): 8-bit P. Memory: ~1/4 of the f32 projector;
        // convergence: same order as f32 GaLore on the toy problem.
        let mut rng = Rng::new(9);
        let cfg_f32 = GaLoreConfig { rank: 8, update_freq: 50, scale: 0.25, ..Default::default() };
        let cfg_q8 = GaLoreConfig { projector_quant: ProjectorQuant::Block8, ..cfg_f32 };
        let mut g_f32 = GaLore::new(cfg_f32, adam());
        let mut g_q8 = GaLore::new(cfg_q8, adam());
        let mut w1 = Matrix::randn(32, 64, 1.0, &mut rng);
        let mut w2 = w1.clone();
        for s in 0..30 {
            let g = Matrix::randn(32, 64, 1.0, &mut rng.child(s));
            g_f32.step(0, &mut w1, &g, 0.01).unwrap();
            g_q8.step(0, &mut w2, &g, 0.01).unwrap();
        }
        assert!(g_q8.projector(0).unwrap().is_quantized());
        let p_f32 = g_f32.projector(0).unwrap().nbytes();
        let p_q8 = g_q8.projector(0).unwrap().nbytes();
        assert!(p_q8 * 3 < p_f32, "q8 {p_q8} vs f32 {p_f32}");
        // Trajectories track closely (quantized P is near-orthonormal).
        let mut d = w1.clone();
        d.sub_assign(&w2);
        assert!(d.frobenius_norm() < 0.05 * w1.frobenius_norm());
    }

    #[test]
    fn quant8_basis_cache_invalidated_on_refresh() {
        // The dequantized basis cache must stay bit-stable within an
        // update window and change when the subspace refreshes.
        let mut rng = Rng::new(21);
        let cfg = GaLoreConfig {
            rank: 4,
            update_freq: 3,
            scale: 0.25,
            projector_quant: ProjectorQuant::Block8,
            ..Default::default()
        };
        let mut gal = GaLore::new(cfg, adam());
        let mut w = Matrix::randn(16, 24, 1.0, &mut rng);
        let probe = Matrix::randn(16, 24, 1.0, &mut rng);
        gal.step(0, &mut w, &Matrix::randn(16, 24, 1.0, &mut rng.child(0)), 0.01).unwrap();
        assert!(gal.projector(0).unwrap().is_quantized());
        let cache0 = gal.projector(0).unwrap().basis().clone();
        let proj0 = gal.projector(0).unwrap().project(&probe);
        for s in 1..3 {
            gal.step(0, &mut w, &Matrix::randn(16, 24, 1.0, &mut rng.child(s)), 0.01).unwrap();
            assert_eq!(
                gal.projector(0).unwrap().basis().data,
                cache0.data,
                "cache changed inside the update window"
            );
        }
        // Step 3 (t % 3 == 0) refreshes the subspace and rebuilds the cache.
        gal.step(0, &mut w, &Matrix::randn(16, 24, 1.0, &mut rng.child(99)), 0.01).unwrap();
        let cache1 = gal.projector(0).unwrap().basis().clone();
        let proj1 = gal.projector(0).unwrap().project(&probe);
        let mut diff = cache1;
        diff.sub_assign(&cache0);
        assert!(diff.frobenius_norm() > 1e-3, "cache not invalidated on refresh");
        let mut pdiff = proj1;
        pdiff.sub_assign(&proj0);
        assert!(pdiff.frobenius_norm() > 1e-3, "projected output unchanged after refresh");
    }

    #[test]
    fn with_seed_makes_runs_reproducible() {
        let cfg = GaLoreConfig { rank: 4, update_freq: 5, scale: 0.25, ..Default::default() };
        let run = |seed: u64| -> Matrix {
            let mut rng = Rng::new(33);
            let mut gal = GaLore::new(cfg, adam()).with_seed(seed);
            let mut w = Matrix::randn(16, 24, 1.0, &mut rng);
            for s in 0..12 {
                let g = Matrix::randn(16, 24, 1.0, &mut rng.child(s));
                gal.step(0, &mut w, &g, 0.01).unwrap();
            }
            w
        };
        assert_eq!(run(7).data, run(7).data, "same seed must reproduce exactly");
        assert_ne!(run(7).data, run(8).data, "different seeds must diverge");
    }

    #[test]
    #[should_panic(expected = "update_freq")]
    fn zero_update_freq_rejected_at_construction() {
        let cfg = GaLoreConfig { update_freq: 0, ..Default::default() };
        let _ = GaLore::new(cfg, adam());
    }

    #[test]
    fn galore_converges_on_low_rank_least_squares() {
        // Lemma 3.3 setting: inputs confined to a k-dim subspace; GaLore
        // with rank >= k must drive the loss down like full Adam.
        let mut rng = Rng::new(7);
        let (m, n, k) = (24, 16, 4);
        let w_star = Matrix::randn(m, n, 1.0, &mut rng);
        let basis = Matrix::randn(k, n, 1.0, &mut rng);
        let run = |use_galore: bool, rng: &mut Rng| -> (f32, f32) {
            let mut w = Matrix::zeros(m, n);
            let mut opt: Box<dyn Optimizer> = if use_galore {
                Box::new(GaLore::new(
                    GaLoreConfig { rank: 6, update_freq: 50, scale: 1.0, ..Default::default() },
                    adam(),
                ))
            } else {
                Box::new(adam())
            };
            let mut first = 0.0;
            let mut last = 0.0;
            for t in 0..300 {
                let z = Matrix::randn(64, k, 1.0, &mut rng.child(t as u64));
                let x = matmul(&z, &basis); // (64, n)
                // err = X Wᵀ - X W*ᵀ; loss = mean(err²); G = 2 errᵀ X / B.
                let pred = matmul_a_bt(&x, &w);
                let target = matmul_a_bt(&x, &w_star);
                let mut err = pred.clone();
                err.sub_assign(&target);
                let loss = (err.frobenius_norm().powi(2)) / err.len() as f32;
                if t == 0 {
                    first = loss;
                }
                last = loss;
                let g = {
                    let mut g = matmul_at_b(&err, &x); // (m, n)
                    g.scale(2.0 / x.rows as f32);
                    g
                };
                opt.step(0, &mut w, &g, 0.02).unwrap();
            }
            (first, last)
        };
        let (f_adam, l_adam) = run(false, &mut rng.child(1000));
        let (f_gal, l_gal) = run(true, &mut rng.child(2000));
        assert!(l_adam < 0.05 * f_adam, "adam {f_adam} -> {l_adam}");
        assert!(l_gal < 0.10 * f_gal, "galore {f_gal} -> {l_gal}");
    }

    #[test]
    fn dyn8_projector_store_trains_and_shrinks_memory() {
        // The dynamic-code store must behave like Block8: ~1/4 projector
        // memory, closely tracking trajectory.
        let mut rng = Rng::new(31);
        let base = GaLoreConfig { rank: 8, update_freq: 50, scale: 0.25, ..Default::default() };
        let cfg_d8 = GaLoreConfig { projector_quant: ProjectorQuant::Dyn8, ..base };
        let mut g_f32 = GaLore::new(base, adam());
        let mut g_d8 = GaLore::new(cfg_d8, adam());
        let mut w1 = Matrix::randn(32, 64, 1.0, &mut rng);
        let mut w2 = w1.clone();
        for s in 0..30 {
            let g = Matrix::randn(32, 64, 1.0, &mut rng.child(s));
            g_f32.step(0, &mut w1, &g, 0.01).unwrap();
            g_d8.step(0, &mut w2, &g, 0.01).unwrap();
        }
        let p = g_d8.projector(0).unwrap();
        assert!(p.is_quantized());
        assert_eq!(p.quant(), ProjectorQuant::Dyn8);
        assert!(p.nbytes() * 3 < g_f32.projector(0).unwrap().nbytes());
        let mut d = w1.clone();
        d.sub_assign(&w2);
        assert!(d.frobenius_norm() < 0.05 * w1.frobenius_norm());
    }

    #[test]
    fn decay_schedule_shrinks_rank_and_state_at_refresh() {
        let cfg = GaLoreConfig {
            rank: 16,
            update_freq: 4,
            scale: 0.25,
            rank_schedule: RankScheduleKind::Decay,
            rank_floor: 2,
            rank_decay: 0.5,
            ..Default::default()
        };
        let mut gal = GaLore::new(cfg, adam());
        let mut rng = Rng::new(41);
        let mut w = Matrix::randn(24, 40, 1.0, &mut rng);
        let mut ranks = Vec::new();
        let mut bytes = Vec::new();
        for s in 0..14 {
            let g = Matrix::randn(24, 40, 1.0, &mut rng.child(s));
            gal.step(0, &mut w, &g, 0.01).unwrap();
            ranks.push(gal.projector(0).unwrap().rank);
            bytes.push(gal.state_bytes());
        }
        // Refreshes at t=0 (create, r=16), t=4 (r=8), t=8 (r=4), t=12 (r=2).
        assert_eq!(ranks[0], 16);
        assert_eq!(ranks[5], 8);
        assert_eq!(ranks[9], 4);
        assert_eq!(ranks[13], 2);
        assert!(bytes.windows(2).skip(1).all(|w| w[1] <= w[0]), "state grew: {bytes:?}");
        assert_eq!(gal.rank_state(0).unwrap().rank, 2);
        assert!(w.all_finite());
    }

    #[test]
    fn spectral_schedule_finds_planted_gradient_rank() {
        // Gradients of exact rank 3: the spectral policy must settle near
        // rank 3 (within the floor band) while training stays finite.
        let cfg = GaLoreConfig {
            rank: 12,
            update_freq: 5,
            scale: 0.25,
            rank_schedule: RankScheduleKind::Spectral,
            rank_floor: 2,
            rank_energy: 0.999,
            ..Default::default()
        };
        let mut gal = GaLore::new(cfg, adam());
        let mut rng = Rng::new(43);
        let u = Matrix::randn(28, 3, 1.0, &mut rng);
        let mut w = Matrix::randn(28, 36, 1.0, &mut rng);
        for s in 0..12 {
            let v = Matrix::randn(3, 36, 1.0, &mut rng.child(s));
            let g = matmul(&u, &v); // exact rank 3
            gal.step(0, &mut w, &g, 0.01).unwrap();
        }
        let r = gal.projector(0).unwrap().rank;
        assert!((2..=5).contains(&r), "spectral rank {r} far from planted 3");
        assert!(w.all_finite());
    }

    #[test]
    fn gate_skips_refresh_when_subspace_stable() {
        // The same gradient repeated: after the first refresh the cached
        // basis captures it fully (cos ~ 1), so every later boundary must
        // be skipped and the basis must stay bit-stable.
        let cfg = GaLoreConfig {
            rank: 4,
            update_freq: 2,
            scale: 0.25,
            refresh_gate_cos: 0.9,
            ..Default::default()
        };
        let mut gal = GaLore::new(cfg, adam());
        let mut rng = Rng::new(47);
        let mut w = Matrix::randn(16, 24, 1.0, &mut rng);
        // Rank-2 gradient: a rank-4 basis captures it entirely (cos ~ 1).
        let u = Matrix::randn(16, 2, 1.0, &mut rng);
        let v = Matrix::randn(2, 24, 1.0, &mut rng);
        let g = matmul(&u, &v);
        gal.step(0, &mut w, &g, 0.01).unwrap();
        let basis0 = gal.projector(0).unwrap().basis().clone();
        for _ in 1..9 {
            gal.step(0, &mut w, &g, 0.01).unwrap();
        }
        let rs = gal.rank_state(0).unwrap();
        assert_eq!(rs.refreshes, 1, "SVD ran despite a stable subspace");
        assert_eq!(rs.gate_skips, 4, "boundaries at t=2,4,6,8 should all skip");
        assert!(rs.last_cosine > 0.9, "cosine {}", rs.last_cosine);
        assert_eq!(gal.projector(0).unwrap().basis().data, basis0.data);
    }

    #[test]
    fn grad_reduce_mode_full_at_boundaries_compact_between() {
        // The DP comm plan: full before the first step and at every
        // refresh boundary, compact (r×n for a wide param) in between.
        let cfg = GaLoreConfig { rank: 4, update_freq: 3, scale: 0.25, ..Default::default() };
        let mut gal = GaLore::new(cfg, adam());
        let mut rng = Rng::new(51);
        let mut w = Matrix::randn(16, 24, 1.0, &mut rng);
        assert_eq!(gal.grad_reduce_mode(0, 16, 24), GradReduceMode::Full, "no projector yet");
        for s in 0..7 {
            let want = if s % 3 == 0 {
                GradReduceMode::Full
            } else {
                GradReduceMode::Compact { rows: 4, cols: 24 }
            };
            assert_eq!(gal.grad_reduce_mode(0, 16, 24), want, "step {s}");
            let g = Matrix::randn(16, 24, 1.0, &mut rng.child(s as u64));
            gal.step(0, &mut w, &g, 0.01).unwrap();
        }
        // Untargeted params always reduce full.
        let mut gal2 = GaLore::new(cfg, adam()).with_targets([9usize]);
        let mut w2 = Matrix::zeros(16, 16);
        let g = Matrix::ones(16, 16);
        gal2.step(0, &mut w2, &g, 0.01).unwrap();
        assert_eq!(gal2.grad_reduce_mode(0, 16, 16), GradReduceMode::Full);
    }

    #[test]
    fn compact_step_surface_bit_exact_with_monolithic_step() {
        // step(G) vs project_grad_into(G) + step_compact(R): the compact
        // surface must reproduce the monolithic step bit-for-bit when fed
        // the same gradient — the property that makes the compact DP
        // all-reduce exact in real arithmetic.
        let cfg = GaLoreConfig { rank: 4, update_freq: 4, scale: 0.25, ..Default::default() };
        let mut mono = GaLore::new(cfg, adam());
        let mut split = GaLore::new(cfg, adam());
        let mut rng = Rng::new(53);
        let mut w_mono = Matrix::randn(12, 20, 1.0, &mut rng);
        let mut w_split = w_mono.clone();
        let mut compact = Matrix::zeros(0, 0);
        for s in 0..11 {
            let g = Matrix::randn(12, 20, 1.0, &mut rng.child(s));
            mono.step(0, &mut w_mono, &g, 0.01).unwrap();
            match split.grad_reduce_mode(0, 12, 20) {
                GradReduceMode::Full => split.step(0, &mut w_split, &g, 0.01).unwrap(),
                GradReduceMode::Compact { rows, cols } => {
                    assert!(split.project_grad_into(0, &g, &mut compact));
                    assert_eq!(compact.shape(), (rows, cols));
                    split.step_compact(0, &mut w_split, &compact, 0.01).unwrap();
                }
            }
            assert_eq!(w_mono.data, w_split.data, "diverged at step {s}");
        }
        assert_eq!(mono.state_bytes(), split.state_bytes());
        assert_eq!(mono.rank_profile(), split.rank_profile());
    }

    #[test]
    fn step_compact_rejected_at_refresh_boundary() {
        // No `.expect` mid-run: misuse of the compact entry surfaces as a
        // recoverable error, not a panic (the DP worker loop propagates it).
        let cfg = GaLoreConfig { rank: 4, update_freq: 2, scale: 0.25, ..Default::default() };
        let mut gal = GaLore::new(cfg, adam());
        let mut rng = Rng::new(55);
        let mut w = Matrix::randn(8, 12, 1.0, &mut rng);
        let g = Matrix::randn(8, 12, 1.0, &mut rng);
        let mut fresh = GaLore::new(cfg, adam());
        let err = fresh.step_compact(0, &mut w, &g, 0.01).unwrap_err();
        assert!(err.contains("before its first full step"), "{err}");
        gal.step(0, &mut w, &g, 0.01).unwrap(); // t=1
        let compact = gal.projector(0).unwrap().project(&g);
        gal.step_compact(0, &mut w, &compact, 0.01).unwrap(); // t=2: fine
        let err = gal.step_compact(0, &mut w, &compact, 0.01).unwrap_err();
        assert!(err.contains("refresh boundary"), "{err}"); // t=2 % 2 == 0
    }

    #[test]
    fn step_planned_matches_sequential_walk() {
        // The parallel `step_planned` override (pool fan-out, pre-projected
        // compact tasks) must be bit-identical to the sequential
        // step/step_compact walk the trait default performs — the invariant
        // the DP bucketed-overlap path rests on.
        let cfg = || GaLoreConfig { rank: 4, update_freq: 4, scale: 0.25, ..Default::default() };
        let mut par = GaLore::new(cfg(), adam());
        let mut seq = GaLore::new(cfg(), adam());
        let mut rng = Rng::new(7);
        // Two targets plus a small untargeted parameter (min dim <= rank),
        // so all three ParKind arms get exercised across refresh cycles.
        let shapes = [(16usize, 24usize), (12, 20), (3, 4)];
        let mut wp: Vec<Matrix> =
            shapes.iter().map(|&(r, c)| Matrix::randn(r, c, 1.0, &mut rng)).collect();
        let mut ws: Vec<Matrix> = wp.clone();
        for step in 0..10u64 {
            let grads: Vec<Matrix> = shapes
                .iter()
                .enumerate()
                .map(|(i, &(r, c))| Matrix::randn(r, c, 1.0, &mut rng.child(step * 10 + i as u64)))
                .collect();
            // Build the DP plan + compact buffers the way `plan_grads` does.
            let mut plan = Vec::new();
            let mut compact = Vec::new();
            for (i, g) in grads.iter().enumerate() {
                let mode = seq.grad_reduce_mode(i, g.rows, g.cols);
                assert_eq!(mode, par.grad_reduce_mode(i, g.rows, g.cols));
                let mut c = Matrix::zeros(0, 0);
                if let GradReduceMode::Compact { .. } = mode {
                    assert!(seq.project_grad_into(i, g, &mut c));
                }
                plan.push(mode);
                compact.push(c);
            }
            par.step_planned(0, &mut wp, &grads, &plan, &compact, 0.01).unwrap();
            for i in 0..grads.len() {
                match plan[i] {
                    GradReduceMode::Full => seq.step(i, &mut ws[i], &grads[i], 0.01).unwrap(),
                    GradReduceMode::Compact { .. } => {
                        seq.step_compact(i, &mut ws[i], &compact[i], 0.01).unwrap()
                    }
                }
            }
            for (a, b) in wp.iter().zip(ws.iter()) {
                assert_slice_close(&a.data, &b.data, 0.0, 0.0);
            }
        }
    }
}
