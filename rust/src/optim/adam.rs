//! Adam / AdamW (Kingma & Ba 2015; Loshchilov & Hutter 2019) — the paper's
//! full-rank baseline (Eqns. 2–4). State: M, V ∈ R^{m×n} per parameter,
//! i.e. 2·mn floats — the memory GaLore attacks.

use super::adaptive::StateRemap;
use super::{bias_correction, Optimizer};
use crate::ser;
use crate::tensor::Matrix;
use std::collections::HashMap;

#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight decay (AdamW when > 0).
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        // The paper's §5.1 defaults.
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

impl AdamConfig {
    pub fn adamw(weight_decay: f32) -> Self {
        AdamConfig { weight_decay, ..Default::default() }
    }
}

struct State {
    m: Matrix,
    v: Matrix,
    /// Reusable buffer for the normalized update — working memory, not
    /// optimizer state (excluded from `state_bytes`, like the transient
    /// the allocating path used to create each step).
    upd: Matrix,
    t: u64,
}

pub struct Adam {
    cfg: AdamConfig,
    states: HashMap<usize, State>,
    decoupled: bool,
}

impl Adam {
    pub fn new(cfg: AdamConfig) -> Self {
        let decoupled = cfg.weight_decay > 0.0;
        Adam { cfg, states: HashMap::new(), decoupled }
    }

    /// Plain Adam with paper defaults.
    pub fn default_paper() -> Self {
        Self::new(AdamConfig::default())
    }

    /// AdamW with decoupled weight decay.
    pub fn adamw(weight_decay: f32) -> Self {
        Self::new(AdamConfig::adamw(weight_decay))
    }

    /// Expose the bias-corrected update direction for one grad without
    /// touching the weight (used by GaLore's compact-space path and tests).
    /// Allocating wrapper over [`Adam::normalized_update_into`].
    pub fn normalized_update(
        state_m: &mut Matrix,
        state_v: &mut Matrix,
        g: &Matrix,
        t: u64,
        cfg: &AdamConfig,
    ) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        Self::normalized_update_into(state_m, state_v, g, t, cfg, &mut out);
        out
    }

    /// As [`Adam::normalized_update`], writing the direction into a
    /// caller-provided buffer — the allocation-free hot path
    /// (EXPERIMENTS.md §Perf). Same arithmetic, bit-for-bit.
    pub fn normalized_update_into(
        state_m: &mut Matrix,
        state_v: &mut Matrix,
        g: &Matrix,
        t: u64,
        cfg: &AdamConfig,
        out: &mut Matrix,
    ) {
        debug_assert_eq!(state_m.shape(), g.shape());
        let (b1, b2) = (cfg.beta1, cfg.beta2);
        state_m.zip_inplace(g, |m, gi| b1 * m + (1.0 - b1) * gi);
        state_v.zip_inplace(g, |v, gi| b2 * v + (1.0 - b2) * gi * gi);
        let bc1 = bias_correction(b1, t);
        let bc2 = bias_correction(b2, t);
        out.resize(g.rows, g.cols);
        for ((nv, &mv), &vv) in
            out.data.iter_mut().zip(state_m.data.iter()).zip(state_v.data.iter())
        {
            let m_hat = mv / bc1;
            let v_hat = vv / bc2;
            *nv = m_hat / (v_hat.sqrt() + cfg.eps);
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        let state = self.states.entry(param).or_insert_with(|| State {
            m: Matrix::zeros(grad.rows, grad.cols),
            v: Matrix::zeros(grad.rows, grad.cols),
            upd: Matrix::zeros(grad.rows, grad.cols),
            t: 0,
        });
        state.t += 1;
        Adam::normalized_update_into(
            &mut state.m,
            &mut state.v,
            grad,
            state.t,
            &self.cfg,
            &mut state.upd,
        );
        if self.decoupled {
            let wd = self.cfg.weight_decay;
            w.map_inplace(|x| x * (1.0 - lr * wd));
        }
        w.axpy(-lr, &state.upd);
        Ok(())
    }

    /// The step-backend moment borrow (`optim::backend`): hand out this
    /// parameter's M/V/t, creating them zeroed at `(rows, cols)` on first
    /// touch — exactly what `step` would create. Restricted to the paper-
    /// default configuration (β₁=0.9, β₂=0.999, ε=1e-8, no decoupled
    /// decay), because that is what the fused `galore_step` artifacts are
    /// lowered with; any other configuration opts out so a backend cannot
    /// silently apply mismatched arithmetic.
    fn moments_mut(
        &mut self,
        param: usize,
        rows: usize,
        cols: usize,
    ) -> Option<super::backend::MomentsMut<'_>> {
        let d = AdamConfig::default();
        if self.decoupled
            || self.cfg.beta1 != d.beta1
            || self.cfg.beta2 != d.beta2
            || self.cfg.eps != d.eps
        {
            return None;
        }
        let state = self.states.entry(param).or_insert_with(|| State {
            m: Matrix::zeros(rows, cols),
            v: Matrix::zeros(rows, cols),
            upd: Matrix::zeros(rows, cols),
            t: 0,
        });
        Some(super::backend::MomentsMut {
            m: &mut state.m,
            v: &mut state.v,
            t: &mut state.t,
            upd: &mut state.upd,
        })
    }

    fn state_bytes(&self) -> usize {
        self.states.values().map(|s| 4 * (s.m.len() + s.v.len())).sum()
    }

    fn name(&self) -> &'static str {
        if self.decoupled {
            "adamw"
        } else {
            "adam"
        }
    }

    fn reset_state(&mut self) {
        self.states.clear();
    }

    /// Rank adaptation: rotate M linearly and mix V through the squared
    /// transition (see `optim::adaptive`) so a compact-space change keeps
    /// the warmed-up moments instead of cold-starting them. `t` is kept —
    /// bias correction continues across the change. Allocation-free once
    /// the remap scratch is warm.
    fn remap_state(&mut self, param: usize, remap: &mut StateRemap<'_>) {
        if let Some(s) = self.states.get_mut(&param) {
            remap.first_moment(&mut s.m);
            remap.second_moment(&mut s.v);
        }
    }

    /// Checkpoint v2: M/V moments and the per-parameter step counter,
    /// sorted by parameter id for a deterministic byte stream. The `upd`
    /// scratch is working memory (fully rewritten every step) and is
    /// recreated as zeros on load.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        let mut params: Vec<usize> = self.states.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in params {
            let s = &self.states[&p];
            ser::put_usize(out, p);
            ser::put_u64(out, s.t);
            ser::put_matrix(out, &s.m);
            ser::put_matrix(out, &s.v);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.states.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let t = r.u64()?;
            let m = r.matrix()?;
            let v = r.matrix()?;
            if m.shape() != v.shape() {
                return Err(format!(
                    "adam param {p}: M shape {:?} != V shape {:?}",
                    m.shape(),
                    v.shape()
                ));
            }
            let upd = Matrix::zeros(m.rows, m.cols);
            self.states.insert(p, State { m, v, upd, t });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::converges_on_quadratic;

    #[test]
    fn first_step_is_signlike() {
        // At t=1 from zero state, update ≈ sign(g) * lr (Adam property).
        let mut adam = Adam::default_paper();
        let mut w = Matrix::zeros(2, 2);
        let g = Matrix::from_vec(2, 2, vec![0.5, -2.0, 1e-3, -1e-3]);
        adam.step(0, &mut w, &g, 0.1).unwrap();
        for (wv, gv) in w.data.iter().zip(g.data.iter()) {
            assert!((wv + 0.1 * gv.signum()).abs() < 1e-2, "{wv} vs {gv}");
        }
    }

    #[test]
    fn converges_on_quadratic_bowl() {
        let mut adam = Adam::default_paper();
        let (d0, d1) = converges_on_quadratic(&mut adam, 300, 0.05);
        assert!(d1 < 0.05 * d0, "d0={d0} d1={d1}");
    }

    #[test]
    fn adamw_decays_weights() {
        let mut adamw = Adam::adamw(0.1);
        let mut w = Matrix::ones(4, 4);
        let g = Matrix::zeros(4, 4);
        for _ in 0..10 {
            adamw.step(0, &mut w, &g, 0.1).unwrap();
        }
        // Pure decay: w = (1 - 0.01)^10.
        for &wv in &w.data {
            assert!((wv - 0.99f32.powi(10)).abs() < 1e-4);
        }
    }

    #[test]
    fn state_bytes_is_2mn_f32() {
        let mut adam = Adam::default_paper();
        let mut w = Matrix::zeros(8, 16);
        let g = Matrix::ones(8, 16);
        adam.step(0, &mut w, &g, 0.01).unwrap();
        assert_eq!(adam.state_bytes(), 2 * 8 * 16 * 4);
    }

    #[test]
    fn independent_params_have_independent_state() {
        let mut adam = Adam::default_paper();
        let mut w0 = Matrix::zeros(2, 2);
        let mut w1 = Matrix::zeros(3, 3);
        let g0 = Matrix::ones(2, 2);
        let g1 = Matrix::ones(3, 3);
        adam.step(0, &mut w0, &g0, 0.1).unwrap();
        adam.step(1, &mut w1, &g1, 0.1).unwrap();
        adam.step(0, &mut w0, &g0, 0.1).unwrap();
        assert_eq!(adam.state_bytes(), (2 * 4 + 2 * 9) * 4);
    }
}
