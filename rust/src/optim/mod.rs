//! Optimizer zoo.
//!
//! Everything the paper's evaluation touches: Adam/AdamW (the full-rank
//! baseline, Eqns. 2–4), Adafactor (first-moment variant, §5.2), SGD with
//! momentum (Lemma 3.3 dynamics), block-wise 8-bit Adam (§4.3), and the
//! **GaLore projector** (`galore::Projector`) plus the generic
//! `galore::GaLore<O>` wrapper that turns any of them into their
//! memory-efficient GaLore variant (Algorithm 1: project → update →
//! project-back).
//!
//! All optimizers implement [`Optimizer`]: a per-parameter, shape-aware
//! fallible `step` that applies the update in-place on the weight and
//! reports its state memory via `state_bytes` (the number the memory
//! benches check against `memory::formulas`). The trait also carries the
//! opt-in surfaces the coordinator composes through one object:
//!
//! * the **compact data-parallel plan** — `grad_reduce_mode` /
//!   `project_grad_into` / `step_compact` (§5.5, `dp_compress`),
//! * **full-state checkpointing** — `save_state` / `load_state`
//!   (checkpoint v2, `coordinator::checkpoint`),
//! * **rank adaptation** — `remap_state` (basis-change moment transport),
//! * the **moment borrow** — `moments_mut`, through which a
//!   [`StepBackend`](backend::StepBackend) executes the update on another
//!   substrate (the AOT artifacts) against the optimizer's own state.
//!
//! Execution substrate is a *backend choice*, not a different optimizer:
//! `GaLore<O>` runs its compact update through a pluggable
//! [`backend::StepBackend`] (pure Rust by default, the fused Pallas/HLO
//! artifacts via [`backend::ArtifactBackend`]), so data parallelism, rank
//! schedules, quantized projectors, and checkpointing compose with either
//! substrate through this one trait.

mod adafactor;
mod adam;
mod adam8bit;
pub mod adaptive;
pub mod backend;
pub mod galore;
pub mod rank;
mod sgd;

pub use adafactor::Adafactor;
pub use adam::{Adam, AdamConfig};
pub use adam8bit::Adam8bit;
pub use adaptive::{basis_transition_into, RankState, StateRemap};
pub use backend::{ArtifactBackend, MomentsMut, RustBackend, StepBackend, StepCtx, StepScratch};
pub use galore::{GaLore, GaLoreConfig, ProjSide, Projector, ProjectorQuant};
pub use rank::{subspace_cosine, RankSchedule, RankScheduleKind, RefreshGate};
pub use sgd::Sgd;

use crate::tensor::Matrix;

/// How a data-parallel worker should exchange one parameter's gradient
/// this step (the §5.5 communication plan). `Full` ships the whole `m×n`
/// gradient; `Compact` ships the projected `r×n` (or `m×r`) gradient —
/// valid only between subspace refreshes, when every replica holds the
/// same basis and the update consumes nothing but `Pᵀ G`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradReduceMode {
    /// Reduce the full gradient (non-target params, refresh boundaries,
    /// optimizers without a compact surface).
    Full,
    /// Reduce the compact projected gradient of this shape.
    Compact { rows: usize, cols: usize },
}

impl GradReduceMode {
    /// Elements exchanged per all-reduce for a full gradient of
    /// `rows × cols` under this mode.
    pub fn payload_f32s(&self, full_rows: usize, full_cols: usize) -> usize {
        match self {
            GradReduceMode::Full => full_rows * full_cols,
            GradReduceMode::Compact { rows, cols } => rows * cols,
        }
    }
}

/// A stateful, per-parameter optimizer. Parameters are identified by a
/// stable index (schema order) so state survives across steps.
pub trait Optimizer: Send {
    /// Apply one update: `w <- w - f(grad)` for this parameter.
    /// `lr` is the (already scheduled) learning rate for this step.
    ///
    /// Fallible: an optimizer whose step can fault at run time (the
    /// artifact backend's engine call, a violated state invariant) reports
    /// the fault instead of panicking mid-run, and must keep its state
    /// *consistent* on error: the failed update itself is not applied
    /// (weights and moments unmodified) and step accounting is rolled
    /// back, so the trainer stays checkpointable and cadence-dependent
    /// plans (`grad_reduce_mode`) are not shifted by a step that never
    /// applied. A subspace refresh that preceded the failure may stay
    /// committed — it is a valid basis decision independent of the failed
    /// update (`GaLore` documents this at its rollback site). Pure-Rust
    /// arithmetic paths simply return `Ok(())`.
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String>;

    /// Apply one update to *every* parameter at once (`weights[i]` and
    /// `grads[i]` are parameter `i` in schema order — the whole roster,
    /// exactly as the trainer's dense update walk hands it over).
    ///
    /// Contract: **bit-identical** to the sequential loop
    /// `for i { self.step(i, &mut weights[i], &grads[i], lr) }` — the
    /// default *is* that loop. Implementations may reorder or parallelize
    /// *across* parameters (per-parameter state is independent), but every
    /// shared-state interaction (RNG draws at subspace refreshes, shared
    /// SVD scratch) must happen in ascending parameter order, and each
    /// parameter's own arithmetic must be unchanged. `GaLore<O>` overrides
    /// this to step independent layers in parallel across the worker pool
    /// between refreshes (pinned by the parity tests in
    /// `rust/tests/hotpath_props.rs`). On error, parameters before the
    /// failing one may already be stepped — the same partial-progress
    /// semantics the sequential trainer loop always had.
    fn step_many(
        &mut self,
        weights: &mut [Matrix],
        grads: &[Matrix],
        lr: f32,
    ) -> Result<(), String> {
        if weights.len() != grads.len() {
            return Err(format!(
                "step_many: {} weights vs {} gradients",
                weights.len(),
                grads.len()
            ));
        }
        for (idx, (w, g)) in weights.iter_mut().zip(grads.iter()).enumerate() {
            self.step(idx, w, g, lr)?;
        }
        Ok(())
    }

    /// Bytes of optimizer state currently held for all parameters.
    fn state_bytes(&self) -> usize;

    /// Human-readable name (used by benches and metrics).
    fn name(&self) -> &'static str;

    /// Hook for subspace/trainer events ("new subspace / merge"); no-op by
    /// default.
    fn reset_state(&mut self) {}

    /// Called by `GaLore<O>` when a projected parameter's compact space
    /// changes shape (rank adaptation): carry this parameter's state into
    /// the new coordinates via `remap`, or at minimum drop the
    /// parameter's state so the next `step` re-creates it at the new
    /// shape. Optimizers that can never be a GaLore inner (or hold no
    /// per-shape state) may keep the no-op default.
    fn remap_state(&mut self, _param: usize, _remap: &mut StateRemap<'_>) {}

    /// (param, rank) pairs for every low-rank-projected parameter —
    /// non-empty only for GaLore wrappers. Lets the coordinator report
    /// per-layer ranks through `Box<dyn Optimizer>` without downcasting.
    fn rank_profile(&self) -> Vec<(usize, usize)> {
        Vec::new()
    }

    /// Total lazy-refresh-gate skips across parameters (non-zero only for
    /// GaLore wrappers running with `refresh_gate_cos` enabled).
    fn gate_skips(&self) -> u64 {
        0
    }

    /// How a data-parallel worker should exchange this parameter's
    /// gradient on its *next* `step`/`step_compact` call. `rows`/`cols`
    /// are the full gradient shape. The default (and the only mode
    /// non-projecting optimizers ever report) is [`GradReduceMode::Full`];
    /// GaLore wrappers report `Compact` between subspace refreshes, where
    /// the update consumes only `Pᵀ G` and replicas hold bit-identical
    /// bases. Contract: when this returns `Compact`, `project_grad_into`
    /// must succeed and `step_compact` must be the step entry point.
    fn grad_reduce_mode(&self, _param: usize, _rows: usize, _cols: usize) -> GradReduceMode {
        GradReduceMode::Full
    }

    /// Project `grad` into this parameter's compact space (`out` is a
    /// caller-owned workspace, resized as needed). Returns `false` — and
    /// leaves `out` untouched — when the parameter currently reduces
    /// full (see [`Optimizer::grad_reduce_mode`]).
    fn project_grad_into(&self, _param: usize, _grad: &Matrix, _out: &mut Matrix) -> bool {
        false
    }

    /// Apply one update from an already-projected (and, under data
    /// parallelism, already-averaged) compact gradient. Arithmetically
    /// interchangeable with `step` fed the corresponding full gradient
    /// (bit-identical on the Rust backend, which computes exactly this
    /// projection first). Only callable when `grad_reduce_mode` returned
    /// `Compact` for this parameter; the default errs because plain
    /// optimizers have no compact space (no `.expect` mid-run — the DP
    /// worker loop propagates this instead of aborting the process).
    fn step_compact(
        &mut self,
        _param: usize,
        _w: &mut Matrix,
        _compact: &Matrix,
        _lr: f32,
    ) -> Result<(), String> {
        Err(format!(
            "optimizer '{}' cannot consume compact (pre-projected) gradients — \
             grad_reduce_mode never returns Compact for it",
            self.name()
        ))
    }

    /// Step a contiguous parameter range `[base, base + weights.len())`
    /// under a data-parallel communication plan: parameters the plan
    /// reduced in full consume `grads[i]` via `step`, compact-reduced
    /// ones consume the averaged `Pᵀ G` in `compact[i]` via
    /// `step_compact`. Contract: **bit-identical** to walking the range
    /// sequentially in ascending order with those calls — overrides may
    /// parallelize (GaLore steps disjoint layers across the worker pool)
    /// but never reorder observable state updates. The bucketed DP
    /// exchange applies each reduced bucket through this entry point.
    fn step_planned(
        &mut self,
        base: usize,
        weights: &mut [Matrix],
        grads: &[Matrix],
        plan: &[GradReduceMode],
        compact: &[Matrix],
        lr: f32,
    ) -> Result<(), String> {
        if weights.len() != grads.len()
            || plan.len() != grads.len()
            || compact.len() != grads.len()
        {
            return Err(format!(
                "step_planned: {} weights vs {} gradients ({} plan entries, {} compact buffers)",
                weights.len(),
                grads.len(),
                plan.len(),
                compact.len()
            ));
        }
        for (i, w) in weights.iter_mut().enumerate() {
            match plan[i] {
                GradReduceMode::Full => self.step(base + i, w, &grads[i], lr)?,
                GradReduceMode::Compact { .. } => {
                    self.step_compact(base + i, w, &compact[i], lr)?
                }
            }
        }
        Ok(())
    }

    /// Opt-in surface for step backends that execute the update on another
    /// substrate (the AOT-artifact backend): borrow this parameter's
    /// Adam-style moment state — `M`, `V`, and the 1-based step counter —
    /// creating it zeroed at `(rows, cols)` on first touch. `None` means
    /// the optimizer holds no such state in the layout the fused kernels
    /// were lowered for (different algorithm, quantized moments, decoupled
    /// decay, or non-default hyperparameters) and the backend must not
    /// bypass `step`. Whatever a backend writes through the borrow *is*
    /// the optimizer's state: checkpoints, `remap_state`, and later
    /// `step` calls all see it.
    fn moments_mut(
        &mut self,
        _param: usize,
        _rows: usize,
        _cols: usize,
    ) -> Option<backend::MomentsMut<'_>> {
        None
    }

    /// Serialize the optimizer's *complete* state (moments, step counters,
    /// projector bases, RNG streams — everything `step` reads) into `out`
    /// using the `crate::ser` vocabulary, such that `load_state` on a
    /// freshly constructed optimizer of the same configuration reproduces
    /// the uninterrupted trajectory bit-for-bit (checkpoint v2 contract,
    /// `coordinator::checkpoint`). The default refuses: an optimizer that
    /// has not opted in must fail a checkpoint loudly rather than silently
    /// dropping its state.
    fn save_state(&self, _out: &mut Vec<u8>) -> Result<(), String> {
        Err(format!("optimizer '{}' does not support full-state checkpointing", self.name()))
    }

    /// Restore state written by `save_state`. The optimizer must already be
    /// constructed with the same configuration (targets, seeds, knobs) —
    /// only the mutable training state travels through the blob.
    fn load_state(&mut self, _r: &mut crate::ser::Reader<'_>) -> Result<(), String> {
        Err(format!("optimizer '{}' does not support full-state checkpointing", self.name()))
    }
}

/// Bias-correction factor `1 - beta^t` shared by the moment optimizers.
pub(crate) fn bias_correction(beta: f32, t: u64) -> f32 {
    1.0 - beta.powi(t as i32)
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::rng::Rng;

    /// Quadratic bowl: f(W) = 0.5 * ||W - W*||_F^2, grad = W - W*.
    /// Any sane optimizer must reduce distance to W* substantially.
    pub fn converges_on_quadratic(opt: &mut dyn Optimizer, steps: usize, lr: f32) -> (f32, f32) {
        let mut rng = Rng::new(0);
        let w_star = Matrix::randn(16, 24, 1.0, &mut rng);
        let mut w = Matrix::zeros(16, 24);
        let d0 = dist(&w, &w_star);
        for _ in 0..steps {
            let mut g = w.clone();
            g.sub_assign(&w_star);
            opt.step(0, &mut w, &g, lr).unwrap();
        }
        (d0, dist(&w, &w_star))
    }

    pub fn dist(a: &Matrix, b: &Matrix) -> f32 {
        let mut d = a.clone();
        d.sub_assign(b);
        d.frobenius_norm()
    }
}
