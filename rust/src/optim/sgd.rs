//! SGD with optional momentum. Used by the Lemma 3.3 low-rank-dynamics
//! experiment (vanilla SGD) and as the cheapest baseline.

use super::Optimizer;
use crate::ser;
use crate::tensor::Matrix;
use std::collections::HashMap;

pub struct Sgd {
    momentum: f32,
    velocity: HashMap<usize, Matrix>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Self {
        Sgd { momentum, velocity: HashMap::new() }
    }

    pub fn vanilla() -> Self {
        Self::new(0.0)
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        if self.momentum == 0.0 {
            w.axpy(-lr, grad);
            return Ok(());
        }
        let v = self
            .velocity
            .entry(param)
            .or_insert_with(|| Matrix::zeros(grad.rows, grad.cols));
        let mu = self.momentum;
        v.zip_inplace(grad, |vv, g| mu * vv + g);
        w.axpy(-lr, v);
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.velocity.values().map(|v| 4 * v.len()).sum()
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn reset_state(&mut self) {
        self.velocity.clear();
    }

    /// Rank adaptation: momentum is a first moment — rotate it linearly.
    fn remap_state(&mut self, param: usize, remap: &mut super::adaptive::StateRemap<'_>) {
        if let Some(v) = self.velocity.get_mut(&param) {
            remap.first_moment(v);
        }
    }

    /// Checkpoint v2: the velocity buffers (empty for vanilla SGD).
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        let mut params: Vec<usize> = self.velocity.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in params {
            ser::put_usize(out, p);
            ser::put_matrix(out, &self.velocity[&p]);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.velocity.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let v = r.matrix()?;
            self.velocity.insert(p, v);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::converges_on_quadratic;

    #[test]
    fn vanilla_sgd_matches_closed_form() {
        let mut sgd = Sgd::vanilla();
        let mut w = Matrix::ones(1, 1);
        // grad = w on a quadratic: w_t = (1 - lr)^t.
        for _ in 0..10 {
            let g = w.clone();
            sgd.step(0, &mut w, &g, 0.1).unwrap();
        }
        assert!((w.at(0, 0) - 0.9f32.powi(10)).abs() < 1e-6);
        assert_eq!(sgd.state_bytes(), 0);
    }

    #[test]
    fn momentum_accelerates() {
        let mut plain = Sgd::vanilla();
        let mut mom = Sgd::new(0.9);
        let (_, d_plain) = converges_on_quadratic(&mut plain, 40, 0.01);
        let (_, d_mom) = converges_on_quadratic(&mut mom, 40, 0.01);
        assert!(d_mom < d_plain, "momentum {d_mom} vs plain {d_plain}");
    }
}
