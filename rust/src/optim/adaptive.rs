//! The rank-adaptation subsystem: everything `GaLore<O>` needs to let a
//! layer's projector shrink or grow its rank at subspace-refresh
//! boundaries *without* throwing the inner optimizer's moments away.
//!
//! The policy decisions live in [`crate::optim::rank`]; this module holds
//! the mechanics:
//!
//! * [`RankState`] — per-parameter bookkeeping (current rank, refreshes
//!   performed, lazy-refresh gate skips, last measured cosine).
//! * [`basis_transition_into`] — the transition matrix `T` between the
//!   outgoing and incoming projector bases, written into caller buffers
//!   (allocation-free once warm, like every other hot-path kernel).
//! * [`StateRemap`] — the carry-over context handed to
//!   [`crate::optim::Optimizer::remap_state`] when a projected parameter's
//!   compact space changes shape. First moments are rotated linearly
//!   (`M' = T M` for Left-side parameters, `M' = M T` for Right-side);
//!   second moments are mixed through `T∘T` — if `v ≈ E[r²]` and
//!   `r' = T r`, then `E[r'²_i] = Σ_j T²_ij E[r²_j]` under coordinate
//!   independence — which also preserves nonnegativity. This is the
//!   AdaRankGrad-style moment projection; optimizers whose state cannot be
//!   rotated (quantized or factored statistics) instead drop the
//!   parameter's state and let the EMA warm back up.
//!
//! Both transforms contract Frobenius norm (`T = P_newᵀ P_old` is a
//! product of orthonormal-projection factors, so `‖T‖₂ ≤ 1`), the property
//! pinned by `tests/adaptive_props.rs`.

use super::galore::ProjSide;
use crate::tensor::{matmul_at_b_into, matmul_into, Matrix};

/// Per-parameter rank-adaptation bookkeeping, exposed by
/// `GaLore::rank_state` for metrics and tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankState {
    /// Rank currently in use by this parameter's projector.
    pub rank: usize,
    /// Subspace refreshes actually performed (SVDs run).
    pub refreshes: u64,
    /// Refresh boundaries skipped by the cosine lazy-refresh gate.
    pub gate_skips: u64,
    /// Current run of back-to-back gate skips (reset by a real refresh).
    /// Under an adaptive schedule the skip streak is capped so the gate
    /// cannot starve the rank policy of sketches forever.
    pub consecutive_skips: u64,
    /// Cosine similarity measured at the most recent gated boundary.
    pub last_cosine: f32,
}

/// Write the basis-transition matrix between two projector bases into
/// `trans`, and its elementwise square into `trans_sq`.
///
/// Left side (bases are (m, r)): `T = P_newᵀ P_old`, shape (r_new, r_old).
/// Right side (bases are (n, r)): `T = Q_oldᵀ Q_new`, shape (r_old, r_new),
/// so that `M' = M T` maps Right-side compact moments `M ∈ R^{m×r_old}`.
pub fn basis_transition_into(
    old: &Matrix,
    new: &Matrix,
    side: ProjSide,
    trans: &mut Matrix,
    trans_sq: &mut Matrix,
) {
    match side {
        ProjSide::Left => matmul_at_b_into(new, old, trans),
        ProjSide::Right => matmul_at_b_into(old, new, trans),
    }
    trans_sq.copy_from(trans);
    trans_sq.map_inplace(|x| x * x);
}

/// Moment carry-over context for one compact-space change. Borrowed
/// buffers come from the `GaLore` per-parameter workspace, so a remap in
/// the steady state performs zero heap allocations.
pub struct StateRemap<'a> {
    side: ProjSide,
    trans: &'a Matrix,
    trans_sq: &'a Matrix,
    scratch: &'a mut Matrix,
}

impl<'a> StateRemap<'a> {
    pub fn new(
        side: ProjSide,
        trans: &'a Matrix,
        trans_sq: &'a Matrix,
        scratch: &'a mut Matrix,
    ) -> StateRemap<'a> {
        StateRemap { side, trans, trans_sq, scratch }
    }

    /// Rank of the outgoing basis.
    pub fn old_rank(&self) -> usize {
        match self.side {
            ProjSide::Left => self.trans.cols,
            ProjSide::Right => self.trans.rows,
        }
    }

    /// Rank of the incoming basis.
    pub fn new_rank(&self) -> usize {
        match self.side {
            ProjSide::Left => self.trans.rows,
            ProjSide::Right => self.trans.cols,
        }
    }

    fn carry(side: ProjSide, trans: &Matrix, scratch: &mut Matrix, state: &mut Matrix) {
        match side {
            // (r_new, r_old) @ (r_old, n) -> (r_new, n)
            ProjSide::Left => matmul_into(trans, state, scratch),
            // (m, r_old) @ (r_old, r_new) -> (m, r_new)
            ProjSide::Right => matmul_into(state, trans, scratch),
        }
        state.copy_from(scratch);
    }

    /// Carry a first-moment matrix into the new basis coordinates
    /// (linear rotation; Frobenius norm never grows).
    pub fn first_moment(&mut self, state: &mut Matrix) {
        Self::carry(self.side, self.trans, self.scratch, state);
    }

    /// Carry a second-moment (elementwise-variance) matrix: mixed through
    /// `T∘T`, then clamped at zero so downstream `sqrt`s stay defined.
    pub fn second_moment(&mut self, state: &mut Matrix) {
        Self::carry(self.side, self.trans_sq, self.scratch, state);
        for v in state.data.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr;
    use crate::rng::Rng;

    fn orthonormal(m: usize, r: usize, rng: &mut Rng) -> Matrix {
        qr(&Matrix::randn(m, r, 1.0, rng)).q
    }

    #[test]
    fn identity_transition_preserves_moments() {
        let mut rng = Rng::new(0);
        let p = orthonormal(24, 6, &mut rng);
        let mut trans = Matrix::zeros(0, 0);
        let mut trans_sq = Matrix::zeros(0, 0);
        basis_transition_into(&p, &p, ProjSide::Left, &mut trans, &mut trans_sq);
        // PᵀP = I for an orthonormal basis.
        let mut m = Matrix::randn(6, 10, 1.0, &mut rng);
        let before = m.clone();
        let mut scratch = Matrix::zeros(0, 0);
        let mut remap = StateRemap::new(ProjSide::Left, &trans, &trans_sq, &mut scratch);
        assert_eq!(remap.old_rank(), 6);
        assert_eq!(remap.new_rank(), 6);
        remap.first_moment(&mut m);
        let mut d = m.clone();
        d.sub_assign(&before);
        assert!(d.frobenius_norm() < 1e-4 * before.frobenius_norm());
    }

    #[test]
    fn rank_shrink_contracts_norm_and_keeps_v_nonnegative() {
        let mut rng = Rng::new(1);
        let old = orthonormal(32, 8, &mut rng);
        let new = orthonormal(32, 4, &mut rng);
        let mut trans = Matrix::zeros(0, 0);
        let mut trans_sq = Matrix::zeros(0, 0);
        basis_transition_into(&old, &new, ProjSide::Left, &mut trans, &mut trans_sq);
        assert_eq!(trans.shape(), (4, 8));
        let mut m = Matrix::randn(8, 12, 1.0, &mut rng);
        let m_norm = m.frobenius_norm();
        let mut v = Matrix::randn(8, 12, 1.0, &mut rng);
        v.map_inplace(|x| x * x);
        let v_sum = v.sum();
        let mut scratch = Matrix::zeros(0, 0);
        let mut remap = StateRemap::new(ProjSide::Left, &trans, &trans_sq, &mut scratch);
        remap.first_moment(&mut m);
        remap.second_moment(&mut v);
        assert_eq!(m.shape(), (4, 12));
        assert_eq!(v.shape(), (4, 12));
        assert!(m.frobenius_norm() <= m_norm * (1.0 + 1e-4));
        assert!(v.data.iter().all(|&x| x >= 0.0));
        assert!(v.sum() <= v_sum * (1.0 + 1e-4));
    }

    #[test]
    fn right_side_maps_column_indexed_moments() {
        let mut rng = Rng::new(2);
        let old = orthonormal(20, 6, &mut rng);
        let new = orthonormal(20, 3, &mut rng);
        let mut trans = Matrix::zeros(0, 0);
        let mut trans_sq = Matrix::zeros(0, 0);
        basis_transition_into(&old, &new, ProjSide::Right, &mut trans, &mut trans_sq);
        assert_eq!(trans.shape(), (6, 3));
        let mut m = Matrix::randn(10, 6, 1.0, &mut rng);
        let norm = m.frobenius_norm();
        let mut scratch = Matrix::zeros(0, 0);
        let mut remap = StateRemap::new(ProjSide::Right, &trans, &trans_sq, &mut scratch);
        assert_eq!(remap.old_rank(), 6);
        assert_eq!(remap.new_rank(), 3);
        remap.first_moment(&mut m);
        assert_eq!(m.shape(), (10, 3));
        assert!(m.frobenius_norm() <= norm * (1.0 + 1e-4));
    }
}
