//! Per-parameter rank policies for the GaLore projector.
//!
//! GaLore as published fixes one projection rank `r` for the whole run, but
//! the gradient's effective rank is neither uniform across layers nor
//! constant over training: Q-GaLore (arXiv:2407.08296) observes that some
//! layers' gradient subspaces converge early and tolerate aggressively
//! quantized, rarely-refreshed projectors, and AdaRankGrad
//! (arXiv:2410.17881) shows the gradient rank *shrinks* as training
//! proceeds and can be adapted online. This module holds the pure policy
//! pieces of the rank-adaptation subsystem (`optim::adaptive` wires them
//! into `GaLore<O>`):
//!
//! * [`RankSchedule`] — decides each layer's rank at subspace-refresh
//!   boundaries, from nothing (fixed), a multiplicative decay, or the
//!   singular spectrum the randomized SVD already computes at refresh.
//! * [`RefreshGate`] — the Q-GaLore-style cosine-similarity lazy-refresh
//!   gate: skip the SVD entirely when the cached basis still captures the
//!   current gradient.
//!
//! # Choosing a rank schedule
//!
//! * **`fixed`** (default) — the paper's behavior: rank `r` everywhere,
//!   forever. Use it for apples-to-apples reproductions and whenever the
//!   fused (artifact) hot path is in play — the AOT kernels are lowered for
//!   fixed shapes.
//! * **`decay`** — halve (or `rank_decay`-multiply) each layer's rank at
//!   every subspace refresh until `rank_floor`. A blunt instrument, but it
//!   needs no spectral information, is monotone in memory (optimizer-state
//!   bytes never grow — pinned by `tests/adaptive_props.rs`), and mirrors
//!   the Fig. 5-style observation that late training tolerates much
//!   smaller subspaces. Start from the paper's `r` and set `rank_floor` to
//!   `r/8` unless the loss curve says otherwise.
//! * **`spectral`** — at each refresh pick the smallest rank whose sketch
//!   singular values capture `rank_energy` (default 0.99) of the sketch
//!   energy, clamped to `[rank_floor, rank]`. This is the AdaRankGrad-style
//!   choice: layers whose gradients are genuinely low-rank shrink early and
//!   hard, layers that stay high-rank keep their budget, and a layer whose
//!   spectrum re-fattens can grow back (up to the oversampling window per
//!   refresh). Prefer it whenever memory matters and the workload is not
//!   shape-locked to artifacts.
//!
//! The lazy-refresh gate (`refresh_gate_cos`, 0 = off) composes with every
//! schedule: a typical setting of `0.6–0.9` skips most late-training SVDs
//! once subspaces stabilize, which is where Q-GaLore's wins come from.
//! Higher thresholds are stricter (fewer skips); `>= 1` is rejected by
//! validation because cosines never exceed 1.

/// Which rank policy drives a run (`galore.rank_schedule` in configs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankScheduleKind {
    /// The paper's behavior: one fixed rank for the whole run.
    Fixed,
    /// Multiply the rank by `decay` at every subspace refresh (rounding
    /// down, stepping by at least 1 while above `floor`, so slow decays
    /// cannot stall at a rounding fixed point), down to `floor`.
    Decay,
    /// Pick the smallest rank capturing `energy` of the refresh sketch's
    /// squared singular values, within `[floor, max_rank]`.
    Spectral,
}

impl RankScheduleKind {
    pub fn parse(s: &str) -> Option<RankScheduleKind> {
        Some(match s {
            "fixed" => RankScheduleKind::Fixed,
            "decay" => RankScheduleKind::Decay,
            "spectral" | "adaptive" => RankScheduleKind::Spectral,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            RankScheduleKind::Fixed => "fixed",
            RankScheduleKind::Decay => "decay",
            RankScheduleKind::Spectral => "spectral",
        }
    }
}

/// A per-parameter rank schedule: the policy plus its band and knobs.
/// Pure decision logic — no optimizer state — so it is trivially testable
/// and `Copy`-cheap to thread through the coordinator.
#[derive(Clone, Copy, Debug)]
pub struct RankSchedule {
    pub kind: RankScheduleKind,
    /// Initial rank and ceiling (the `galore.rank` knob). Buffers are
    /// warmed at this size, so staying under it keeps rank *growth*
    /// allocation-free too.
    pub max_rank: usize,
    /// Lower bound for the adaptive policies.
    pub floor: usize,
    /// Multiplicative factor per refresh (`Decay`; in (0, 1]).
    pub decay: f32,
    /// Cumulative-energy target (`Spectral`; in (0, 1]).
    pub energy: f32,
}

impl RankSchedule {
    /// The schedule every run without adaptive knobs gets.
    pub fn fixed(rank: usize) -> RankSchedule {
        RankSchedule {
            kind: RankScheduleKind::Fixed,
            max_rank: rank,
            floor: rank,
            decay: 1.0,
            energy: 1.0,
        }
    }

    pub fn is_adaptive(&self) -> bool {
        self.kind != RankScheduleKind::Fixed
    }

    /// Clamp a candidate rank into the schedule band and the matrix's
    /// feasible range (`min(m, n)`).
    pub fn clamp(&self, r: usize, min_dim: usize) -> usize {
        r.max(self.floor).min(self.max_rank).min(min_dim).max(1)
    }

    /// Decide the rank for the refresh that is about to happen.
    /// `sq_spectrum` holds the *squared* singular values of the refresh
    /// sketch, descending (empty for policies that do not need it — the
    /// spectral policy then keeps the current rank).
    pub fn next_rank(&self, current: usize, min_dim: usize, sq_spectrum: &[f32]) -> usize {
        match self.kind {
            RankScheduleKind::Fixed => self.clamp(self.max_rank, min_dim),
            RankScheduleKind::Decay => {
                // Round down and force at least one step of progress:
                // ceil() would stall at a fixed point above the floor for
                // any decay > (r-1)/r (e.g. 0.9 stalls at rank 9 forever).
                let shrunk = ((current as f32) * self.decay).floor() as usize;
                let shrunk = if self.decay < 1.0 {
                    shrunk.min(current.saturating_sub(1))
                } else {
                    current
                };
                self.clamp(shrunk, min_dim)
            }
            RankScheduleKind::Spectral => {
                if sq_spectrum.is_empty() {
                    return self.clamp(current, min_dim);
                }
                let total: f32 = sq_spectrum.iter().map(|&e| e.max(0.0)).sum();
                if total <= 0.0 {
                    // Zero gradient sketch: nothing to capture.
                    return self.clamp(self.floor, min_dim);
                }
                let target = self.energy * total;
                let mut acc = 0.0f32;
                let mut r = sq_spectrum.len();
                for (i, &e) in sq_spectrum.iter().enumerate() {
                    acc += e.max(0.0);
                    if acc >= target {
                        r = i + 1;
                        break;
                    }
                }
                self.clamp(r, min_dim)
            }
        }
    }
}

/// The Q-GaLore-style lazy-refresh gate. `threshold <= 0` disables it.
#[derive(Clone, Copy, Debug)]
pub struct RefreshGate {
    /// Skip the SVD at a refresh boundary when the cosine similarity
    /// between the gradient and its projection onto the cached subspace
    /// meets this threshold (the new basis would be nearly collinear with
    /// the cached one).
    pub threshold: f32,
}

impl RefreshGate {
    pub fn disabled() -> RefreshGate {
        RefreshGate { threshold: 0.0 }
    }

    pub fn enabled(&self) -> bool {
        self.threshold > 0.0
    }

    /// The gate *fires* — the refresh SVD is skipped — iff the gate is
    /// enabled and the cosine meets the threshold (the property pinned by
    /// `tests/adaptive_props.rs`).
    pub fn fires(&self, cosine: f32) -> bool {
        self.enabled() && cosine >= self.threshold
    }
}

/// Cosine of the angle between the gradient and its projection onto the
/// cached subspace: `‖Pᵀ G‖_F / ‖G‖_F` (Left side; `‖G Q‖_F / ‖G‖_F`
/// Right). 1.0 means the subspace still captures the gradient entirely;
/// 0.0 means the gradient is orthogonal to it. A (near-)zero gradient
/// reports 1.0 — there is nothing to refresh for. Computed from norms the
/// step has on hand anyway, so gating costs one projection and no SVD.
pub fn subspace_cosine(projected_norm: f32, grad_norm: f32) -> f32 {
    if grad_norm <= f32::EPSILON {
        return 1.0;
    }
    (projected_norm / grad_norm).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spectral(max_rank: usize, floor: usize, energy: f32) -> RankSchedule {
        RankSchedule { kind: RankScheduleKind::Spectral, max_rank, floor, decay: 1.0, energy }
    }

    #[test]
    fn fixed_always_returns_clamped_max() {
        let s = RankSchedule::fixed(16);
        assert_eq!(s.next_rank(16, 64, &[]), 16);
        assert_eq!(s.next_rank(16, 8, &[]), 8); // clamped to min_dim
        assert!(!s.is_adaptive());
    }

    #[test]
    fn decay_is_monotone_and_respects_floor() {
        let s = RankSchedule {
            kind: RankScheduleKind::Decay,
            max_rank: 32,
            floor: 4,
            decay: 0.5,
            energy: 1.0,
        };
        let mut r = 32;
        let mut seen = vec![r];
        for _ in 0..6 {
            let next = s.next_rank(r, 64, &[]);
            assert!(next <= r, "decay grew the rank: {r} -> {next}");
            r = next;
            seen.push(r);
        }
        assert_eq!(r, 4, "decay did not reach the floor: {seen:?}");
    }

    #[test]
    fn slow_decay_never_stalls_above_the_floor() {
        // decay = 0.9 used to stall at rank 9 (ceil fixed point); the
        // forced step-down must walk it all the way to the floor.
        let s = RankSchedule {
            kind: RankScheduleKind::Decay,
            max_rank: 32,
            floor: 2,
            decay: 0.9,
            energy: 1.0,
        };
        let mut r = 32;
        for _ in 0..40 {
            let next = s.next_rank(r, 64, &[]);
            assert!(next <= r);
            r = next;
        }
        assert_eq!(r, 2, "slow decay stalled above the floor");
        // decay = 1.0 means "hold": no forced shrink.
        let hold = RankSchedule { decay: 1.0, ..s };
        assert_eq!(hold.next_rank(16, 64, &[]), 16);
    }

    #[test]
    fn spectral_picks_planted_rank() {
        // 4 dominant squared singular values, then near-zero noise:
        // energy=0.99 lands exactly on r=4.
        let planted = [100.0f32, 90.0, 80.0, 70.0, 1e-4, 1e-4, 1e-4, 1e-4];
        assert_eq!(spectral(8, 1, 0.99).next_rank(8, 64, &planted), 4);
        // A heavier tail: looser targets shrink, stricter targets grow.
        let heavy = [100.0f32, 90.0, 80.0, 70.0, 30.0, 20.0, 10.0, 5.0];
        assert!(spectral(8, 1, 0.50).next_rank(8, 64, &heavy) <= 3);
        assert_eq!(spectral(8, 1, 0.80).next_rank(8, 64, &heavy), 4);
        assert_eq!(spectral(8, 1, 0.99).next_rank(8, 64, &heavy), 8);
    }

    #[test]
    fn spectral_clamps_into_band() {
        let spec = [100.0f32, 0.0, 0.0, 0.0];
        assert_eq!(spectral(8, 3, 0.99).next_rank(8, 64, &spec), 3); // floor
        let flat = [1.0f32; 16];
        assert_eq!(spectral(8, 1, 1.0).next_rank(8, 64, &flat), 8); // ceiling
        // Degenerate inputs keep a sane rank.
        assert_eq!(spectral(8, 2, 0.99).next_rank(5, 64, &[]), 5);
        assert_eq!(spectral(8, 2, 0.99).next_rank(5, 64, &[0.0, 0.0]), 2);
    }

    #[test]
    fn gate_fires_iff_threshold_met() {
        let g = RefreshGate { threshold: 0.8 };
        assert!(g.enabled());
        assert!(g.fires(0.8));
        assert!(g.fires(0.95));
        assert!(!g.fires(0.7999));
        let off = RefreshGate::disabled();
        assert!(!off.enabled());
        assert!(!off.fires(1.0));
    }

    #[test]
    fn cosine_is_ratio_clamped() {
        assert!((subspace_cosine(0.5, 1.0) - 0.5).abs() < 1e-7);
        assert_eq!(subspace_cosine(1.2, 1.0), 1.0); // numeric overshoot clamps
        assert_eq!(subspace_cosine(0.0, 0.0), 1.0); // zero gradient
    }
}
