//! Pluggable **step backends** for `GaLore<O>`: one optimizer object, two
//! execution substrates for the projected update.
//!
//! The GaLore step factors into (a) *subspace management* — refresh
//! cadence, randomized SVD, rank schedules, the lazy-refresh gate — and
//! (b) the *compact update* — run Adam-style moments on `R = Pᵀ G` and
//! apply `W ← W − lr·α·P N` (Algorithm 2). `GaLore<O>` owns (a) on both
//! substrates; a [`StepBackend`] executes (b):
//!
//! * [`RustBackend`] — the pure-Rust compact-update tail: project into a
//!   workspace, run the inner optimizer in the compact space, project back.
//!   Works with every inner optimizer and stays allocation-free once warm.
//! * [`ArtifactBackend`] — the `galore_step_{m}x{n}_r{r}` AOT artifacts
//!   (the Pallas kernels of `python/compile/kernels/galore.py`), owning
//!   its own PJRT [`Engine`] plus per-layer transpose staging. The
//!   artifacts implement exactly the paper-default Adam arithmetic, so the
//!   backend *borrows the inner optimizer's own moments* through
//!   [`Optimizer::moments_mut`] instead of keeping a parallel state store.
//!
//! Shared moments are the load-bearing design decision: both backends read
//! and write the same `M`/`V`/`t`, so checkpointing, rank-adaptation
//! remaps, and the compact (`dp_compress`) data-parallel entry point all
//! go through the one `Optimizer` surface with zero backend-specific
//! state. The checkpoint *blob* is therefore backend-agnostic — there is
//! no fused-specific section — but resume is pinned to the saving
//! backend through the config fingerprint, because the two substrates
//! round their f32 matmuls differently and a cross-backend resume would
//! silently drift off the uninterrupted trajectory.
//!
//! Contract for implementors:
//! * `step_into` consumes the **full** gradient of a projected parameter
//!   whose projector is already current (refresh happened, basis cached).
//! * `step_compact_into` consumes an **already-projected** (and, under
//!   data parallelism, already-averaged) compact gradient. It must be
//!   arithmetically interchangeable with `step_into` fed the matching full
//!   gradient — the property `dp_compress` rests on.
//! * Neither entry may panic on runtime faults (missing artifact, engine
//!   failure): errors travel up through `Optimizer::step`'s `Result`
//!   (PR 4's "no `.expect` mid-run" policy).
//! * Steady-state calls perform no Rust-side heap allocations once warm
//!   (staging buffers are reused; the PJRT literal marshalling inside
//!   `Engine::execute` is the artifact backend's only remaining allocator
//!   traffic, as before — EXPERIMENTS.md §Perf).

use super::galore::Projector;
use super::Optimizer;
use crate::runtime::{Engine, Input};
use crate::tensor::Matrix;
use std::collections::HashMap;

/// Mutable borrow of one parameter's Adam-style moment state, exposed by
/// optimizers that opt into [`Optimizer::moments_mut`]. `m`/`v` are the
/// (compact-shaped, for GaLore inners) EMAs; `t` is the 1-based update
/// count that drives bias correction; `upd` is the optimizer's reusable
/// normalized-update buffer (working memory — a substrate that computes
/// the update out-of-band writes through it so the host-side arithmetic
/// stays allocation-free). An optimizer returning `Some` asserts its
/// `step` is exactly paper-default Adam on this state — the contract both
/// the fused artifacts and GaLore's cross-layer parallel step rely on to
/// replicate the update away from `&mut self`.
pub struct MomentsMut<'a> {
    pub m: &'a mut Matrix,
    pub v: &'a mut Matrix,
    pub t: &'a mut u64,
    pub upd: &'a mut Matrix,
}

/// Per-parameter scratch for one backend step, owned by `GaLore<O>`'s
/// workspace (working memory, excluded from `state_bytes`): the projected
/// gradient, the inner optimizer's zero-initialized compact weight, and
/// the projected-back full update.
pub struct StepScratch {
    pub compact_grad: Matrix,
    pub scratch: Matrix,
    pub full_update: Matrix,
}

impl StepScratch {
    pub fn new() -> StepScratch {
        StepScratch {
            compact_grad: Matrix::zeros(0, 0),
            scratch: Matrix::zeros(0, 0),
            full_update: Matrix::zeros(0, 0),
        }
    }
}

impl Default for StepScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything a backend needs to apply one projected parameter's update:
/// the weight, the current projector (basis by borrow), the pre-scaled
/// learning rate `lr·α`, the inner optimizer (moment owner), and the
/// parameter's reusable scratch.
pub struct StepCtx<'a> {
    pub param: usize,
    pub w: &'a mut Matrix,
    pub proj: &'a Projector,
    /// `lr * scale` — the factor on the projected-back update.
    pub lr_scale: f32,
    pub inner: &'a mut (dyn Optimizer + 'a),
    pub scratch: &'a mut StepScratch,
}

/// An execution substrate for the projected GaLore update (see the module
/// docs for the contract).
pub trait StepBackend: Send {
    /// Human-readable backend name (metrics, error messages).
    fn name(&self) -> &'static str;

    /// Apply one update from the full gradient of a projected parameter.
    fn step_into(&mut self, ctx: StepCtx<'_>, grad: &Matrix) -> Result<(), String>;

    /// Apply one update from an already-projected compact gradient (the
    /// lazy-refresh-gate and `dp_compress` entry point).
    fn step_compact_into(&mut self, ctx: StepCtx<'_>, compact: &Matrix) -> Result<(), String>;

    /// Bytes of backend-owned *state* (not staging). Both built-in
    /// backends keep all state in the inner optimizer and report 0.
    fn state_bytes(&self) -> usize {
        0
    }

    /// Whether `GaLore::step_many` may bypass this backend and run the
    /// steady-state compact update for many layers concurrently on the
    /// worker pool. Only sound for a backend whose step entries are
    /// exactly the shared Rust compact tail — pure per-parameter
    /// arithmetic on disjoint state. The artifact backend keeps the
    /// default `false`: its steps serialize through one PJRT engine, and
    /// bypassing it would silently swap the execution substrate mid-run.
    fn supports_parallel_step(&self) -> bool {
        false
    }
}

/// The compact-update tail shared by both backends — one implementation,
/// so every entry point stays bit-identical *by construction* (the
/// property the compact data-parallel all-reduce rests on): run the inner
/// optimizer in the compact space against a zero scratch weight with
/// lr=1 — the scratch then holds `-N_t` regardless of which optimizer it
/// is — project back, and apply with `W ← W − lr·α·P N_t` (Algorithm 2).
pub(crate) fn compact_tail(
    inner: &mut (dyn Optimizer + '_),
    param: usize,
    proj: &Projector,
    compact: &Matrix,
    w: &mut Matrix,
    lr_scale: f32,
    scr: &mut StepScratch,
) -> Result<(), String> {
    scr.scratch.resize(compact.rows, compact.cols);
    scr.scratch.data.fill(0.0);
    inner.step(param, &mut scr.scratch, compact, 1.0)?;
    proj.project_back_into(&scr.scratch, &mut scr.full_update);
    w.axpy(lr_scale, &scr.full_update);
    Ok(())
}

/// The full Rust-substrate step: project the gradient into the compact
/// space and run the shared tail. One implementation for both
/// [`RustBackend::step_into`] and the artifact backend's rank-schedule
/// fallback, so the detach-swap and the allocation-free invariant cannot
/// drift between the two.
fn project_compact_tail(ctx: StepCtx<'_>, grad: &Matrix) -> Result<(), String> {
    ctx.proj.project_into(grad, &mut ctx.scratch.compact_grad);
    // Detach the compact gradient (empty-matrix swap, no allocation) so
    // the shared tail can borrow the scratch mutably.
    let compact = std::mem::replace(&mut ctx.scratch.compact_grad, Matrix::zeros(0, 0));
    let res = compact_tail(
        ctx.inner,
        ctx.param,
        ctx.proj,
        &compact,
        ctx.w,
        ctx.lr_scale,
        ctx.scratch,
    );
    ctx.scratch.compact_grad = compact;
    res
}

/// The pure-Rust backend: the default, works with any inner optimizer,
/// zero allocations per steady-state step.
pub struct RustBackend;

impl StepBackend for RustBackend {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn step_into(&mut self, ctx: StepCtx<'_>, grad: &Matrix) -> Result<(), String> {
        project_compact_tail(ctx, grad)
    }

    fn step_compact_into(&mut self, ctx: StepCtx<'_>, compact: &Matrix) -> Result<(), String> {
        compact_tail(ctx.inner, ctx.param, ctx.proj, compact, ctx.w, ctx.lr_scale, ctx.scratch)
    }

    fn supports_parallel_step(&self) -> bool {
        true
    }
}

/// Per-layer transpose staging for tall parameters (the artifacts are
/// lowered short-side-first, §4.2). Working memory, reused across steps.
struct Staging {
    g_t: Matrix,
    w_t: Matrix,
    m_t: Matrix,
    v_t: Matrix,
}

impl Staging {
    fn new() -> Staging {
        Staging {
            g_t: Matrix::zeros(0, 0),
            w_t: Matrix::zeros(0, 0),
            m_t: Matrix::zeros(0, 0),
            v_t: Matrix::zeros(0, 0),
        }
    }
}

/// The AOT-artifact backend: executes the fused `galore_step_{m}x{n}_r{r}`
/// kernels through its own PJRT engine, feeding them the projector basis
/// computed by `GaLore<O>`'s (host-side) refresh machinery and the inner
/// Adam's own moments. See the module docs for why the moments are
/// borrowed rather than owned.
///
/// Rank schedules compose by *fallback*: a refresh that moves a layer's
/// rank off the lowered artifact set routes that layer through the shared
/// Rust compact tail — same moments, same trajectory class — and counts
/// the event in `fallback_steps`.
pub struct ArtifactBackend {
    engine: Engine,
    staging: HashMap<usize, Staging>,
    /// Artifact name per (short, long, rank), resolved from the manifest
    /// once and cached — `None` caches a known-missing combination (rank
    /// schedules drifting off the lowered set). Keeps the steady-state
    /// step free of Rust-side allocations and immune to drift between a
    /// formatted name and the manifest's actual entry.
    names: HashMap<(usize, usize, usize), Option<String>>,
    /// Steps executed through an artifact.
    pub artifact_steps: u64,
    /// Steps routed through the Rust tail because the (shape, rank) pair
    /// had no lowered artifact (adaptive schedules drifting off the
    /// artifact set).
    pub fallback_steps: u64,
}

impl ArtifactBackend {
    /// Validate that every projected `(rows, cols)` target shape has a
    /// `galore_step` artifact at `rank` (clamped to the short side) and
    /// pre-compile them, failing fast at construction instead of mid-run.
    pub fn new(
        mut engine: Engine,
        rank: usize,
        shapes: &[(usize, usize)],
    ) -> Result<ArtifactBackend, String> {
        for &(rows, cols) in shapes {
            let (gm, gn) = short_side_first(rows, cols);
            let r = rank.min(gm);
            let Some(art) = engine.manifest.galore_step_for(gm, gn, r) else {
                return Err(format!(
                    "no galore_step artifact for shape {gm}x{gn} rank {r} — \
                     re-run `make artifacts` with matching ranks"
                ));
            };
            let name = art.name.clone();
            engine.prepare(&name).map_err(|e| format!("compiling {name}: {e}"))?;
        }
        Ok(ArtifactBackend {
            engine,
            staging: HashMap::new(),
            names: HashMap::new(),
            artifact_steps: 0,
            fallback_steps: 0,
        })
    }
}

impl StepBackend for ArtifactBackend {
    fn name(&self) -> &'static str {
        "artifact"
    }

    fn step_into(&mut self, ctx: StepCtx<'_>, grad: &Matrix) -> Result<(), String> {
        let (rows, cols) = grad.shape();
        let (cm, cn) = ctx.proj.compact_shape(rows, cols);
        let r = ctx.proj.rank;
        let (gm, gn) = short_side_first(rows, cols);
        // Resolve the artifact for this (shape, rank) from the manifest
        // once and cache the outcome (including "missing" for rank-
        // schedule fallbacks): the steady-state step allocates nothing.
        let key = (gm, gn, r);
        if !self.names.contains_key(&key) {
            let resolved =
                self.engine.manifest.galore_step_for(gm, gn, r).map(|a| a.name.clone());
            self.names.insert(key, resolved);
        }
        let artifact = self.names[&key].as_deref();
        let Some(artifact) = artifact else {
            // A rank schedule moved this layer off the lowered shapes:
            // take the Rust substrate on the same moments.
            self.fallback_steps += 1;
            return project_compact_tail(ctx, grad);
        };
        let inner_name = ctx.inner.name();
        let Some(mom) = ctx.inner.moments_mut(ctx.param, cm, cn) else {
            return Err(format!(
                "the artifact backend drives the fused GaLore-Adam kernels and needs \
                 paper-default Adam moments for parameter {}, but inner optimizer \
                 '{inner_name}' does not expose them — run this method on the rust \
                 backend",
                ctx.param
            ));
        };
        if mom.m.shape() != (cm, cn) || mom.v.shape() != (cm, cn) {
            return Err(format!(
                "parameter {}: moment shape {:?} does not match the compact shape \
                 ({cm}, {cn}) of the current projector",
                ctx.param,
                mom.m.shape()
            ));
        }
        // The artifact consumes the *post-increment* step count (Adam's
        // 1-based bias correction); the counter is committed only after a
        // successful execute so a failed step leaves the state untouched.
        let t_new = *mom.t + 1;
        let t_in = [t_new as f32];
        let la_in = [ctx.lr_scale];
        let basis = ctx.proj.basis();
        if rows <= cols {
            // Left projection: every buffer is already short-side-first.
            let outputs = self
                .engine
                .execute(
                    &artifact,
                    &[
                        Input::F32(&ctx.w.data),
                        Input::F32(&mom.m.data),
                        Input::F32(&mom.v.data),
                        Input::F32(&grad.data),
                        Input::F32(&basis.data),
                        Input::F32(&t_in),
                        Input::F32(&la_in),
                    ],
                )
                .map_err(|e| {
                    format!("artifact {artifact} failed on parameter {}: {e}", ctx.param)
                })?;
            ctx.w.data.copy_from_slice(&outputs[0].data);
            mom.m.data.copy_from_slice(&outputs[1].data);
            mom.v.data.copy_from_slice(&outputs[2].data);
        } else {
            // Tall parameter: the Rust projector is Right-sided (R = G Q,
            // compact (rows, r)) while the artifact is lowered for the
            // transposed problem (Gᵀ with the same basis Q, compact
            // (r, rows)). Element-wise Adam commutes with transposition, so
            // staging W/G/M/V through transposes and transposing back is
            // exactly the Right-side update.
            let st = self.staging.entry(ctx.param).or_insert_with(Staging::new);
            grad.transpose_into(&mut st.g_t);
            ctx.w.transpose_into(&mut st.w_t);
            mom.m.transpose_into(&mut st.m_t);
            mom.v.transpose_into(&mut st.v_t);
            let outputs = self
                .engine
                .execute(
                    &artifact,
                    &[
                        Input::F32(&st.w_t.data),
                        Input::F32(&st.m_t.data),
                        Input::F32(&st.v_t.data),
                        Input::F32(&st.g_t.data),
                        Input::F32(&basis.data),
                        Input::F32(&t_in),
                        Input::F32(&la_in),
                    ],
                )
                .map_err(|e| {
                    format!("artifact {artifact} failed on parameter {}: {e}", ctx.param)
                })?;
            st.w_t.data.copy_from_slice(&outputs[0].data);
            st.w_t.transpose_into(ctx.w);
            st.m_t.data.copy_from_slice(&outputs[1].data);
            st.m_t.transpose_into(mom.m);
            st.v_t.data.copy_from_slice(&outputs[2].data);
            st.v_t.transpose_into(mom.v);
        }
        *mom.t = t_new;
        self.artifact_steps += 1;
        Ok(())
    }

    /// Compact gradients arrive pre-projected (gate skips, `dp_compress`
    /// exchanges), and the artifacts take the *full* gradient — so the
    /// compact entry runs the shared Rust tail against the very same
    /// moments the artifact path updates. Mixing the two within a run is
    /// sound because the substrates implement identical arithmetic up to
    /// f32 matmul rounding (pinned by the backend-equivalence tests).
    fn step_compact_into(&mut self, ctx: StepCtx<'_>, compact: &Matrix) -> Result<(), String> {
        compact_tail(ctx.inner, ctx.param, ctx.proj, compact, ctx.w, ctx.lr_scale, ctx.scratch)
    }
}

/// Short-side-first reordering of a gradient shape (§4.2: the artifacts
/// are lowered only for `m ≤ n`; tall layers transpose on entry/exit).
pub fn short_side_first(rows: usize, cols: usize) -> (usize, usize) {
    if rows <= cols {
        (rows, cols)
    } else {
        (cols, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adam, AdamConfig, GaLore, GaLoreConfig};
    use crate::rng::Rng;

    #[test]
    fn short_side_first_orders_dims() {
        assert_eq!(short_side_first(3, 7), (3, 7));
        assert_eq!(short_side_first(7, 3), (3, 7));
        assert_eq!(short_side_first(5, 5), (5, 5));
    }

    #[test]
    fn explicit_rust_backend_is_bit_exact_with_default() {
        // `with_backend(RustBackend)` must be a no-op relative to the
        // default construction: same buffers, same arithmetic.
        let cfg = GaLoreConfig { rank: 4, update_freq: 3, scale: 0.25, ..Default::default() };
        let mut a = GaLore::new(cfg, Adam::new(AdamConfig::default()));
        let mut b = GaLore::new(cfg, Adam::new(AdamConfig::default()))
            .with_backend(Box::new(RustBackend));
        let mut rng = Rng::new(91);
        let mut wa = Matrix::randn(12, 20, 1.0, &mut rng);
        let mut wb = wa.clone();
        for s in 0..8 {
            let g = Matrix::randn(12, 20, 1.0, &mut rng.child(s));
            a.step(0, &mut wa, &g, 0.01).unwrap();
            b.step(0, &mut wb, &g, 0.01).unwrap();
        }
        assert_eq!(wa.data, wb.data);
        assert_eq!(a.state_bytes(), b.state_bytes());
    }

    #[test]
    fn adam_exposes_moments_and_they_are_the_step_state() {
        // moments_mut must hand out the same M/V that step updates, so a
        // backend writing through it cannot fork the state.
        let mut adam = Adam::new(AdamConfig::default());
        let mut w = Matrix::zeros(4, 6);
        let g = Matrix::ones(4, 6);
        adam.step(0, &mut w, &g, 0.1).unwrap();
        let mom = adam.moments_mut(0, 4, 6).expect("paper-default Adam exposes moments");
        assert_eq!(*mom.t, 1);
        assert_eq!(mom.m.shape(), (4, 6));
        // First step from zero state: m = (1-b1) * g = 0.1.
        assert!((mom.m.data[0] - 0.1).abs() < 1e-6);
        // Writing through the borrow is writing the optimizer's state.
        *mom.t = 7;
        let mom2 = adam.moments_mut(0, 4, 6).unwrap();
        assert_eq!(*mom2.t, 7);
    }

    #[test]
    fn non_default_adam_refuses_moment_borrow() {
        // The artifacts are lowered with the paper's beta/eps and no
        // decoupled decay; any other configuration must opt out.
        let mut adamw = Adam::adamw(0.1);
        let mut w = Matrix::zeros(4, 4);
        let g = Matrix::ones(4, 4);
        adamw.step(0, &mut w, &g, 0.1).unwrap();
        assert!(adamw.moments_mut(0, 4, 4).is_none());
        let mut odd = Adam::new(AdamConfig { beta1: 0.8, ..AdamConfig::default() });
        odd.step(0, &mut w, &g, 0.1).unwrap();
        assert!(odd.moments_mut(0, 4, 4).is_none());
    }
}
