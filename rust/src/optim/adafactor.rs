//! Adafactor (Shazeer & Stern, 2018), the sub-linear-memory baseline of
//! Fig. 3 / Table 11.
//!
//! The second moment of an (m, n) parameter is factored into a row vector
//! R ∈ R^m and column vector C ∈ R^n with V ≈ R Cᵀ / sum(R): memory m + n
//! instead of mn. Following §5.2 we use the variant *with* first-order
//! momentum ("Adafactor with first-order statistics") to avoid instability,
//! which is also what makes it a fair GaLore host (GaLore composes with it
//! by running this update in the compact space).

use super::{bias_correction, Optimizer};
use crate::ser;
use crate::tensor::Matrix;
use std::collections::HashMap;

pub struct Adafactor {
    beta1: f32,
    beta2: f32,
    eps: f32,
    states: HashMap<usize, State>,
}

struct State {
    m: Matrix,       // first moment (full shape; §5.2 variant)
    row: Vec<f32>,   // R: row sums of the squared-grad EMA
    col: Vec<f32>,   // C: col sums
    t: u64,
}

impl Adafactor {
    pub fn new() -> Self {
        Adafactor { beta1: 0.9, beta2: 0.999, eps: 1e-30, states: HashMap::new() }
    }
}

impl Default for Adafactor {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for Adafactor {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        let (rows, cols) = grad.shape();
        let state = self.states.entry(param).or_insert_with(|| State {
            m: Matrix::zeros(rows, cols),
            row: vec![0.0; rows],
            col: vec![0.0; cols],
            t: 0,
        });
        state.t += 1;
        let b2 = self.beta2;
        // Update factored second-moment statistics.
        for i in 0..rows {
            let mut rsum = 0.0f32;
            for &g in grad.row(i) {
                rsum += g * g + self.eps;
            }
            state.row[i] = b2 * state.row[i] + (1.0 - b2) * (rsum / cols as f32);
        }
        for j in 0..cols {
            let mut csum = 0.0f32;
            for i in 0..rows {
                let g = grad.at(i, j);
                csum += g * g + self.eps;
            }
            state.col[j] = b2 * state.col[j] + (1.0 - b2) * (csum / rows as f32);
        }
        let row_mean: f32 =
            state.row.iter().sum::<f32>() / rows as f32;
        let bc2 = bias_correction(b2, state.t);
        // First moment on the normalized gradient.
        let b1 = self.beta1;
        let bc1 = bias_correction(b1, state.t);
        for i in 0..rows {
            let r = state.row[i] / bc2;
            for j in 0..cols {
                let c = state.col[j] / bc2;
                // V_hat[i,j] ≈ r * c / mean(row)
                let v_hat = (r * c / (row_mean / bc2).max(1e-30)).max(1e-30);
                let g = grad.at(i, j);
                let u = g / v_hat.sqrt();
                let mij = state.m.at_mut(i, j);
                *mij = b1 * *mij + (1.0 - b1) * u;
                let upd = *mij / bc1;
                *w.at_mut(i, j) -= lr * upd;
            }
        }
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.states
            .values()
            .map(|s| 4 * (s.m.len() + s.row.len() + s.col.len()))
            .sum()
    }

    fn name(&self) -> &'static str {
        "adafactor"
    }

    /// Rank adaptation: the factored row/col statistics have no meaningful
    /// linear transport across a basis change — drop this parameter's
    /// state and re-accumulate at the new shape.
    fn remap_state(&mut self, param: usize, _remap: &mut super::adaptive::StateRemap<'_>) {
        self.states.remove(&param);
    }

    fn reset_state(&mut self) {
        self.states.clear();
    }

    /// Checkpoint v2: first moment plus the factored row/col second-moment
    /// statistics and the step counter, sorted by parameter id.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        let mut params: Vec<usize> = self.states.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in params {
            let s = &self.states[&p];
            ser::put_usize(out, p);
            ser::put_u64(out, s.t);
            ser::put_matrix(out, &s.m);
            ser::put_f32s(out, &s.row);
            ser::put_f32s(out, &s.col);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.states.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let t = r.u64()?;
            let m = r.matrix()?;
            let row = r.f32s()?;
            let col = r.f32s()?;
            if row.len() != m.rows || col.len() != m.cols {
                return Err(format!(
                    "adafactor param {p}: factors ({}, {}) disagree with M {:?}",
                    row.len(),
                    col.len(),
                    m.shape()
                ));
            }
            self.states.insert(p, State { m, row, col, t });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::converges_on_quadratic;

    #[test]
    fn converges_on_quadratic_bowl() {
        let mut opt = Adafactor::new();
        let (d0, d1) = converges_on_quadratic(&mut opt, 400, 0.05);
        assert!(d1 < 0.2 * d0, "d0={d0} d1={d1}");
    }

    #[test]
    fn second_moment_is_factored() {
        // State must be m*n (first moment) + m + n, NOT 2*m*n.
        let mut opt = Adafactor::new();
        let mut w = Matrix::zeros(32, 64);
        let g = Matrix::ones(32, 64);
        opt.step(0, &mut w, &g, 0.01).unwrap();
        assert_eq!(opt.state_bytes(), 4 * (32 * 64 + 32 + 64));
    }

    #[test]
    fn scale_invariance_of_direction() {
        // Adafactor's normalized update should be insensitive to a global
        // gradient rescale (property of the V normalization) at t=1.
        let mut a = Adafactor::new();
        let mut b = Adafactor::new();
        let mut wa = Matrix::zeros(4, 4);
        let mut wb = Matrix::zeros(4, 4);
        let g = Matrix::from_fn(4, 4, |i, j| ((i * 4 + j) as f32 - 7.5) * 0.1);
        let mut g_scaled = g.clone();
        g_scaled.scale(100.0);
        a.step(0, &mut wa, &g, 0.01).unwrap();
        b.step(0, &mut wb, &g_scaled, 0.01).unwrap();
        for (x, y) in wa.data.iter().zip(wb.data.iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
