//! 8-bit Adam (Dettmers et al., 2022): Adam whose M/V states live in
//! block-wise 8-bit buffers with the **dynamic** (logarithmic) code —
//! linear int8 would zero small second-moment cells inside blocks with one
//! large value and blow the update up, which is exactly why bitsandbytes
//! uses dynamic tree quantization. M uses the signed code, V the unsigned
//! one. This is the "8-bit Adam" baseline of Tables 3/11 and, wrapped in
//! `galore::GaLore`, the paper's headline **8-bit GaLore**.
//!
//! State memory: 2·mn bytes + per-block scales, vs 8·mn for f32 Adam —
//! the 4× optimizer-state shrink in Fig. 1.

use super::{bias_correction, Optimizer};
use crate::quant::DynQuantBuf;
use crate::ser;
use crate::tensor::Matrix;
use std::collections::HashMap;

pub struct Adam8bit {
    beta1: f32,
    beta2: f32,
    eps: f32,
    states: HashMap<usize, State>,
    /// Scratch f32 buffers reused across steps (hot path: no allocation).
    scratch_m: Vec<f32>,
    scratch_v: Vec<f32>,
}

struct State {
    m: DynQuantBuf,
    v: DynQuantBuf,
    t: u64,
}

impl Adam8bit {
    pub fn new() -> Self {
        Adam8bit {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            states: HashMap::new(),
            scratch_m: Vec::new(),
            scratch_v: Vec::new(),
        }
    }
}

impl Default for Adam8bit {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for Adam8bit {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        let n = grad.len();
        let state = self.states.entry(param).or_insert_with(|| State {
            m: DynQuantBuf::zeros(n, true),
            v: DynQuantBuf::zeros(n, false),
            t: 0,
        });
        state.t += 1;
        // Dequantize -> f32 update -> requantize (the Pallas quant8 kernel
        // is the artifact-side mirror of this streaming path).
        self.scratch_m.resize(n, 0.0);
        self.scratch_v.resize(n, 0.0);
        state.m.dequantize_into(&mut self.scratch_m);
        state.v.dequantize_into(&mut self.scratch_v);
        let (b1, b2) = (self.beta1, self.beta2);
        let bc1 = bias_correction(b1, state.t);
        let bc2 = bias_correction(b2, state.t);
        for (((mv, vv), &g), wv) in self
            .scratch_m
            .iter_mut()
            .zip(self.scratch_v.iter_mut())
            .zip(grad.data.iter())
            .zip(w.data.iter_mut())
        {
            *mv = b1 * *mv + (1.0 - b1) * g;
            *vv = b2 * *vv + (1.0 - b2) * g * g;
            let m_hat = *mv / bc1;
            let v_hat = *vv / bc2;
            *wv -= lr * m_hat / (v_hat.sqrt() + self.eps);
        }
        state.m.quantize_from(&self.scratch_m);
        state.v.quantize_from(&self.scratch_v);
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.states.values().map(|s| s.m.nbytes() + s.v.nbytes()).sum()
    }

    fn name(&self) -> &'static str {
        "adam8bit"
    }

    fn reset_state(&mut self) {
        self.states.clear();
    }

    /// Rank adaptation: the quantized moments carry no shape metadata, so
    /// they cannot be rotated in place — drop this parameter's state and
    /// let the EMAs warm back up at the new shape (~1/(1−β₂) steps).
    fn remap_state(&mut self, param: usize, _remap: &mut super::adaptive::StateRemap<'_>) {
        self.states.remove(&param);
    }

    /// Checkpoint v2: the quantized M/V buffers travel as their exact
    /// int8 codes + block scales, so a resumed run dequantizes to the very
    /// same floats the uninterrupted run would. Scratch buffers are not
    /// state (fully rewritten per step).
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        let mut params: Vec<usize> = self.states.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in params {
            let s = &self.states[&p];
            ser::put_usize(out, p);
            ser::put_u64(out, s.t);
            ser::put_dyn_quant_buf(out, &s.m);
            ser::put_dyn_quant_buf(out, &s.v);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.states.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let t = r.u64()?;
            let m = r.dyn_quant_buf()?;
            let v = r.dyn_quant_buf()?;
            if m.len != v.len {
                return Err(format!("adam8bit param {p}: M len {} != V len {}", m.len, v.len));
            }
            self.states.insert(p, State { m, v, t });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::testutil::converges_on_quadratic;
    use crate::optim::{Adam, AdamConfig};

    #[test]
    fn converges_on_quadratic_bowl() {
        let mut opt = Adam8bit::new();
        let (d0, d1) = converges_on_quadratic(&mut opt, 300, 0.05);
        assert!(d1 < 0.1 * d0, "d0={d0} d1={d1}");
    }

    #[test]
    fn tracks_f32_adam_closely() {
        // Over a short horizon the quantized trajectory must hug f32 Adam.
        let mut rng = crate::rng::Rng::new(1);
        let mut w8 = Matrix::randn(16, 32, 1.0, &mut rng);
        let mut wf = w8.clone();
        let mut o8 = Adam8bit::new();
        let mut of = Adam::new(AdamConfig::default());
        for s in 0..20 {
            let g = Matrix::randn(16, 32, 1.0, &mut rng.child(s));
            o8.step(0, &mut w8, &g, 0.01).unwrap();
            of.step(0, &mut wf, &g, 0.01).unwrap();
        }
        let mut d = w8.clone();
        d.sub_assign(&wf);
        let rel = d.frobenius_norm() / wf.frobenius_norm();
        assert!(rel < 0.02, "divergence {rel}");
    }

    #[test]
    fn state_is_quarter_of_f32() {
        let mut opt = Adam8bit::new();
        let mut w = Matrix::zeros(64, 64);
        let g = Matrix::ones(64, 64);
        opt.step(0, &mut w, &g, 0.01).unwrap();
        let f32_state = 2 * 64 * 64 * 4;
        assert!(opt.state_bytes() < f32_state / 3, "{}", opt.state_bytes());
    }

    #[test]
    fn no_blowup_with_outlier_blocks() {
        // A gradient with one huge element per block must not destabilize
        // the small elements' updates (the linear-int8 failure mode).
        let rng = crate::rng::Rng::new(2);
        let mut w = Matrix::zeros(8, 64); // 512 elements = 2 blocks
        let mut opt = Adam8bit::new();
        for s in 0..100 {
            let mut g = Matrix::randn(8, 64, 0.01, &mut rng.child(s));
            g.data[0] = 10.0; // persistent outlier
            opt.step(0, &mut w, &g, 0.001).unwrap();
        }
        assert!(w.all_finite());
        assert!(w.max_abs() < 1.0, "blowup: {}", w.max_abs());
    }
}
