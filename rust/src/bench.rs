//! Micro/macro benchmark harness.
//!
//! `criterion` is unavailable in this offline build (DESIGN.md §4), so the
//! bench targets under `benches/` use this small harness instead: warmup,
//! adaptive iteration count, median/p10/p90 statistics, and a fixed-width
//! table printer used to render the paper-style rows each bench reproduces.

use std::time::{Duration, Instant};

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl Sample {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }

    /// Throughput in `units`/second given units of work per iteration.
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median_secs()
    }
}

/// Time `f`, autoscaling iterations to fill ~`budget` (default 1s, override
/// with GALORE_BENCH_BUDGET_MS). Returns per-iteration statistics.
pub fn bench(name: &str, mut f: impl FnMut()) -> Sample {
    let budget_ms: u64 =
        std::env::var("GALORE_BENCH_BUDGET_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1000);
    let budget = Duration::from_millis(budget_ms);
    // Warmup + calibration: run once to estimate cost.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let target_samples = 30usize;
    let iters_per_sample =
        ((budget.as_secs_f64() / target_samples as f64) / once.as_secs_f64()).ceil().max(1.0)
            as usize;
    let n_samples = if once > budget { 1 } else { target_samples };
    let mut times: Vec<Duration> = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        times.push(t.elapsed() / iters_per_sample as u32);
    }
    times.sort();
    let pick = |q: f64| times[((times.len() - 1) as f64 * q) as usize];
    Sample {
        name: name.to_string(),
        iters: n_samples * iters_per_sample,
        median: pick(0.5),
        p10: pick(0.1),
        p90: pick(0.9),
    }
}

/// Pretty-print a sample line (used by the hot-path benches).
pub fn report(s: &Sample) {
    println!(
        "{:<44} {:>12} median  [{:>10} .. {:>10}]  ({} iters)",
        s.name,
        fmt_dur(s.median),
        fmt_dur(s.p10),
        fmt_dur(s.p90),
        s.iters
    );
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row.iter()) {
                *w = (*w).max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len();
        println!("\n=== {title} ===");
        let line: String = self
            .headers
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("{h:>w$} | ", w = w))
            .collect();
        println!("{line}");
        println!("{}", "-".repeat(total));
        for row in &self.rows {
            let line: String =
                row.iter().zip(&widths).map(|(c, w)| format!("{c:>w$} | ", w = w)).collect();
            println!("{line}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        std::env::set_var("GALORE_BENCH_BUDGET_MS", "50");
        let s = bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.iters >= 1);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["method", "60M", "1B"]);
        t.row(&["Full-Rank".into(), "34.06".into(), "15.56".into()]);
        t.row(&["GaLore".into(), "34.88".into(), "15.64".into()]);
        t.print("Table 2 (smoke)");
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_dur(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_dur(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).contains(" s"));
    }
}
