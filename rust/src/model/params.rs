//! Flattened parameter schema and storage.
//!
//! Order MUST match `python/compile/model.py::param_names` — the training
//! artifact takes weights as positional inputs and returns gradients in the
//! same order. 1-D tensors (norm gains) are stored as (1, n) matrices.

use super::ModelConfig;
use crate::quant::{Bf16Buf, QuantizedBuf};
use crate::rng::Rng;
use crate::ser;
use crate::tensor::Matrix;

/// Salt folded into the run seed for the int8 stochastic-rounding stream,
/// so rounding draws never alias the data-order or init streams.
const ROUNDING_STREAM_TAG: u64 = 0x51C8_0B17;

/// Master-store precision of the model weights (`weight_precision` run
/// knob). `Bf16` keeps the persistent weight copy in bf16 (2 bytes/el —
/// the paper's §5 storage format); `Int8` holds it block-quantized at
/// ~1 byte/el with **stochastic rounding** on commit (Q-GaLore's weight
/// recipe — unbiased rounding is what keeps the loss curve). Every
/// consumer — forward/backward artifacts, projector matmuls, optimizer
/// updates — still reads the f32 working tensors; updates accumulate in
/// f32 and are rounded through the store once per step
/// ([`ParamStore::commit`]). Trajectory-shaping: part of the config
/// fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum WeightPrecision {
    #[default]
    F32,
    Bf16,
    Int8,
}

impl WeightPrecision {
    pub fn parse(s: &str) -> Option<WeightPrecision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(WeightPrecision::F32),
            "bf16" | "bfloat16" => Some(WeightPrecision::Bf16),
            "int8" | "i8" => Some(WeightPrecision::Int8),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            WeightPrecision::F32 => "f32",
            WeightPrecision::Bf16 => "bf16",
            WeightPrecision::Int8 => "int8",
        }
    }

    /// Bytes per element of the weight *master store* at this precision.
    /// For `Int8` this is the code byte only; the per-block scales add
    /// `4 * ceil(n/BLOCK)` on top — [`ParamStore::weight_store_bytes`]
    /// and `memory::formulas::weight_store_bytes` carry the exact figure.
    pub fn bytes_per_el(&self) -> usize {
        match self {
            WeightPrecision::F32 => 4,
            WeightPrecision::Bf16 => 2,
            WeightPrecision::Int8 => 1,
        }
    }
}

/// What role a parameter plays — drives GaLore/LoRA targeting (§5.1: only
/// attention and FFN projections are low-rank-projected).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    Embedding,
    Attention,
    Ffn,
    Norm,
    LmHead,
}

/// Metadata for one schema entry.
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub kind: ParamKind,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Is this a GaLore/LoRA target (2-D attention/FFN projection)?
    pub fn is_projection_target(&self) -> bool {
        matches!(self.kind, ParamKind::Attention | ParamKind::Ffn)
    }
}

/// Build the schema for a config, mirroring model.py exactly.
pub fn schema(cfg: &ModelConfig) -> Vec<ParamMeta> {
    let (d, i, v) = (cfg.dim, cfg.intermediate, cfg.vocab);
    let mut out = Vec::with_capacity(cfg.n_schema_params());
    out.push(ParamMeta { name: "embed.weight".into(), rows: v, cols: d, kind: ParamKind::Embedding });
    for l in 0..cfg.layers {
        let mk = |field: &str, rows, cols, kind| ParamMeta {
            name: format!("layers.{l}.{field}"),
            rows,
            cols,
            kind,
        };
        out.push(mk("attn.wq", d, d, ParamKind::Attention));
        out.push(mk("attn.wk", d, d, ParamKind::Attention));
        out.push(mk("attn.wv", d, d, ParamKind::Attention));
        out.push(mk("attn.wo", d, d, ParamKind::Attention));
        out.push(mk("ffn.w_gate", d, i, ParamKind::Ffn));
        out.push(mk("ffn.w_up", d, i, ParamKind::Ffn));
        out.push(mk("ffn.w_down", i, d, ParamKind::Ffn));
        out.push(mk("attn_norm", 1, d, ParamKind::Norm));
        out.push(mk("ffn_norm", 1, d, ParamKind::Norm));
    }
    out.push(ParamMeta { name: "final_norm".into(), rows: 1, cols: d, kind: ParamKind::Norm });
    out.push(ParamMeta { name: "lm_head.weight".into(), rows: d, cols: v, kind: ParamKind::LmHead });
    out
}

/// All model parameters, in schema order.
///
/// `tensors` are the f32 *working* copies every consumer reads. Under
/// `WeightPrecision::Bf16` / `Int8` the store additionally keeps the
/// low-precision master copy per tensor, with the invariant that each
/// working tensor equals the dequantized master store (established by
/// [`ParamStore::set_precision`], re-established after every update by
/// [`ParamStore::commit`]). Code that mutates `tensors` directly outside
/// the trainer's update path (e.g. `perturb`, test fixtures) must call
/// `commit` afterwards if it cares about the invariant.
pub struct ParamStore {
    pub cfg: &'static ModelConfig,
    pub metas: Vec<ParamMeta>,
    pub tensors: Vec<Matrix>,
    precision: WeightPrecision,
    /// bf16 master copies (schema order); non-empty iff `precision == Bf16`.
    store: Vec<Bf16Buf>,
    /// int8 master copies (schema order); non-empty iff `precision == Int8`.
    store8: Vec<QuantizedBuf>,
    /// Stochastic-rounding stream for int8 commits. Seeded from the run
    /// seed ([`ParamStore::seed_rounding`]) and snapshotted in checkpoints
    /// ([`ParamStore::save_store_state`]) so a resumed run draws the exact
    /// rounding sequence the uninterrupted run would.
    round_rng: Rng,
}

impl ParamStore {
    /// Wrap existing tensors (schema order) into a store at f32 precision.
    pub fn from_tensors(
        cfg: &'static ModelConfig,
        metas: Vec<ParamMeta>,
        tensors: Vec<Matrix>,
    ) -> Self {
        ParamStore {
            cfg,
            metas,
            tensors,
            precision: WeightPrecision::F32,
            store: Vec::new(),
            store8: Vec::new(),
            round_rng: Rng::new(ROUNDING_STREAM_TAG),
        }
    }

    /// Zero-initialized store (callers usually want `init_params`).
    pub fn zeros(cfg: &'static ModelConfig) -> Self {
        let metas = schema(cfg);
        let tensors = metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        ParamStore::from_tensors(cfg, metas, tensors)
    }

    /// Seed the int8 stochastic-rounding stream from the run seed. Call
    /// before [`ParamStore::set_precision`] so the lossy entry commit and
    /// every later per-step commit draw from a deterministic, run-scoped
    /// stream (checkpoint restore replaces it with the snapshotted state).
    pub fn seed_rounding(&mut self, seed: u64) {
        self.round_rng = Rng::new(seed).child(ROUNDING_STREAM_TAG);
    }

    /// Switch the weight master store to `precision`. Entering `Bf16` or
    /// `Int8` builds the master copies and rounds the working tensors
    /// through them (the weights *become* store-valued — this is the lossy
    /// moment; re-applying `Bf16` to already-bf16-valued weights, e.g.
    /// after a checkpoint restore of a bf16 run, is exact, while an `Int8`
    /// restore installs the snapshotted store via
    /// [`ParamStore::load_store_state`] instead of re-entering here).
    /// `F32` drops the master copies and keeps the working tensors as
    /// they are.
    pub fn set_precision(&mut self, precision: WeightPrecision) {
        self.precision = precision;
        match precision {
            WeightPrecision::F32 => {
                self.store.clear();
                self.store8.clear();
            }
            WeightPrecision::Bf16 => {
                self.store8.clear();
                self.store.resize_with(self.tensors.len(), || Bf16Buf::zeros(0));
                self.commit();
            }
            WeightPrecision::Int8 => {
                self.store.clear();
                self.store8.resize_with(self.tensors.len(), || QuantizedBuf::zeros(0));
                self.commit();
            }
        }
    }

    pub fn precision(&self) -> WeightPrecision {
        self.precision
    }

    /// Re-establish the master-store invariant after the working tensors
    /// changed (one optimizer step's worth of f32-accumulated updates):
    /// round every working tensor through its master copy in place. No-op
    /// at f32 precision; allocation-free once warm. The bf16 path is
    /// deterministic per element; the int8 path rounds stochastically from
    /// the store's own seeded stream, consuming exactly one draw per
    /// element — deterministic given (seed, commit count), so it composes
    /// with the bit-exactness guarantees of the parallel step path.
    pub fn commit(&mut self) {
        match self.precision {
            WeightPrecision::F32 => {}
            WeightPrecision::Bf16 => {
                for (buf, t) in self.store.iter_mut().zip(self.tensors.iter_mut()) {
                    buf.store_round(&mut t.data);
                }
            }
            WeightPrecision::Int8 => {
                for (buf, t) in self.store8.iter_mut().zip(self.tensors.iter_mut()) {
                    buf.store_round_stochastic(&mut t.data, &mut self.round_rng);
                }
            }
        }
    }

    /// Snapshot the int8 master store for checkpointing: the rounding
    /// stream state plus every tensor's codes and scales. The codes are
    /// serialized (not re-derived on load) because absmax re-quantization
    /// of the dequantized weights is not guaranteed bit-stable — and the
    /// rounding RNG makes re-entry non-deterministic anyway.
    pub fn save_store_state(&self, out: &mut Vec<u8>) {
        ser::put_rng(out, &self.round_rng);
        ser::put_u32(out, self.store8.len() as u32);
        for buf in &self.store8 {
            ser::put_quant_buf(out, buf);
        }
    }

    /// Install an int8 master store snapshotted by
    /// [`ParamStore::save_store_state`]: restores the rounding stream,
    /// the per-tensor codes/scales, and re-derives the working tensors
    /// from the store (a bit-exact no-op on well-formed checkpoints,
    /// where the saved f32 params already equal the dequantized store).
    pub fn load_store_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        let round_rng = r.rng()?;
        let n = r.u32()? as usize;
        if n != self.tensors.len() {
            return Err(format!(
                "int8 weight store has {n} tensors, schema has {}",
                self.tensors.len()
            ));
        }
        let mut store8 = Vec::with_capacity(n);
        for (i, t) in self.tensors.iter().enumerate() {
            let buf = r.quant_buf()?;
            if buf.len != t.data.len() {
                return Err(format!(
                    "int8 weight store tensor {i} ({}) has {} elements, want {}",
                    self.metas[i].name,
                    buf.len,
                    t.data.len()
                ));
            }
            store8.push(buf);
        }
        for (buf, t) in store8.iter().zip(self.tensors.iter_mut()) {
            crate::quant::dequantize_into(buf, &mut t.data);
        }
        self.store.clear();
        self.store8 = store8;
        self.round_rng = round_rng;
        self.precision = WeightPrecision::Int8;
        Ok(())
    }

    /// Bytes held by the weight *master store* at the active precision
    /// (the Fig. 1 "weight memory" quantity: 2 bytes/el under bf16, ~1
    /// byte/el + block scales under int8). The f32 working tensors are
    /// working memory on this substrate — like the projector dequant
    /// caches — and are accounted separately.
    pub fn weight_store_bytes(&self) -> usize {
        match self.precision {
            WeightPrecision::Int8 => self.store8.iter().map(|b| b.nbytes()).sum(),
            p => self.numel() * p.bytes_per_el(),
        }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Indices of GaLore/LoRA target parameters (attention + FFN).
    pub fn projection_targets(&self) -> Vec<usize> {
        self.metas
            .iter()
            .enumerate()
            .filter(|(_, m)| m.is_projection_target())
            .map(|(i, _)| i)
            .collect()
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.metas.iter().map(|m| m.numel()).sum()
    }

    /// Bytes at a given per-element width (2 for BF16 accounting, 4 f32).
    pub fn weight_bytes(&self, bytes_per_el: usize) -> usize {
        self.numel() * bytes_per_el
    }

    pub fn by_name(&self, name: &str) -> Option<(usize, &Matrix)> {
        self.metas
            .iter()
            .position(|m| m.name == name)
            .map(|i| (i, &self.tensors[i]))
    }

    /// Fisher-style parameter perturbation (used by fine-tune experiments
    /// to model a "pre-trained" checkpoint drift). Commits at the end so
    /// a bf16 store never keeps stale pre-perturbation masters (the drift
    /// used to survive only until the first optimizer commit rounded the
    /// working tensors back through the old store).
    pub fn perturb(&mut self, std: f32, rng: &mut Rng) {
        for t in self.tensors.iter_mut() {
            for v in t.data.iter_mut() {
                *v += rng.normal_f32() * std;
            }
        }
        self.commit();
    }

    /// Guarded whole-tensor setter for non-optimizer weight writers
    /// (weight import, surgery tools): shape-checked copy into the
    /// working tensor, then an immediate single-tensor commit so the
    /// bf16 master-store invariant holds on every exit path — unlike a
    /// raw `tensors[idx]` write, which silently leaves a stale master.
    pub fn write_weights(&mut self, idx: usize, data: &[f32]) -> Result<(), String> {
        let Some(t) = self.tensors.get_mut(idx) else {
            return Err(format!(
                "write_weights: parameter {idx} out of range ({} tensors)",
                self.metas.len()
            ));
        };
        if data.len() != t.data.len() {
            return Err(format!(
                "write_weights: parameter {idx} ({}) has {} elements, got {}",
                self.metas[idx].name,
                t.data.len(),
                data.len()
            ));
        }
        t.data.copy_from_slice(data);
        match self.precision {
            WeightPrecision::F32 => {}
            WeightPrecision::Bf16 => self.store[idx].store_round(&mut t.data),
            WeightPrecision::Int8 => {
                self.store8[idx].store_round_stochastic(&mut t.data, &mut self.round_rng)
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PROXY_CONFIGS;

    #[test]
    fn schema_matches_python_layout() {
        let cfg = &PROXY_CONFIGS[0]; // nano
        let s = schema(cfg);
        assert_eq!(s.len(), cfg.n_schema_params());
        assert_eq!(s[0].name, "embed.weight");
        assert_eq!((s[0].rows, s[0].cols), (cfg.vocab, cfg.dim));
        assert_eq!(s[1].name, "layers.0.attn.wq");
        assert_eq!(s[5].name, "layers.0.ffn.w_gate");
        assert_eq!((s[5].rows, s[5].cols), (cfg.dim, cfg.intermediate));
        assert_eq!(s[7].name, "layers.0.ffn.w_down");
        assert_eq!((s[7].rows, s[7].cols), (cfg.intermediate, cfg.dim));
        let last = s.last().unwrap();
        assert_eq!(last.name, "lm_head.weight");
        assert_eq!((last.rows, last.cols), (cfg.dim, cfg.vocab));
    }

    #[test]
    fn numel_matches_config_formula() {
        for cfg in PROXY_CONFIGS {
            let store = ParamStore::zeros(cfg);
            assert_eq!(store.numel() as u64, cfg.n_params(), "{}", cfg.name);
        }
    }

    #[test]
    fn projection_targets_are_attention_and_ffn_only() {
        let cfg = &PROXY_CONFIGS[0];
        let store = ParamStore::zeros(cfg);
        let targets = store.projection_targets();
        assert_eq!(targets.len(), 7 * cfg.layers);
        for &t in &targets {
            assert!(store.metas[t].is_projection_target());
            assert!(store.metas[t].rows > 1 && store.metas[t].cols > 1);
        }
        // Embedding and head excluded.
        assert!(!targets.contains(&0));
        assert!(!targets.contains(&(store.len() - 1)));
    }

    #[test]
    fn bf16_store_halves_bytes_and_pins_working_tensors() {
        let cfg = &PROXY_CONFIGS[0];
        let mut store = crate::model::init_params(cfg, 7);
        assert_eq!(store.weight_store_bytes(), store.numel() * 4);
        store.set_precision(WeightPrecision::Bf16);
        assert_eq!(store.weight_store_bytes(), store.numel() * 2);
        // Invariant: every working value is exactly its bf16 round-trip.
        for t in &store.tensors {
            for &v in &t.data {
                assert_eq!(v, crate::quant::bf16_to_f32(crate::quant::f32_to_bf16(v)));
            }
        }
        // Re-entering bf16 on bf16-valued weights is exact (the restore
        // path relies on this).
        let snapshot: Vec<Vec<f32>> = store.tensors.iter().map(|t| t.data.clone()).collect();
        store.set_precision(WeightPrecision::Bf16);
        for (t, s) in store.tensors.iter().zip(snapshot.iter()) {
            assert_eq!(&t.data, s);
        }
        // commit() rounds a drifted working tensor back through the store.
        store.tensors[1].data[0] = 1.0 + 2f32.powi(-12);
        store.commit();
        assert_eq!(store.tensors[1].data[0], 1.0);
        // Back to f32: master copies dropped, accounting follows.
        store.set_precision(WeightPrecision::F32);
        assert_eq!(store.weight_store_bytes(), store.numel() * 4);
    }

    #[test]
    fn perturb_commits_bf16_masters() {
        let cfg = &PROXY_CONFIGS[0];
        let mut store = crate::model::init_params(cfg, 7);
        store.set_precision(WeightPrecision::Bf16);
        let mut rng = crate::rng::Rng::new(11);
        store.perturb(0.05, &mut rng);
        // The perturbed working tensors must already be bf16-valued: a
        // later commit() (what every optimizer step does) must be a
        // bit-exact no-op, not a silent rollback to the pre-perturbation
        // masters.
        let after_perturb: Vec<Vec<f32>> = store.tensors.iter().map(|t| t.data.clone()).collect();
        for t in &store.tensors {
            for &v in &t.data {
                assert_eq!(v, crate::quant::bf16_to_f32(crate::quant::f32_to_bf16(v)));
            }
        }
        store.commit();
        for (t, snap) in store.tensors.iter().zip(after_perturb.iter()) {
            assert_eq!(&t.data, snap, "commit after perturb must be a no-op");
        }
    }

    #[test]
    fn write_weights_guards_shape_and_commits() {
        let cfg = &PROXY_CONFIGS[0];
        let mut store = crate::model::init_params(cfg, 7);
        store.set_precision(WeightPrecision::Bf16);
        let n = store.tensors[1].data.len();
        // Values chosen to NOT be bf16-representable: the setter must
        // round them through the master store immediately.
        let raw: Vec<f32> = (0..n).map(|i| 1.0 + (i as f32 + 1.0) * 2f32.powi(-12)).collect();
        store.write_weights(1, &raw).unwrap();
        for (&v, &r) in store.tensors[1].data.iter().zip(raw.iter()) {
            assert_eq!(v, crate::quant::bf16_to_f32(crate::quant::f32_to_bf16(r)));
        }
        let snap = store.tensors[1].data.clone();
        store.commit();
        assert_eq!(store.tensors[1].data, snap);
        // Guards: bad index, bad length.
        let err = store.write_weights(usize::MAX, &raw).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        let err = store.write_weights(1, &raw[..n - 1]).unwrap_err();
        assert!(err.contains("elements"), "{err}");
        // At f32 precision the setter is a plain copy.
        store.set_precision(WeightPrecision::F32);
        store.write_weights(1, &raw).unwrap();
        assert_eq!(store.tensors[1].data, raw);
    }

    #[test]
    fn int8_store_shrinks_bytes_and_pins_working_tensors() {
        let cfg = &PROXY_CONFIGS[0];
        let mut store = crate::model::init_params(cfg, 7);
        store.seed_rounding(7);
        store.set_precision(WeightPrecision::Int8);
        // ~1 byte/el + 4 bytes per 256-el block (tensor-granular ceil).
        let closed: usize = store
            .metas
            .iter()
            .map(|m| m.numel() + 4 * m.numel().div_ceil(crate::quant::BLOCK))
            .sum();
        assert_eq!(store.weight_store_bytes(), closed);
        assert!(store.weight_store_bytes() < store.numel() * 2);
        // Master-store invariant: the working tensors equal the
        // dequantized int8 store (read it back through the snapshot path).
        let mut blob = Vec::new();
        store.save_store_state(&mut blob);
        let mut r = crate::ser::Reader::new(&blob);
        let _rng = r.rng().unwrap();
        let n = r.u32().unwrap() as usize;
        assert_eq!(n, store.tensors.len());
        for t in &store.tensors {
            let buf = r.quant_buf().unwrap();
            assert_eq!(crate::quant::dequantize(&buf), t.data);
        }
        r.expect_end().unwrap();
        // The rounding stream is run-scoped and deterministic: an
        // identically-seeded store quantizes to identical weights.
        let mut twin = crate::model::init_params(cfg, 7);
        twin.seed_rounding(7);
        twin.set_precision(WeightPrecision::Int8);
        for (a, b) in store.tensors.iter().zip(twin.tensors.iter()) {
            assert_eq!(a.data, b.data);
        }
        // Back to f32: master copies dropped, accounting follows.
        store.set_precision(WeightPrecision::F32);
        assert_eq!(store.weight_store_bytes(), store.numel() * 4);
    }

    #[test]
    fn int8_store_state_roundtrip_is_bit_exact_and_guarded() {
        let cfg = &PROXY_CONFIGS[0];
        let mut store = crate::model::init_params(cfg, 3);
        store.seed_rounding(3);
        store.set_precision(WeightPrecision::Int8);
        let mut blob = Vec::new();
        store.save_store_state(&mut blob);
        let mut other = crate::model::init_params(cfg, 99);
        let mut r = crate::ser::Reader::new(&blob);
        other.load_store_state(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(other.precision(), WeightPrecision::Int8);
        for (a, b) in store.tensors.iter().zip(other.tensors.iter()) {
            assert_eq!(a.data, b.data);
        }
        // The restored rounding stream continues identically: the next
        // commit after drifting both stores the same way is bit-equal.
        for s in [&mut store, &mut other] {
            for t in s.tensors.iter_mut() {
                for v in t.data.iter_mut() {
                    *v += 1e-3;
                }
            }
            s.commit();
        }
        for (a, b) in store.tensors.iter().zip(other.tensors.iter()) {
            assert_eq!(a.data, b.data);
        }
        // A snapshot from a different schema is rejected.
        let small = &PROXY_CONFIGS[0];
        let mut tiny = ParamStore::zeros(small);
        tiny.tensors.pop();
        tiny.metas.pop();
        assert!(tiny.load_store_state(&mut crate::ser::Reader::new(&blob)).is_err());
    }

    #[test]
    fn by_name_roundtrip() {
        let store = ParamStore::zeros(&PROXY_CONFIGS[1]);
        let (idx, t) = store.by_name("layers.2.attn.wo").unwrap();
        assert_eq!(store.metas[idx].kind, ParamKind::Attention);
        assert_eq!(t.shape(), (128, 128));
        assert!(store.by_name("layers.99.nope").is_none());
    }
}
