//! Flattened parameter schema and storage.
//!
//! Order MUST match `python/compile/model.py::param_names` — the training
//! artifact takes weights as positional inputs and returns gradients in the
//! same order. 1-D tensors (norm gains) are stored as (1, n) matrices.

use super::ModelConfig;
use crate::rng::Rng;
use crate::tensor::Matrix;

/// What role a parameter plays — drives GaLore/LoRA targeting (§5.1: only
/// attention and FFN projections are low-rank-projected).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    Embedding,
    Attention,
    Ffn,
    Norm,
    LmHead,
}

/// Metadata for one schema entry.
#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub kind: ParamKind,
}

impl ParamMeta {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Is this a GaLore/LoRA target (2-D attention/FFN projection)?
    pub fn is_projection_target(&self) -> bool {
        matches!(self.kind, ParamKind::Attention | ParamKind::Ffn)
    }
}

/// Build the schema for a config, mirroring model.py exactly.
pub fn schema(cfg: &ModelConfig) -> Vec<ParamMeta> {
    let (d, i, v) = (cfg.dim, cfg.intermediate, cfg.vocab);
    let mut out = Vec::with_capacity(cfg.n_schema_params());
    out.push(ParamMeta { name: "embed.weight".into(), rows: v, cols: d, kind: ParamKind::Embedding });
    for l in 0..cfg.layers {
        let mk = |field: &str, rows, cols, kind| ParamMeta {
            name: format!("layers.{l}.{field}"),
            rows,
            cols,
            kind,
        };
        out.push(mk("attn.wq", d, d, ParamKind::Attention));
        out.push(mk("attn.wk", d, d, ParamKind::Attention));
        out.push(mk("attn.wv", d, d, ParamKind::Attention));
        out.push(mk("attn.wo", d, d, ParamKind::Attention));
        out.push(mk("ffn.w_gate", d, i, ParamKind::Ffn));
        out.push(mk("ffn.w_up", d, i, ParamKind::Ffn));
        out.push(mk("ffn.w_down", i, d, ParamKind::Ffn));
        out.push(mk("attn_norm", 1, d, ParamKind::Norm));
        out.push(mk("ffn_norm", 1, d, ParamKind::Norm));
    }
    out.push(ParamMeta { name: "final_norm".into(), rows: 1, cols: d, kind: ParamKind::Norm });
    out.push(ParamMeta { name: "lm_head.weight".into(), rows: d, cols: v, kind: ParamKind::LmHead });
    out
}

/// All model parameters, in schema order.
pub struct ParamStore {
    pub cfg: &'static ModelConfig,
    pub metas: Vec<ParamMeta>,
    pub tensors: Vec<Matrix>,
}

impl ParamStore {
    /// Zero-initialized store (callers usually want `init_params`).
    pub fn zeros(cfg: &'static ModelConfig) -> Self {
        let metas = schema(cfg);
        let tensors = metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        ParamStore { cfg, metas, tensors }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Indices of GaLore/LoRA target parameters (attention + FFN).
    pub fn projection_targets(&self) -> Vec<usize> {
        self.metas
            .iter()
            .enumerate()
            .filter(|(_, m)| m.is_projection_target())
            .map(|(i, _)| i)
            .collect()
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.metas.iter().map(|m| m.numel()).sum()
    }

    /// Bytes at a given per-element width (2 for BF16 accounting, 4 f32).
    pub fn weight_bytes(&self, bytes_per_el: usize) -> usize {
        self.numel() * bytes_per_el
    }

    pub fn by_name(&self, name: &str) -> Option<(usize, &Matrix)> {
        self.metas
            .iter()
            .position(|m| m.name == name)
            .map(|i| (i, &self.tensors[i]))
    }

    /// Fisher-style parameter perturbation (used by fine-tune experiments
    /// to model a "pre-trained" checkpoint drift).
    pub fn perturb(&mut self, std: f32, rng: &mut Rng) {
        for t in self.tensors.iter_mut() {
            for v in t.data.iter_mut() {
                *v += rng.normal_f32() * std;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PROXY_CONFIGS;

    #[test]
    fn schema_matches_python_layout() {
        let cfg = &PROXY_CONFIGS[0]; // nano
        let s = schema(cfg);
        assert_eq!(s.len(), cfg.n_schema_params());
        assert_eq!(s[0].name, "embed.weight");
        assert_eq!((s[0].rows, s[0].cols), (cfg.vocab, cfg.dim));
        assert_eq!(s[1].name, "layers.0.attn.wq");
        assert_eq!(s[5].name, "layers.0.ffn.w_gate");
        assert_eq!((s[5].rows, s[5].cols), (cfg.dim, cfg.intermediate));
        assert_eq!(s[7].name, "layers.0.ffn.w_down");
        assert_eq!((s[7].rows, s[7].cols), (cfg.intermediate, cfg.dim));
        let last = s.last().unwrap();
        assert_eq!(last.name, "lm_head.weight");
        assert_eq!((last.rows, last.cols), (cfg.dim, cfg.vocab));
    }

    #[test]
    fn numel_matches_config_formula() {
        for cfg in PROXY_CONFIGS {
            let store = ParamStore::zeros(cfg);
            assert_eq!(store.numel() as u64, cfg.n_params(), "{}", cfg.name);
        }
    }

    #[test]
    fn projection_targets_are_attention_and_ffn_only() {
        let cfg = &PROXY_CONFIGS[0];
        let store = ParamStore::zeros(cfg);
        let targets = store.projection_targets();
        assert_eq!(targets.len(), 7 * cfg.layers);
        for &t in &targets {
            assert!(store.metas[t].is_projection_target());
            assert!(store.metas[t].rows > 1 && store.metas[t].cols > 1);
        }
        // Embedding and head excluded.
        assert!(!targets.contains(&0));
        assert!(!targets.contains(&(store.len() - 1)));
    }

    #[test]
    fn by_name_roundtrip() {
        let store = ParamStore::zeros(&PROXY_CONFIGS[1]);
        let (idx, t) = store.by_name("layers.2.attn.wo").unwrap();
        assert_eq!(store.metas[idx].kind, ParamKind::Attention);
        assert_eq!(t.shape(), (128, 128));
        assert!(store.by_name("layers.99.nope").is_none());
    }
}
