//! Model schema: LLaMA-style configs (the paper's Table 5 plus scaled CPU
//! proxies), the flattened parameter schema shared with
//! `python/compile/model.py`, parameter storage and initialization.
//!
//! The *math* of the model lives in the AOT HLO artifacts; this module owns
//! the shapes, the schema order (which must match `model.param_names` on
//! the python side exactly — the runtime feeds literals in this order), and
//! host-side initialization so training is reproducible without python.

mod config;
mod init;
mod params;

pub use config::{ModelConfig, ALL_CONFIGS, PAPER_CONFIGS, PROXY_CONFIGS};
pub use init::init_params;
pub use params::{schema, ParamKind, ParamMeta, ParamStore, WeightPrecision};
