//! LLaMA model configurations.
//!
//! `PAPER_CONFIGS` encodes Table 5 verbatim (60M..7B, with the paper's
//! steps and token budgets); `PROXY_CONFIGS` are the scaled-down shapes the
//! CPU experiments actually train (same architecture family, same r/d
//! ratios — see DESIGN.md §4 Substitutions). Must mirror
//! `python/compile/model.py::CONFIGS`.

/// Static model shape plus the paper's training budget for that size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub dim: usize,
    pub intermediate: usize,
    pub heads: usize,
    pub layers: usize,
    pub seq: usize,
    /// Paper Table 5 training steps (proxies: scaled-down defaults).
    pub steps: usize,
    /// Paper Table 5 data amount in tokens.
    pub tokens: u64,
}

impl ModelConfig {
    pub const fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    /// Total trainable parameters.
    pub fn n_params(&self) -> u64 {
        let (d, i, v) = (self.dim as u64, self.intermediate as u64, self.vocab as u64);
        let per_layer = 4 * d * d + 3 * d * i + 2 * d;
        v * d // embed
            + self.layers as u64 * per_layer
            + d // final norm
            + d * v // lm head
    }

    /// Number of entries in the flattened parameter schema.
    pub fn n_schema_params(&self) -> usize {
        3 + 9 * self.layers
    }

    /// Default GaLore rank for this size (paper Table 2: r/d in 1/4..1/2;
    /// we use d/4 as the canonical setting).
    pub fn default_rank(&self) -> usize {
        (self.dim / 4).max(4)
    }

    pub fn by_name(name: &str) -> Option<&'static ModelConfig> {
        Self::all().find(|c| c.name == name)
    }
}

/// Scaled-down proxies trained on the CPU PJRT backend.
pub const PROXY_CONFIGS: &[ModelConfig] = &[
    ModelConfig {
        name: "nano",
        vocab: 256,
        dim: 64,
        intermediate: 172,
        heads: 4,
        layers: 2,
        seq: 64,
        steps: 300,
        tokens: 300 * 8 * 64,
    },
    ModelConfig {
        name: "micro",
        vocab: 512,
        dim: 128,
        intermediate: 344,
        heads: 4,
        layers: 4,
        seq: 64,
        steps: 600,
        tokens: 600 * 8 * 64,
    },
    ModelConfig {
        name: "mini",
        vocab: 1024,
        dim: 256,
        intermediate: 688,
        heads: 8,
        layers: 4,
        seq: 128,
        steps: 1000,
        tokens: 1000 * 8 * 128,
    },
    ModelConfig {
        name: "small",
        vocab: 2048,
        dim: 512,
        intermediate: 1376,
        heads: 8,
        layers: 6,
        seq: 128,
        steps: 1500,
        tokens: 1500 * 8 * 128,
    },
];

/// The paper's Table 5 (steps/tokens included). Used by the memory
/// estimator and shape tests; never trained on CPU.
pub const PAPER_CONFIGS: &[ModelConfig] = &[
    ModelConfig {
        name: "60m",
        vocab: 32000,
        dim: 512,
        intermediate: 1376,
        heads: 8,
        layers: 8,
        seq: 256,
        steps: 10_000,
        tokens: 1_300_000_000,
    },
    ModelConfig {
        name: "130m",
        vocab: 32000,
        dim: 768,
        intermediate: 2048,
        heads: 12,
        layers: 12,
        seq: 256,
        steps: 20_000,
        tokens: 2_600_000_000,
    },
    ModelConfig {
        name: "350m",
        vocab: 32000,
        dim: 1024,
        intermediate: 2736,
        heads: 16,
        layers: 24,
        seq: 256,
        steps: 60_000,
        tokens: 7_800_000_000,
    },
    // NOTE: the paper's Table 5 lists 24 heads / 32 layers for "1B", but
    // 2048 is not divisible by 24 and the paper's own memory tables imply
    // ~1.3B parameters; we use the ReLoRA-paper 1.3B shape (32 heads,
    // 24 layers) that those numbers are consistent with.
    ModelConfig {
        name: "1b",
        vocab: 32000,
        dim: 2048,
        intermediate: 5461,
        heads: 32,
        layers: 24,
        seq: 256,
        steps: 100_000,
        tokens: 13_100_000_000,
    },
    ModelConfig {
        name: "7b",
        vocab: 32000,
        dim: 4096,
        intermediate: 11008,
        heads: 32,
        layers: 32,
        seq: 2048,
        steps: 150_000,
        tokens: 19_700_000_000,
    },
];

pub const ALL_CONFIGS: &[&[ModelConfig]; 2] = &[PROXY_CONFIGS, PAPER_CONFIGS];

impl ModelConfig {
    pub fn all() -> impl Iterator<Item = &'static ModelConfig> {
        PROXY_CONFIGS.iter().chain(PAPER_CONFIGS.iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        assert_eq!(ModelConfig::by_name("7b").unwrap().dim, 4096);
        assert!(ModelConfig::by_name("42b").is_none());
    }

    #[test]
    fn param_counts_near_nominal() {
        let near = |name: &str, lo: f64, hi: f64| {
            let c = ModelConfig::by_name(name).unwrap();
            let p = c.n_params() as f64;
            assert!(p > lo && p < hi, "{name}: {p}");
        };
        near("60m", 45e6, 80e6);
        near("130m", 100e6, 170e6);
        near("350m", 280e6, 430e6);
        near("1b", 0.9e9, 1.9e9);
        near("7b", 6e9, 8e9);
    }

    #[test]
    fn head_dims_divide() {
        for c in ModelConfig::all() {
            assert_eq!(c.dim % c.heads, 0, "{}", c.name);
        }
    }

    #[test]
    fn paper_token_budgets_match_table2() {
        // Table 2 footer: 1.1B/2.2B/6.4B/13.1B tokens; Table 5 uses
        // 1.3/2.6/7.8/13.1/19.7 — we encode Table 5.
        assert_eq!(ModelConfig::by_name("1b").unwrap().tokens, 13_100_000_000);
        assert_eq!(ModelConfig::by_name("7b").unwrap().tokens, 19_700_000_000);
    }
}
