//! Host-side parameter initialization (scaled normal, std = 1/sqrt(fan_in);
//! norm gains = 1) — the same scheme as `model.py::init_params`, generated
//! by the Rust RNG so runs are reproducible with python absent.

use super::params::{schema, ParamKind, ParamStore};
use super::ModelConfig;
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Initialize all parameters from `seed`.
pub fn init_params(cfg: &'static ModelConfig, seed: u64) -> ParamStore {
    let metas = schema(cfg);
    let root = Rng::new(seed);
    let tensors: Vec<Matrix> = metas
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let mut rng = root.child(i as u64);
            match m.kind {
                ParamKind::Norm => Matrix::ones(m.rows, m.cols),
                _ => {
                    let std = 1.0 / (m.rows as f32).sqrt();
                    Matrix::randn(m.rows, m.cols, std, &mut rng)
                }
            }
        })
        .collect();
    ParamStore::from_tensors(cfg, metas, tensors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::PROXY_CONFIGS;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let cfg = &PROXY_CONFIGS[0];
        let a = init_params(cfg, 1);
        let b = init_params(cfg, 1);
        let c = init_params(cfg, 2);
        assert_eq!(a.tensors[1].data, b.tensors[1].data);
        assert_ne!(a.tensors[1].data, c.tensors[1].data);
    }

    #[test]
    fn norms_are_ones_weights_are_scaled() {
        let cfg = &PROXY_CONFIGS[0];
        let store = init_params(cfg, 0);
        for (meta, t) in store.metas.iter().zip(store.tensors.iter()) {
            match meta.kind {
                ParamKind::Norm => assert!(t.data.iter().all(|&v| v == 1.0)),
                _ => {
                    // Sample std should be near 1/sqrt(fan_in).
                    let want = 1.0 / (meta.rows as f32).sqrt();
                    let var = t.data.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
                        / t.len() as f64;
                    let got = var.sqrt() as f32;
                    assert!(
                        (got - want).abs() < 0.2 * want,
                        "{}: std {got} vs {want}",
                        meta.name
                    );
                }
            }
        }
    }
}
