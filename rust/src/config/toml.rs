//! TOML-subset parser: `[section]` headers, `key = value` pairs with
//! string / integer / float / bool values, `#` comments. Exactly what the
//! run configs under `configs/` use — nested tables and arrays are out of
//! scope on purpose.
//!
//! Recognized sections (consumed by `RunConfig::from_toml` and friends):
//!
//! * top level — `model`, `method`, `backend`, `steps`, `batch`, `lr`,
//!   `seed`, `layerwise`, `eval_every`, `eval_batches`, `dp_workers`,
//!   `dp_compress`, `dp_transport`, `dp_bucket_mb`, `weight_precision`,
//!   `threads`, `artifact_dir`.
//! * `[galore]` — `rank`, `update_freq`, `scale`, `projector_quant`,
//!   `rank_schedule`, `rank_floor`, `rank_decay`, `rank_energy`,
//!   `refresh_gate_cos`.
//! * `[lowrank]` — `rank`, `merge_every` (LoRA/ReLoRA/low-rank baselines).
//! * `[checkpoint]` — `every`, `keep_last`, `dir`.
//! * `[serve]` — the `galore serve` daemon knobs (`ServeConfig::from_toml`):
//!   `socket_path` (Unix-domain socket the daemon binds), `max_jobs`
//!   (resident-job cap), `mem_budget_mb` (admission-control byte budget,
//!   0 = unlimited), `slice_steps` (round-robin steps per scheduler turn),
//!   `job_dir` (evicted checkpoints + JSONL step log), `step_log` (bool).
//! * `[job]` — submit-payload metadata read by the serve API, not by
//!   `RunConfig`: `name`, `workload` (`synthetic`|`artifact`|`finetune`),
//!   `p_bigram` (finetune corpus knob).

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    /// section -> key -> raw value. Top-level keys live under "".
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            let mut value = value.trim().to_string();
            if value.starts_with('"') && value.ends_with('"') && value.len() >= 2 {
                value = value[1..value.len() - 1].to_string();
            }
            doc.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<TomlDoc, String> {
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {:?}: {e}", path.as_ref()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section).and_then(|s| s.get(key)).map(String::as_str)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, section: &str, key: &str) -> Option<T> {
        self.get(section, key).and_then(|v| v.parse().ok())
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_run_config_shape() {
        let doc = TomlDoc::parse(
            r#"
# pre-training config
model = "micro"
steps = 500

[galore]
rank = 32          # quarter dim
update_freq = 200
scale = 0.25

[data]
seed = 42
corpus = "synthetic"
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "model"), Some("micro"));
        assert_eq!(doc.get_parse::<usize>("", "steps"), Some(500));
        assert_eq!(doc.get_parse::<usize>("galore", "rank"), Some(32));
        assert_eq!(doc.get_parse::<f32>("galore", "scale"), Some(0.25));
        assert_eq!(doc.get("data", "corpus"), Some("synthetic"));
        assert_eq!(doc.get("nope", "x"), None);
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = TomlDoc::parse("name = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "name"), Some("a#b"));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("just words").is_err());
    }
}
