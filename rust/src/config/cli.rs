//! Tiny CLI argument parser (offline substitute for `clap`): long flags
//! with values (`--steps 100` or `--steps=100`), boolean switches, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(flag) => write!(f, "unknown flag --{flag}"),
            CliError::MissingValue(flag) => write!(f, "flag --{flag} needs a value"),
            CliError::BadValue(flag, v) => write!(f, "bad value '{v}' for --{flag}"),
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed command line.
pub struct Cli {
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Cli {
    /// `known_switches` are boolean flags that take no value.
    pub fn parse(args: impl Iterator<Item = String>, known_switches: &[&str]) -> Result<Cli, CliError> {
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut positional = Vec::new();
        let mut args = args.peekable();
        while let Some(arg) = args.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&name) {
                    switches.push(name.to_string());
                } else {
                    let v = args.next().ok_or_else(|| CliError::MissingValue(name.into()))?;
                    flags.insert(name.to_string(), v);
                }
            } else {
                positional.push(arg);
            }
        }
        Ok(Cli { flags, switches, positional })
    }

    pub fn from_env(known_switches: &[&str]) -> Result<Cli, CliError> {
        Self::parse(std::env::args().skip(1), known_switches)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError::BadValue(name.into(), v.into())),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(String::from)
    }

    #[test]
    fn flags_switches_positionals() {
        let cli =
            Cli::parse(args("train --model micro --steps=100 --layerwise extra"), &["layerwise"])
                .unwrap();
        assert_eq!(cli.positional(), &["train".to_string(), "extra".to_string()]);
        assert_eq!(cli.get("model"), Some("micro"));
        assert_eq!(cli.get_parse::<usize>("steps").unwrap(), Some(100));
        assert!(cli.has("layerwise"));
        assert!(!cli.has("quiet"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Cli::parse(args("--model"), &[]).is_err());
    }

    #[test]
    fn bad_value_is_error() {
        let cli = Cli::parse(args("--steps abc"), &[]).unwrap();
        assert!(cli.get_parse::<usize>("steps").is_err());
    }
}
