//! Run configuration: a TOML-subset config file format plus a CLI argument
//! parser (offline substitutes for `toml`/`clap`; see DESIGN.md §4).
//!
//! A training run is fully described by a [`RunConfig`] — model size,
//! method, optimizer hyperparameters, GaLore knobs, data seed, schedule —
//! so every experiment in EXPERIMENTS.md is reproducible from its config.

mod cli;
mod run;
mod toml;

pub use cli::{Cli, CliError};
pub use run::{BackendKind, DpTransport, MethodKind, RunConfig, ServeConfig};
pub use toml::TomlDoc;
