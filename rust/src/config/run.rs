//! RunConfig: the full description of one training run.

use super::TomlDoc;
use crate::model::{schema, ModelConfig, WeightPrecision};
use crate::optim::{GaLoreConfig, ProjectorQuant, RankScheduleKind};

/// Which training method drives the run (paper §5.1 roster).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    FullRank,
    AdamW,
    Adam8bit,
    Adafactor,
    GaLore,
    GaLore8bit,
    GaLoreAdafactor,
    Lora,
    ReLora,
    LowRank,
}

impl MethodKind {
    pub fn parse(s: &str) -> Option<MethodKind> {
        Some(match s {
            "full-rank" | "adam" => MethodKind::FullRank,
            "adamw" => MethodKind::AdamW,
            "adam8bit" | "8bit-adam" => MethodKind::Adam8bit,
            "adafactor" => MethodKind::Adafactor,
            "galore" => MethodKind::GaLore,
            "galore8bit" | "8bit-galore" => MethodKind::GaLore8bit,
            "galore-adafactor" => MethodKind::GaLoreAdafactor,
            "lora" => MethodKind::Lora,
            "relora" => MethodKind::ReLora,
            "low-rank" => MethodKind::LowRank,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            MethodKind::FullRank => "full-rank",
            MethodKind::AdamW => "adamw",
            MethodKind::Adam8bit => "adam8bit",
            MethodKind::Adafactor => "adafactor",
            MethodKind::GaLore => "galore",
            MethodKind::GaLore8bit => "galore8bit",
            MethodKind::GaLoreAdafactor => "galore-adafactor",
            MethodKind::Lora => "lora",
            MethodKind::ReLora => "relora",
            MethodKind::LowRank => "low-rank",
        }
    }

    pub fn is_galore(&self) -> bool {
        matches!(self, MethodKind::GaLore | MethodKind::GaLore8bit | MethodKind::GaLoreAdafactor)
    }
}

/// Which execution substrate runs the GaLore compact update (the
/// `optim::backend::StepBackend` plugged into `GaLore<O>` at construction).
/// A backend choice, not a different optimizer: schedules, gating, the DP
/// communication plan, and checkpointing compose identically on either.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust compact-update tail (every method; the default).
    Rust,
    /// Fused `galore_step_{m}x{n}_r{r}` AOT artifacts (Pallas/HLO kernels)
    /// through a backend-owned PJRT engine. Requires `method = "galore"`
    /// (the kernels implement the paper-default GaLore-Adam step) and a
    /// `make artifacts` run covering the model's target shapes.
    Artifact,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        Some(match s {
            "rust" => BackendKind::Rust,
            // "fused" kept as the historical CLI spelling of the same thing.
            "artifact" | "fused" => BackendKind::Artifact,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Rust => "rust",
            BackendKind::Artifact => "artifact",
        }
    }
}

/// Which transport carries the data-parallel ring all-reduce
/// (`coordinator::transport`). A deployment knob, not a trajectory knob:
/// both transports run the identical collective arithmetic, so results
/// are bit-identical across them (and the field stays out of the resume
/// fingerprint, like `threads`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum DpTransport {
    /// In-process channel ring: every replica is a thread of this
    /// process (the default; what every DP run before the socket
    /// transport used).
    #[default]
    Thread,
    /// Multi-process ring over Unix-domain sockets: rank 0 (this
    /// process) binds a rendezvous socket, spawns one worker process per
    /// extra rank, and wires the ring in join order.
    Process,
}

impl DpTransport {
    pub fn parse(s: &str) -> Option<DpTransport> {
        match s.to_ascii_lowercase().as_str() {
            "thread" | "threads" | "channel" => Some(DpTransport::Thread),
            "process" | "socket" => Some(DpTransport::Process),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DpTransport::Thread => "thread",
            DpTransport::Process => "process",
        }
    }
}

/// Full run description. Defaults reproduce the paper's §5.1 settings
/// scaled to the proxy configs.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: &'static ModelConfig,
    pub method: MethodKind,
    /// Step backend for the GaLore compact update (`--backend` /
    /// TOML `backend`; `--fused` is shorthand for `artifact`).
    pub backend: BackendKind,
    pub steps: usize,
    pub batch: usize,
    /// Peak learning rate. Paper: GaLore 0.01 with α=0.25; baselines tuned
    /// per size over {0.01..0.0001}.
    pub lr: f32,
    /// Cosine schedule with warmup over the first 10% (Appendix C.1).
    pub warmup_frac: f32,
    pub final_lr_frac: f32,
    pub galore: GaLoreConfig,
    /// LoRA/ReLoRA/low-rank rank (defaults to galore.rank).
    pub lowrank_rank: usize,
    pub relora_merge_every: u64,
    pub weight_decay: f32,
    pub seed: u64,
    /// §4.3 per-layer weight updates.
    pub layerwise: bool,
    /// Evaluate every N steps (0 = only at end).
    pub eval_every: usize,
    /// Held-out batches per evaluation — the *single* eval window used by
    /// in-loop, final, and data-parallel evals alike, so every point on
    /// the eval curve is comparable (the old code used 2 in-loop but 4 at
    /// the end).
    pub eval_batches: usize,
    /// Data-parallel worker count (1 = single process).
    pub dp_workers: usize,
    /// Compact-gradient data parallelism: between subspace refreshes,
    /// replicas exchange the projected `r×n` gradient instead of the full
    /// `m×n` one for GaLore-targeted layers (full gradients still flow at
    /// refresh boundaries and for non-target parameters). Exact in real
    /// arithmetic; requires a GaLore method.
    pub dp_compress: bool,
    /// Ring transport for the DP gradient exchange (`dp_transport` /
    /// `--dp-transport`): in-process channels (default) or worker
    /// processes over Unix-domain sockets. Bit-identical results either
    /// way, so — like `threads` — it is NOT part of the fingerprint.
    pub dp_transport: DpTransport,
    /// Bucket capacity in MiB for the overlapped all-reduce
    /// (`dp_bucket_mb` / `--dp-bucket-mb`): each replica's compact
    /// gradients are split into ≤ this many MiB per bucket and each
    /// bucket's reduce launches as soon as its parameters finish the
    /// backward sweep, overlapping communication with the remaining
    /// update compute. `0` = the stop-the-world barrier exchange. The
    /// collective *sequence* is identical at any bucket size, so results
    /// are bit-identical and the knob stays out of the fingerprint.
    pub dp_bucket_mb: usize,
    /// Write a full-state (v2) checkpoint every N steps (0 = off). Under
    /// data parallelism rank 0 writes; replicas are bit-identical.
    pub checkpoint_every: usize,
    /// Periodic-checkpoint retention: keep the newest N (0 = keep all).
    pub checkpoint_keep_last: usize,
    /// Directory for periodic checkpoints.
    pub checkpoint_dir: String,
    /// Weight-store precision (`weight_precision` / `--weight-precision`):
    /// `bf16` keeps the master copy of every parameter rounded to
    /// bfloat16 (2 bytes/element on an accelerator; Q-GaLore-style) while
    /// the gradient/update arithmetic runs in f32 working tensors.
    /// Trajectory-shaping (each step rounds the weights), so it is part
    /// of the resume fingerprint.
    pub weight_precision: WeightPrecision,
    /// Worker-pool width for the threaded kernels and the cross-layer
    /// parallel optimizer step (`threads` / `--threads`). 0 = auto
    /// (`GALORE_THREADS` env var, else `available_parallelism`, capped at
    /// 16). Deliberately *not* in the fingerprint: results are
    /// bit-identical at any thread count.
    pub threads: usize,
    /// AOT artifact directory (`artifact_dir` / `--artifact-dir`). Empty =
    /// use [`crate::runtime::default_dir`] (`$GALORE_ARTIFACTS`, then
    /// `$GALORE_ARTIFACT_DIR`, then `./artifacts`). A deployment knob like
    /// `threads` — where the HLO files live cannot shape the trajectory —
    /// so it stays out of the fingerprint; it exists so the serve daemon
    /// and tests can point a run at a private manifest without env-var
    /// games.
    pub artifact_dir: String,
}

impl RunConfig {
    pub fn new(model: &'static ModelConfig, method: MethodKind) -> RunConfig {
        let rank = model.default_rank();
        RunConfig {
            model,
            method,
            backend: BackendKind::Rust,
            steps: model.steps,
            batch: 8,
            lr: if method.is_galore() { 0.01 } else { 0.001 },
            warmup_frac: 0.1,
            final_lr_frac: 0.1,
            galore: GaLoreConfig {
                rank,
                update_freq: 200,
                scale: 0.25,
                rank_floor: rank.min(4).max(1),
                ..Default::default()
            },
            lowrank_rank: rank,
            relora_merge_every: 200,
            weight_decay: 0.0,
            seed: 0,
            layerwise: false,
            eval_every: 0,
            eval_batches: 4,
            dp_workers: 1,
            dp_compress: false,
            dp_transport: DpTransport::Thread,
            dp_bucket_mb: 4,
            checkpoint_every: 0,
            checkpoint_keep_last: 3,
            checkpoint_dir: "checkpoints".into(),
            weight_precision: WeightPrecision::F32,
            threads: 0,
            artifact_dir: String::new(),
        }
    }

    /// The artifact directory this run reads: `artifact_dir` if set, else
    /// the process default (`$GALORE_ARTIFACTS` / `$GALORE_ARTIFACT_DIR` /
    /// `./artifacts`).
    pub fn artifacts_dir(&self) -> std::path::PathBuf {
        if self.artifact_dir.is_empty() {
            crate::runtime::default_dir()
        } else {
            std::path::PathBuf::from(&self.artifact_dir)
        }
    }

    /// Stable one-line digest of every knob that shapes the training
    /// *trajectory*. Stored in v2 checkpoints and compared on resume: a
    /// run resumed under a different fingerprint could silently diverge
    /// from the uninterrupted trajectory, so `Trainer::restore` rejects
    /// the mismatch. Observation-only knobs (eval cadence, checkpoint
    /// cadence, CSV paths) are deliberately excluded.
    pub fn fingerprint(&self) -> String {
        let g = &self.galore;
        format!(
            "model={} method={} backend={} steps={} batch={} lr={} warmup={} final_lr={} wd={} \
             seed={} layerwise={} dp={} dp_compress={} rank={} T={} scale={} quant={} \
             schedule={} floor={} decay={} energy={} gate={} lowrank_rank={} merge={} wprec={}",
            self.model.name,
            self.method.label(),
            // The backend shapes the trajectory: the artifact kernels round
            // their matmuls differently than the Rust tail, so a resume
            // under the other backend would drift off the uninterrupted
            // run. (The state *blob* itself is backend-agnostic.)
            self.backend.label(),
            self.steps,
            self.batch,
            self.lr,
            self.warmup_frac,
            self.final_lr_frac,
            self.weight_decay,
            self.seed,
            self.layerwise,
            self.dp_workers,
            self.dp_compress,
            g.rank,
            g.update_freq,
            g.scale,
            g.projector_quant.label(),
            g.rank_schedule.label(),
            g.rank_floor,
            g.rank_decay,
            g.rank_energy,
            g.refresh_gate_cos,
            self.lowrank_rank,
            self.relora_merge_every,
            // Each step rounds the weights through the store, so the
            // precision shapes the trajectory. `threads` stays out: the
            // parallel step is bit-identical at any width. `dp_transport`
            // and `dp_bucket_mb` stay out for the same reason — both
            // transports and every bucket size run the identical
            // collective sequence, so the trajectory is bit-identical
            // across them (pinned by the DP equivalence tests).
            self.weight_precision.label(),
        )
    }

    /// Fields deliberately *excluded* from `fingerprint()`, each with the
    /// argument for why a resume across a change of that knob cannot
    /// diverge from the uninterrupted trajectory. `galore lint`
    /// (`fingerprint-covers-config`) enforces that every `RunConfig` and
    /// `GaLoreConfig` field is either fingerprinted or listed here, so a
    /// new knob cannot ship without a resume-semantics decision.
    pub const FINGERPRINT_EXEMPT: &'static [(&'static str, &'static str)] = &[
        ("eval_every", "observation cadence; eval reads weights, never advances the run RNG"),
        ("eval_batches", "observation depth; same reason as eval_every"),
        ("dp_transport", "thread and process rings run the identical collective sequence (pinned by the DP equivalence tests)"),
        ("dp_bucket_mb", "bucketing changes overlap, not arithmetic; all-reduce sums are order-fixed per bucket layout and pinned bit-identical"),
        ("checkpoint_every", "durability cadence only; saving is a pure read of run state"),
        ("checkpoint_keep_last", "retention policy for finished artifacts"),
        ("checkpoint_dir", "where checkpoints land, not what is in them"),
        ("threads", "the parallel step is bit-identical at any pool width"),
        ("artifact_dir", "where kernel artifacts are loaded from; the artifact hash, not its path, shapes the math"),
    ];

    /// Reject configs that would fault at step time instead of panicking
    /// deep inside the optimizer (e.g. `update_freq == 0` divides by zero
    /// in `GaLore::step`). Called by `from_toml`, the CLI launcher, and
    /// `Trainer::new`.
    pub fn validate(&self) -> Result<(), String> {
        self.galore.validate()?;
        // A rank beyond the short side of a target matrix would silently
        // clamp at projector construction; reject it up front with the
        // offending parameter named (only GaLore methods project).
        if self.method.is_galore() {
            for meta in schema(self.model) {
                if meta.is_projection_target() {
                    self.galore.validate_for_shape(meta.rows, meta.cols, &meta.name)?;
                }
            }
        }
        if self.lowrank_rank == 0 {
            return Err("lowrank rank must be >= 1".into());
        }
        if self.relora_merge_every == 0 {
            return Err(
                "relora merge_every must be >= 1 (0 would divide by zero in ReLora::step)".into(),
            );
        }
        if self.dp_workers == 0 {
            return Err("dp_workers must be >= 1".into());
        }
        if self.backend == BackendKind::Artifact && self.method != MethodKind::GaLore {
            return Err(format!(
                "backend = 'artifact' drives the fused GaLore-Adam kernels and \
                 requires method = 'galore' (got '{}'); other methods run on the \
                 rust backend",
                self.method.label()
            ));
        }
        if self.dp_compress && !self.method.is_galore() {
            return Err(format!(
                "dp_compress requires a GaLore method (got '{}'): only projected \
                 gradients have a compact form to exchange",
                self.method.label()
            ));
        }
        if self.dp_compress && self.dp_workers < 2 {
            return Err(
                "dp_compress requires dp_workers >= 2: with a single worker there \
                 is no gradient exchange to compress (the flag would be a silent \
                 no-op)"
                    .into(),
            );
        }
        if self.dp_transport == DpTransport::Process && self.dp_workers < 2 {
            return Err(
                "dp_transport = 'process' requires dp_workers >= 2: a single replica \
                 has no ring to carry over sockets (drop the flag for solo runs)"
                    .into(),
            );
        }
        if self.eval_batches == 0 {
            return Err("eval_batches must be >= 1 (the held-out eval window)".into());
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_empty() {
            return Err(
                "checkpoint.every is set but checkpoint.dir is empty — periodic \
                 checkpoints need a directory"
                    .into(),
            );
        }
        Ok(())
    }

    /// Parse from a TOML-subset document (CLI overrides applied by main).
    pub fn from_toml(doc: &TomlDoc) -> Result<RunConfig, String> {
        let model_name = doc.get("", "model").ok_or("missing 'model'")?;
        let model = ModelConfig::by_name(model_name)
            .ok_or_else(|| format!("unknown model '{model_name}'"))?;
        let method = MethodKind::parse(doc.get("", "method").unwrap_or("galore"))
            .ok_or("unknown method")?;
        let mut cfg = RunConfig::new(model, method);
        if let Some(v) = doc.get("", "backend") {
            cfg.backend = BackendKind::parse(v)
                .ok_or_else(|| format!("unknown backend '{v}' (rust|artifact)"))?;
        }
        if let Some(v) = doc.get_parse("", "steps") {
            cfg.steps = v;
        }
        if let Some(v) = doc.get_parse("", "batch") {
            cfg.batch = v;
        }
        if let Some(v) = doc.get_parse("", "lr") {
            cfg.lr = v;
        }
        if let Some(v) = doc.get_parse("", "seed") {
            cfg.seed = v;
        }
        if let Some(v) = doc.get_parse("", "layerwise") {
            cfg.layerwise = v;
        }
        if let Some(v) = doc.get_parse("", "eval_every") {
            cfg.eval_every = v;
        }
        if let Some(v) = doc.get_parse("", "eval_batches") {
            cfg.eval_batches = v;
        }
        if let Some(v) = doc.get_parse("", "dp_workers") {
            cfg.dp_workers = v;
        }
        if let Some(v) = doc.get_parse("", "dp_compress") {
            cfg.dp_compress = v;
        }
        if let Some(v) = doc.get("", "dp_transport") {
            cfg.dp_transport = DpTransport::parse(v)
                .ok_or_else(|| format!("unknown dp_transport '{v}' (thread|process)"))?;
        }
        if let Some(v) = doc.get_parse("", "dp_bucket_mb") {
            cfg.dp_bucket_mb = v;
        }
        if let Some(v) = doc.get("", "weight_precision") {
            cfg.weight_precision = WeightPrecision::parse(v)
                .ok_or_else(|| format!("unknown weight_precision '{v}' (f32|bf16|int8)"))?;
        }
        if let Some(v) = doc.get_parse("", "threads") {
            cfg.threads = v;
        }
        if let Some(v) = doc.get_parse("galore", "rank") {
            cfg.galore.rank = v;
            cfg.lowrank_rank = v;
            // Keep the default floor consistent with a small explicit rank
            // (an explicit rank_floor key below still overrides).
            cfg.galore.rank_floor = cfg.galore.rank_floor.min(cfg.galore.rank).max(1);
        }
        if let Some(v) = doc.get_parse("galore", "update_freq") {
            cfg.galore.update_freq = v;
        }
        if let Some(v) = doc.get_parse("galore", "scale") {
            cfg.galore.scale = v;
        }
        // Back-compat boolean (pre-adaptive configs): true => Block8.
        if let Some(true) = doc.get_parse("galore", "quantize_projector") {
            cfg.galore.projector_quant = ProjectorQuant::Block8;
        }
        if let Some(v) = doc.get("galore", "projector_quant") {
            cfg.galore.projector_quant = ProjectorQuant::parse(v).ok_or_else(|| {
                format!("unknown galore.projector_quant '{v}' (f32|block8|dyn8|int4)")
            })?;
        }
        if let Some(v) = doc.get("galore", "rank_schedule") {
            cfg.galore.rank_schedule = RankScheduleKind::parse(v).ok_or_else(|| {
                format!("unknown galore.rank_schedule '{v}' (fixed|decay|spectral)")
            })?;
        }
        if let Some(v) = doc.get_parse("galore", "rank_floor") {
            cfg.galore.rank_floor = v;
        }
        if let Some(v) = doc.get_parse("galore", "rank_decay") {
            cfg.galore.rank_decay = v;
        }
        if let Some(v) = doc.get_parse("galore", "rank_energy") {
            cfg.galore.rank_energy = v;
        }
        if let Some(v) = doc.get_parse("galore", "refresh_gate_cos") {
            cfg.galore.refresh_gate_cos = v;
        }
        if let Some(v) = doc.get_parse("lowrank", "rank") {
            cfg.lowrank_rank = v;
        }
        if let Some(v) = doc.get_parse("lowrank", "merge_every") {
            cfg.relora_merge_every = v;
        }
        if let Some(v) = doc.get_parse("checkpoint", "every") {
            cfg.checkpoint_every = v;
        }
        if let Some(v) = doc.get_parse("checkpoint", "keep_last") {
            cfg.checkpoint_keep_last = v;
        }
        if let Some(v) = doc.get("checkpoint", "dir") {
            cfg.checkpoint_dir = v.to_string();
        }
        if let Some(v) = doc.get("", "artifact_dir") {
            cfg.artifact_dir = v.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// The train artifact name this run needs.
    pub fn train_artifact(&self) -> String {
        format!("train_{}_b{}", self.model.name, self.batch)
    }

    pub fn eval_artifact(&self) -> String {
        format!("eval_{}_b{}", self.model.name, self.batch)
    }
}

/// Configuration of the resident multi-job daemon (`galore serve`):
/// the `[serve]` TOML section plus CLI overrides.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Unix-domain socket the daemon listens on (and `galore client`
    /// connects to).
    pub socket_path: String,
    /// Maximum jobs resident (admitted/running) at once; further
    /// submissions queue.
    pub max_jobs: usize,
    /// Global memory budget in MiB for admission control (0 = unlimited).
    /// A job is admitted only while the `memory::breakdown` estimates of
    /// every resident job plus its own fit under this budget; otherwise it
    /// stays `Queued` until capacity frees.
    pub mem_budget_mb: usize,
    /// Steps each resident job runs per scheduler turn (round-robin
    /// slicing; smaller = fairer interleaving, larger = less switching).
    pub slice_steps: usize,
    /// Directory for evicted-job checkpoints and the JSONL step log.
    pub job_dir: String,
    /// Write per-step JSONL rows (job id, name, step, loss, lr, tokens)
    /// to `<job_dir>/steps.jsonl`.
    pub step_log: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            socket_path: "galore-serve.sock".into(),
            max_jobs: 4,
            mem_budget_mb: 0,
            slice_steps: 25,
            job_dir: "serve-jobs".into(),
            step_log: true,
        }
    }
}

impl ServeConfig {
    /// Parse the `[serve]` section of a config document (missing keys keep
    /// their defaults; a document without the section is the default
    /// config).
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeConfig, String> {
        let mut cfg = ServeConfig::default();
        if let Some(v) = doc.get("serve", "socket_path") {
            cfg.socket_path = v.to_string();
        }
        if let Some(v) = doc.get_parse("serve", "max_jobs") {
            cfg.max_jobs = v;
        }
        if let Some(v) = doc.get_parse("serve", "mem_budget_mb") {
            cfg.mem_budget_mb = v;
        }
        if let Some(v) = doc.get_parse("serve", "slice_steps") {
            cfg.slice_steps = v;
        }
        if let Some(v) = doc.get("serve", "job_dir") {
            cfg.job_dir = v.to_string();
        }
        if let Some(v) = doc.get_parse("serve", "step_log") {
            cfg.step_log = v;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.socket_path.is_empty() {
            return Err("serve.socket_path must not be empty".into());
        }
        if self.max_jobs == 0 {
            return Err("serve.max_jobs must be >= 1 (0 jobs would never run anything)".into());
        }
        if self.slice_steps == 0 {
            return Err(
                "serve.slice_steps must be >= 1 (a zero-step slice makes no progress)".into()
            );
        }
        if self.job_dir.is_empty() {
            return Err(
                "serve.job_dir must not be empty — paused jobs evict their checkpoints there"
                    .into(),
            );
        }
        Ok(())
    }

    /// The admission budget in bytes (0 = unlimited).
    pub fn budget_bytes(&self) -> u64 {
        self.mem_budget_mb as u64 * (1 << 20)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let cfg = RunConfig::new(ModelConfig::by_name("micro").unwrap(), MethodKind::GaLore);
        assert_eq!(cfg.galore.update_freq, 200);
        assert!((cfg.galore.scale - 0.25).abs() < 1e-6);
        assert!((cfg.lr - 0.01).abs() < 1e-6);
        assert_eq!(cfg.galore.rank, 32); // micro dim 128 / 4
    }

    #[test]
    fn from_toml_overrides() {
        let doc = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore8bit\"\nsteps = 42\nlayerwise = true\n[galore]\nrank = 8\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.model.name, "nano");
        assert_eq!(cfg.method, MethodKind::GaLore8bit);
        assert_eq!(cfg.steps, 42);
        assert!(cfg.layerwise);
        assert_eq!(cfg.galore.rank, 8);
        assert_eq!(cfg.train_artifact(), "train_nano_b8");
    }

    #[test]
    fn validate_rejects_zero_update_freq() {
        let mut cfg = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        assert!(cfg.validate().is_ok());
        cfg.galore.update_freq = 0;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("update_freq"), "{err}");
    }

    #[test]
    fn from_toml_rejects_zero_update_freq() {
        let doc = TomlDoc::parse("model = \"nano\"\n[galore]\nupdate_freq = 0\n").unwrap();
        let err = RunConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("update_freq"), "{err}");
    }

    #[test]
    fn validate_rejects_degenerate_knobs() {
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let mut c = base.clone();
        c.galore.rank = 0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.relora_merge_every = 0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.dp_workers = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_toml_parses_adaptive_knobs() {
        let doc = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore\"\n[galore]\nrank = 16\n\
             rank_schedule = \"spectral\"\nrank_floor = 2\nrank_energy = 0.95\n\
             refresh_gate_cos = 0.7\nprojector_quant = \"dyn8\"\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.galore.rank_schedule, RankScheduleKind::Spectral);
        assert_eq!(cfg.galore.rank_floor, 2);
        assert!((cfg.galore.rank_energy - 0.95).abs() < 1e-6);
        assert!((cfg.galore.refresh_gate_cos - 0.7).abs() < 1e-6);
        assert_eq!(cfg.galore.projector_quant, ProjectorQuant::Dyn8);
        assert!(cfg.galore.is_adaptive());
    }

    #[test]
    fn quantize_projector_bool_still_parses_as_block8() {
        let doc =
            TomlDoc::parse("model = \"nano\"\n[galore]\nquantize_projector = true\n").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.galore.projector_quant, ProjectorQuant::Block8);
    }

    #[test]
    fn validate_rejects_rank_beyond_target_short_side() {
        // The fix this PR pins: rank > min(m, n) of a projection target
        // used to pass validation and silently clamp at construction.
        let mut cfg = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        cfg.galore.rank = cfg.model.dim + 1;
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("exceeds min(m, n)"), "{err}");
        assert!(err.contains("rank"), "{err}");
        // Non-GaLore methods carry the knob but never project: accepted.
        let mut lora = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::Lora);
        lora.galore.rank = lora.model.dim + 1;
        assert!(lora.validate().is_ok());
    }

    #[test]
    fn validate_rejects_degenerate_adaptive_knobs() {
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let mut c = base.clone();
        c.galore.rank_floor = 0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.galore.rank_floor = c.galore.rank + 1;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.galore.rank_decay = 0.0;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.galore.rank_energy = 1.5;
        assert!(c.validate().is_err());
        let mut c = base.clone();
        c.galore.refresh_gate_cos = 1.0;
        assert!(c.validate().is_err());
        let mut c = base;
        c.galore.refresh_gate_cos = 0.9;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn from_toml_parses_checkpoint_knobs() {
        let doc = TomlDoc::parse(
            "model = \"nano\"\n[checkpoint]\nevery = 50\nkeep_last = 2\ndir = \"ckpts/run1\"\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.checkpoint_every, 50);
        assert_eq!(cfg.checkpoint_keep_last, 2);
        assert_eq!(cfg.checkpoint_dir, "ckpts/run1");
        // Empty dir with cadence on is rejected.
        let bad =
            TomlDoc::parse("model = \"nano\"\n[checkpoint]\nevery = 50\ndir = \"\"\n").unwrap();
        assert!(RunConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn dp_compress_parses_and_requires_galore() {
        let doc = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore\"\ndp_workers = 4\ndp_compress = true\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert!(cfg.dp_compress);
        assert_eq!(cfg.dp_workers, 4);
        // Non-GaLore methods have no compact gradient to exchange.
        let bad = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"adamw\"\ndp_workers = 4\ndp_compress = true\n",
        )
        .unwrap();
        let err = RunConfig::from_toml(&bad).unwrap_err();
        assert!(err.contains("dp_compress"), "{err}");
        assert!(err.contains("GaLore"), "{err}");
        // A single worker has no exchange to compress: reject the silent
        // no-op instead of printing a banner that reads like it's on.
        let solo =
            TomlDoc::parse("model = \"nano\"\nmethod = \"galore\"\ndp_compress = true\n").unwrap();
        let err = RunConfig::from_toml(&solo).unwrap_err();
        assert!(err.contains("dp_workers >= 2"), "{err}");
    }

    #[test]
    fn dp_transport_parses_and_requires_workers() {
        let doc = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore\"\ndp_workers = 2\n\
             dp_transport = \"process\"\ndp_bucket_mb = 8\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.dp_transport, DpTransport::Process);
        assert_eq!(cfg.dp_bucket_mb, 8);
        // Defaults: thread transport, 4 MiB buckets.
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        assert_eq!(base.dp_transport, DpTransport::Thread);
        assert_eq!(base.dp_bucket_mb, 4);
        // "socket" is an accepted spelling; junk is not.
        assert_eq!(DpTransport::parse("socket"), Some(DpTransport::Process));
        assert_eq!(DpTransport::parse("thread"), Some(DpTransport::Thread));
        assert_eq!(DpTransport::parse("tcp"), None);
        // A process ring needs at least two ranks.
        let solo = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore\"\ndp_transport = \"process\"\n",
        )
        .unwrap();
        let err = RunConfig::from_toml(&solo).unwrap_err();
        assert!(err.contains("dp_workers >= 2"), "{err}");
        // dp_bucket_mb = 0 selects the barrier exchange: valid anywhere.
        let barrier = TomlDoc::parse("model = \"nano\"\ndp_bucket_mb = 0\n").unwrap();
        assert_eq!(RunConfig::from_toml(&barrier).unwrap().dp_bucket_mb, 0);
    }

    #[test]
    fn dp_transport_and_bucket_stay_out_of_fingerprint() {
        // Both knobs are bit-exactness-preserving deployment choices: a
        // checkpoint written by a thread-transport run must resume under
        // the socket transport (and any bucket size) without a mismatch.
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let fp = base.fingerprint();
        let mut proc = base.clone();
        proc.dp_workers = 2;
        proc.dp_transport = DpTransport::Process;
        let mut threaded = base.clone();
        threaded.dp_workers = 2;
        assert_eq!(threaded.fingerprint(), proc.fingerprint());
        let mut bucketed = base.clone();
        bucketed.dp_bucket_mb = 64;
        assert_eq!(fp, bucketed.fingerprint());
        let mut barrier = base.clone();
        barrier.dp_bucket_mb = 0;
        assert_eq!(fp, barrier.fingerprint());
    }

    #[test]
    fn eval_batches_parses_and_rejects_zero() {
        let doc = TomlDoc::parse("model = \"nano\"\neval_batches = 8\n").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.eval_batches, 8);
        assert_eq!(
            RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore)
                .eval_batches,
            4
        );
        let bad = TomlDoc::parse("model = \"nano\"\neval_batches = 0\n").unwrap();
        assert!(RunConfig::from_toml(&bad).unwrap_err().contains("eval_batches"));
    }

    #[test]
    fn fingerprint_tracks_trajectory_knobs_only() {
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let fp = base.fingerprint();
        assert_eq!(fp, base.clone().fingerprint(), "fingerprint must be deterministic");
        let mut diff = base.clone();
        diff.lr *= 2.0;
        assert_ne!(fp, diff.fingerprint(), "lr must change the fingerprint");
        let mut diff = base.clone();
        diff.galore.rank = 8;
        assert_ne!(fp, diff.fingerprint());
        let mut diff = base.clone();
        diff.dp_compress = true;
        assert_ne!(fp, diff.fingerprint(), "dp_compress changes reduction order");
        let mut same = base.clone();
        same.eval_every = 10;
        same.eval_batches = 8;
        same.checkpoint_every = 50;
        assert_eq!(fp, same.fingerprint(), "observation knobs must not change it");
    }

    #[test]
    fn backend_parses_requires_galore_and_fingerprints() {
        // Spellings: "fused" is the historical alias for the artifact backend.
        assert_eq!(BackendKind::parse("rust"), Some(BackendKind::Rust));
        assert_eq!(BackendKind::parse("artifact"), Some(BackendKind::Artifact));
        assert_eq!(BackendKind::parse("fused"), Some(BackendKind::Artifact));
        assert_eq!(BackendKind::parse("pallas"), None);
        // TOML plumbing.
        let doc =
            TomlDoc::parse("model = \"nano\"\nmethod = \"galore\"\nbackend = \"artifact\"\n")
                .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.backend, BackendKind::Artifact);
        // The artifact backend implements GaLore-Adam only.
        let bad = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore8bit\"\nbackend = \"artifact\"\n",
        )
        .unwrap();
        let err = RunConfig::from_toml(&bad).unwrap_err();
        assert!(err.contains("artifact"), "{err}");
        assert!(err.contains("galore"), "{err}");
        // The backend shapes the trajectory => it participates in the
        // resume fingerprint.
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let mut fused = base.clone();
        fused.backend = BackendKind::Artifact;
        assert_ne!(base.fingerprint(), fused.fingerprint());
        // ...and composes with dp_compress in validation (the PR 4
        // restriction is lifted at the config level).
        let both = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore\"\nbackend = \"artifact\"\n\
             dp_workers = 4\ndp_compress = true\n",
        )
        .unwrap();
        assert!(RunConfig::from_toml(&both).is_ok());
    }

    #[test]
    fn weight_precision_and_threads_parse() {
        let doc =
            TomlDoc::parse("model = \"nano\"\nweight_precision = \"bf16\"\nthreads = 3\n").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.weight_precision, WeightPrecision::Bf16);
        assert_eq!(cfg.threads, 3);
        // Defaults: f32 store, auto-sized pool.
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        assert_eq!(base.weight_precision, WeightPrecision::F32);
        assert_eq!(base.threads, 0);
        let bad = TomlDoc::parse("model = \"nano\"\nweight_precision = \"fp8\"\n").unwrap();
        assert!(RunConfig::from_toml(&bad).unwrap_err().contains("weight_precision"));
        // The Q-GaLore low-precision pair parses from TOML.
        let low = TomlDoc::parse(
            "model = \"nano\"\nmethod = \"galore\"\nweight_precision = \"int8\"\n\
             [galore]\nprojector_quant = \"int4\"\n",
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&low).unwrap();
        assert_eq!(cfg.weight_precision, WeightPrecision::Int8);
        assert_eq!(cfg.galore.projector_quant, ProjectorQuant::Int4);
    }

    #[test]
    fn weight_precision_fingerprints_threads_do_not() {
        // bf16/int8 round the weights every step (trajectory-shaping); the
        // pool width is bit-exact by design and must NOT pin a resume.
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let fp = base.fingerprint();
        let mut bf16 = base.clone();
        bf16.weight_precision = WeightPrecision::Bf16;
        assert_ne!(fp, bf16.fingerprint());
        let mut int8 = base.clone();
        int8.weight_precision = WeightPrecision::Int8;
        assert_ne!(fp, int8.fingerprint());
        assert_ne!(bf16.fingerprint(), int8.fingerprint());
        assert!(int8.fingerprint().contains("wprec=int8"));
        // projector_quant = int4 is trajectory-shaping too (the basis the
        // run projects against is the dequantized int4 store).
        let mut int4 = base.clone();
        int4.galore.projector_quant = ProjectorQuant::Int4;
        assert_ne!(fp, int4.fingerprint());
        assert!(int4.fingerprint().contains("quant=int4"));
        let mut threaded = base.clone();
        threaded.threads = 4;
        assert_eq!(fp, threaded.fingerprint());
    }

    #[test]
    fn artifact_dir_parses_and_stays_out_of_fingerprint() {
        let doc = TomlDoc::parse("model = \"nano\"\nartifact_dir = \"/tmp/private\"\n").unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.artifact_dir, "/tmp/private");
        assert_eq!(cfg.artifacts_dir(), std::path::PathBuf::from("/tmp/private"));
        // Where the HLO files live cannot shape the trajectory.
        let base = RunConfig::new(ModelConfig::by_name("nano").unwrap(), MethodKind::GaLore);
        let mut moved = base.clone();
        moved.artifact_dir = "elsewhere".into();
        assert_eq!(base.fingerprint(), moved.fingerprint());
    }

    #[test]
    fn serve_config_defaults_parse_and_validate() {
        let d = ServeConfig::default();
        assert!(d.validate().is_ok());
        assert_eq!(d.max_jobs, 4);
        assert_eq!(d.mem_budget_mb, 0);
        let doc = TomlDoc::parse(
            "[serve]\nsocket_path = \"/tmp/g.sock\"\nmax_jobs = 2\nmem_budget_mb = 512\n\
             slice_steps = 10\njob_dir = \"jd\"\nstep_log = false\n",
        )
        .unwrap();
        let cfg = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.socket_path, "/tmp/g.sock");
        assert_eq!(cfg.max_jobs, 2);
        assert_eq!(cfg.mem_budget_mb, 512);
        assert_eq!(cfg.budget_bytes(), 512 << 20);
        assert_eq!(cfg.slice_steps, 10);
        assert_eq!(cfg.job_dir, "jd");
        assert!(!cfg.step_log);
        // A document without a [serve] section is the default config.
        let none = TomlDoc::parse("model = \"nano\"\n").unwrap();
        assert_eq!(ServeConfig::from_toml(&none).unwrap().max_jobs, 4);
        // Degenerate knobs are rejected up front.
        for bad in [
            "[serve]\nmax_jobs = 0\n",
            "[serve]\nslice_steps = 0\n",
            "[serve]\nsocket_path = \"\"\n",
            "[serve]\njob_dir = \"\"\n",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ServeConfig::from_toml(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            MethodKind::FullRank,
            MethodKind::Adam8bit,
            MethodKind::GaLore8bit,
            MethodKind::Lora,
            MethodKind::ReLora,
            MethodKind::LowRank,
        ] {
            assert_eq!(MethodKind::parse(m.label()), Some(m), "{}", m.label());
        }
        assert_eq!(MethodKind::parse("nope"), None);
    }
}
