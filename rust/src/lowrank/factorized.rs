//! "Low-Rank" baseline of Table 2: the weight itself is a learned low-rank
//! factorization W = BA (Kamalakara et al., 2022). No frozen full-rank
//! component — which is exactly why it collapses at scale (78.18 ppl at
//! 60M in the paper vs 34.06 full-rank).

use super::FactorState;
use crate::optim::{Adam, AdamConfig, Optimizer};
use crate::rng::Rng;
use crate::ser;
use crate::tensor::{matmul_a_bt_into, matmul_at_b_into, matmul_into, Matrix};
use std::collections::{HashMap, HashSet};

struct Factors {
    b: Matrix, // (m, r)
    a: Matrix, // (r, n)
    opt_b: FactorState,
    opt_a: FactorState,
    /// Reusable factor-gradient buffers (working memory).
    gb: Matrix,
    ga: Matrix,
}

pub struct Factorized {
    pub rank: usize,
    adam_cfg: AdamConfig,
    targets: HashSet<usize>,
    explicit_targets: bool,
    factors: HashMap<usize, Factors>,
    full_rank: Adam,
    rng: Rng,
}

impl Factorized {
    pub fn new(rank: usize) -> Self {
        Factorized {
            rank,
            adam_cfg: AdamConfig::default(),
            targets: HashSet::new(),
            explicit_targets: false,
            factors: HashMap::new(),
            full_rank: Adam::new(AdamConfig::default()),
            rng: Rng::new(0xFAC7),
        }
    }

    pub fn with_targets(mut self, targets: impl IntoIterator<Item = usize>) -> Self {
        self.targets = targets.into_iter().collect();
        self.explicit_targets = true;
        self
    }

    /// Seed the factor-init RNG from the run seed (reproducible runs).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.rng = Rng::new(seed ^ 0xFAC7);
        self
    }

    fn is_target(&self, param: usize, grad: &Matrix) -> bool {
        if self.explicit_targets {
            return self.targets.contains(&param);
        }
        grad.rows > 1 && grad.cols > 1 && grad.rows.min(grad.cols) > self.rank
    }
}

impl Optimizer for Factorized {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        if !self.is_target(param, grad) {
            return self.full_rank.step(param, w, grad, lr);
        }
        let (m, n) = w.shape();
        let r = self.rank.min(m).min(n);
        let rng = &mut self.rng;
        let f = self.factors.entry(param).or_insert_with(|| {
            // Initialize so that BA ≈ current W's scale: split the variance
            // between the two factors.
            Factors {
                b: Matrix::randn(m, r, 1.0 / (m as f32).sqrt(), rng),
                a: Matrix::randn(r, n, 1.0 / (r as f32).sqrt(), rng),
                opt_b: FactorState::new(m, r),
                opt_a: FactorState::new(r, n),
                gb: Matrix::zeros(0, 0),
                ga: Matrix::zeros(0, 0),
            }
        });
        matmul_a_bt_into(grad, &f.a, &mut f.gb);
        matmul_at_b_into(&f.b, grad, &mut f.ga);
        f.opt_b.adam_step(&mut f.b, &f.gb, lr, &self.adam_cfg);
        f.opt_a.adam_step(&mut f.a, &f.ga, lr, &self.adam_cfg);
        matmul_into(&f.b, &f.a, w);
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.full_rank.state_bytes()
            + self
                .factors
                .values()
                .map(|f| f.opt_b.nbytes() + f.opt_a.nbytes())
                .sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "low-rank"
    }

    fn reset_state(&mut self) {
        self.factors.clear();
        self.full_rank.reset_state();
    }

    /// Checkpoint v2: the learned factors ARE the weights here — without
    /// them a resumed run cannot even rebuild W = BA.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        ser::put_rng(out, &self.rng);
        let mut fr = Vec::new();
        self.full_rank.save_state(&mut fr)?;
        ser::put_bytes(out, &fr);
        let mut params: Vec<usize> = self.factors.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in params {
            let f = &self.factors[&p];
            ser::put_usize(out, p);
            ser::put_matrix(out, &f.b);
            ser::put_matrix(out, &f.a);
            f.opt_b.save_state(out);
            f.opt_a.save_state(out);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.rng = r.rng()?;
        let fr = r.bytes()?;
        let mut frr = ser::Reader::new(fr);
        self.full_rank.load_state(&mut frr)?;
        frr.expect_end()?;
        self.factors.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let b = r.matrix()?;
            let a = r.matrix()?;
            let opt_b = FactorState::load_state(r)?;
            let opt_a = FactorState::load_state(r)?;
            if b.cols != a.rows {
                return Err(format!(
                    "factorized param {p}: B {:?} and A {:?} disagree on rank",
                    b.shape(),
                    a.shape()
                ));
            }
            self.factors.insert(
                p,
                Factors { b, a, opt_b, opt_a, gb: Matrix::zeros(0, 0), ga: Matrix::zeros(0, 0) },
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_jacobi;
    use crate::tensor::matmul;

    #[test]
    fn weight_is_always_rank_r() {
        let mut rng = Rng::new(0);
        let mut fac = Factorized::new(2);
        let mut w = Matrix::randn(12, 16, 1.0, &mut rng);
        for s in 0..10 {
            let g = Matrix::randn(12, 16, 1.0, &mut rng.child(s));
            fac.step(0, &mut w, &g, 0.01).unwrap();
            let svd = svd_jacobi(&w);
            assert!(svd.s[2] < 1e-4 * svd.s[0].max(1e-6));
        }
    }

    #[test]
    fn cannot_fit_high_rank_target() {
        // The §3.2 motivating failure: if W* is full-rank, rank-r BA can
        // never reach it — residual stalls well above zero.
        let _ = Rng::new(1);
        let w_star = Matrix::eye(12); // rank 12
        let mut w = Matrix::zeros(12, 12);
        let mut fac = Factorized::new(2);
        let mut last = f32::MAX;
        for _ in 0..300 {
            let mut g = w.clone();
            g.sub_assign(&w_star);
            last = g.frobenius_norm();
            fac.step(0, &mut w, &g, 0.05).unwrap();
        }
        // Best possible rank-2 approximation of I_12 leaves sqrt(10) ≈ 3.16.
        assert!(last > 2.5, "impossibly good: {last}");
    }

    #[test]
    fn fits_low_rank_target() {
        let mut rng = Rng::new(2);
        let u = Matrix::randn(10, 2, 1.0, &mut rng);
        let v = Matrix::randn(2, 14, 1.0, &mut rng);
        let w_star = matmul(&u, &v);
        let mut w = Matrix::zeros(10, 14);
        let mut fac = Factorized::new(2);
        let mut first = 0.0;
        let mut last = 0.0;
        for t in 0..400 {
            let mut g = w.clone();
            g.sub_assign(&w_star);
            let loss = g.frobenius_norm();
            if t == 0 {
                first = loss;
            }
            last = loss;
            fac.step(0, &mut w, &g, 0.05).unwrap();
        }
        assert!(last < 0.15 * first, "{first} -> {last}");
    }
}
