//! Low-rank *weight* baselines the paper compares against (§5.1):
//!
//! * [`Lora`] — W = W₀ + (α/r)·BA with frozen W₀ (Hu et al., 2022).
//! * [`ReLora`] — LoRA that periodically merges BA into W₀ and restarts
//!   the adaptor + optimizer state (Lialin et al., 2024), evaluated
//!   without full-rank warmup as in Table 2.
//! * [`Factorized`] — W = BA learned from scratch (Kamalakara et al.,
//!   2022), the "Low-Rank" row of Table 2.
//!
//! All three implement [`Optimizer`] so the coordinator treats them
//! uniformly: `step` consumes the *full* weight gradient from the AOT
//! artifact, applies the chain rule to the factors (∂L/∂B = s·G Aᵀ,
//! ∂L/∂A = s·Bᵀ G), Adam-updates the factors, and re-materializes the
//! effective weight in place (the artifact always receives dense weights).

mod factorized;
mod lora;
mod relora;

pub use factorized::Factorized;
pub use lora::{Lora, LoraConfig};
pub use relora::ReLora;

use crate::optim::AdamConfig;
use crate::ser;
use crate::tensor::Matrix;

/// Adam moments for one factor matrix.
pub(crate) struct FactorState {
    pub m: Matrix,
    pub v: Matrix,
    /// Reusable normalized-update buffer (working memory, excluded from
    /// `nbytes` — Table 1 counts moments only).
    upd: Matrix,
    pub t: u64,
}

impl FactorState {
    pub fn new(rows: usize, cols: usize) -> Self {
        FactorState {
            m: Matrix::zeros(rows, cols),
            v: Matrix::zeros(rows, cols),
            upd: Matrix::zeros(0, 0),
            t: 0,
        }
    }

    /// One Adam update on `w` given `grad` — allocation-free once warm.
    pub fn adam_step(&mut self, w: &mut Matrix, grad: &Matrix, lr: f32, cfg: &AdamConfig) {
        self.t += 1;
        crate::optim::Adam::normalized_update_into(
            &mut self.m,
            &mut self.v,
            grad,
            self.t,
            cfg,
            &mut self.upd,
        );
        w.axpy(-lr, &self.upd);
    }

    pub fn nbytes(&self) -> usize {
        4 * (self.m.len() + self.v.len())
    }

    /// Checkpoint v2: moments + step counter (`upd` is per-step scratch).
    pub(crate) fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_u64(out, self.t);
        ser::put_matrix(out, &self.m);
        ser::put_matrix(out, &self.v);
    }

    pub(crate) fn load_state(r: &mut ser::Reader<'_>) -> Result<FactorState, String> {
        let t = r.u64()?;
        let m = r.matrix()?;
        let v = r.matrix()?;
        if m.shape() != v.shape() {
            return Err(format!(
                "factor state: M shape {:?} != V shape {:?}",
                m.shape(),
                v.shape()
            ));
        }
        Ok(FactorState { m, v, upd: Matrix::zeros(0, 0), t })
    }
}
