//! LoRA baseline: W = W₀ + (α/r)·BA, W₀ frozen (Hu et al., 2022).

use super::FactorState;
use crate::optim::{Adam, AdamConfig, Optimizer};
use crate::rng::Rng;
use crate::ser;
use crate::tensor::{matmul_a_bt_into, matmul_at_b_into, matmul_into, Matrix};
use std::collections::{HashMap, HashSet};

#[derive(Clone, Copy, Debug)]
pub struct LoraConfig {
    pub rank: usize,
    /// LoRA alpha; effective scale is alpha / rank. Paper §5.1 uses 32.
    pub alpha: f32,
}

impl Default for LoraConfig {
    fn default() -> Self {
        LoraConfig { rank: 128, alpha: 32.0 }
    }
}

impl LoraConfig {
    pub fn scale(&self) -> f32 {
        self.alpha / self.rank as f32
    }
}

pub(crate) struct AdaptorState {
    pub w0: Matrix,
    pub b: Matrix, // (m, r), zero-init
    pub a: Matrix, // (r, n), gaussian-init
    pub opt_b: FactorState,
    pub opt_a: FactorState,
    /// Reusable factor-gradient buffers (working memory, excluded from the
    /// Table 1 state accounting).
    gb: Matrix,
    ga: Matrix,
}

impl AdaptorState {
    pub fn new(w: &Matrix, rank: usize, rng: &mut Rng) -> Self {
        let (m, n) = w.shape();
        let r = rank.min(m).min(n);
        AdaptorState {
            w0: w.clone(),
            b: Matrix::zeros(m, r),
            a: Matrix::randn(r, n, 1.0 / (r as f32).sqrt(), rng),
            opt_b: FactorState::new(m, r),
            opt_a: FactorState::new(r, n),
            gb: Matrix::zeros(0, 0),
            ga: Matrix::zeros(0, 0),
        }
    }

    /// Effective weight W₀ + s·BA (allocating wrapper over
    /// [`AdaptorState::materialize_into`]; merges and tests only).
    pub fn materialize(&self, scale: f32) -> Matrix {
        let mut out = Matrix::zeros(0, 0);
        self.materialize_into(scale, &mut out);
        out
    }

    /// Write W₀ + s·BA into `out` — the per-step path, allocation-free
    /// once `out` is warm (trainers pass the live weight buffer).
    pub fn materialize_into(&self, scale: f32, out: &mut Matrix) {
        matmul_into(&self.b, &self.a, out);
        out.scale(scale);
        out.add_assign(&self.w0);
    }

    /// Chain rule + Adam updates for both factors given the full-weight
    /// gradient G: ∂L/∂B = s·G Aᵀ, ∂L/∂A = s·Bᵀ G. Allocation-free once
    /// the factor-gradient buffers are warm.
    pub fn update_factors(&mut self, grad: &Matrix, lr: f32, scale: f32, cfg: &AdamConfig) {
        matmul_a_bt_into(grad, &self.a, &mut self.gb);
        self.gb.scale(scale);
        matmul_at_b_into(&self.b, grad, &mut self.ga);
        self.ga.scale(scale);
        self.opt_b.adam_step(&mut self.b, &self.gb, lr, cfg);
        self.opt_a.adam_step(&mut self.a, &self.ga, lr, cfg);
    }

    pub fn state_bytes(&self) -> usize {
        self.opt_b.nbytes() + self.opt_a.nbytes()
    }

    /// Adaptor weight bytes (B and A) — extra *weight* memory vs GaLore
    /// (Table 1's `mn + mr + nr` weights row).
    pub fn adaptor_bytes(&self) -> usize {
        4 * (self.b.len() + self.a.len())
    }

    /// Checkpoint v2: the frozen base, both factors, and their optimizer
    /// moments. The adaptor factors are *trained weights* that live
    /// outside the `ParamStore`, so a weights-only checkpoint genuinely
    /// loses them — full fidelity requires this path.
    pub(crate) fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_matrix(out, &self.w0);
        ser::put_matrix(out, &self.b);
        ser::put_matrix(out, &self.a);
        self.opt_b.save_state(out);
        self.opt_a.save_state(out);
    }

    pub(crate) fn load_state(r: &mut ser::Reader<'_>) -> Result<AdaptorState, String> {
        let w0 = r.matrix()?;
        let b = r.matrix()?;
        let a = r.matrix()?;
        let opt_b = FactorState::load_state(r)?;
        let opt_a = FactorState::load_state(r)?;
        if b.cols != a.rows || b.rows != w0.rows || a.cols != w0.cols {
            return Err(format!(
                "adaptor shapes disagree: w0 {:?}, B {:?}, A {:?}",
                w0.shape(),
                b.shape(),
                a.shape()
            ));
        }
        Ok(AdaptorState {
            w0,
            b,
            a,
            opt_b,
            opt_a,
            gb: Matrix::zeros(0, 0),
            ga: Matrix::zeros(0, 0),
        })
    }
}

pub struct Lora {
    pub cfg: LoraConfig,
    adam_cfg: AdamConfig,
    targets: HashSet<usize>,
    explicit_targets: bool,
    pub(crate) adaptors: HashMap<usize, AdaptorState>,
    full_rank: Adam,
    rng: Rng,
}

impl Lora {
    pub fn new(cfg: LoraConfig) -> Self {
        Lora {
            cfg,
            adam_cfg: AdamConfig::default(),
            targets: HashSet::new(),
            explicit_targets: false,
            adaptors: HashMap::new(),
            full_rank: Adam::new(AdamConfig::default()),
            rng: Rng::new(0x10A4),
        }
    }

    pub fn with_targets(mut self, targets: impl IntoIterator<Item = usize>) -> Self {
        self.targets = targets.into_iter().collect();
        self.explicit_targets = true;
        self
    }

    /// Seed the adaptor-init RNG from the run seed (reproducible runs).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.rng = Rng::new(seed ^ 0x10A4);
        self
    }

    fn is_target(&self, param: usize, grad: &Matrix) -> bool {
        if self.explicit_targets {
            return self.targets.contains(&param);
        }
        grad.rows > 1 && grad.cols > 1 && grad.rows.min(grad.cols) > self.cfg.rank
    }

    /// Extra weight memory the adaptors introduce (Table 1 comparison).
    pub fn adaptor_bytes(&self) -> usize {
        self.adaptors.values().map(|a| a.adaptor_bytes()).sum()
    }
}

impl Optimizer for Lora {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        if !self.is_target(param, grad) {
            return self.full_rank.step(param, w, grad, lr);
        }
        let scale = self.cfg.scale();
        let rank = self.cfg.rank;
        let rng = &mut self.rng;
        let ad = self
            .adaptors
            .entry(param)
            .or_insert_with(|| AdaptorState::new(w, rank, rng));
        ad.update_factors(grad, lr, scale, &self.adam_cfg);
        ad.materialize_into(scale, w);
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.full_rank.state_bytes()
            + self.adaptors.values().map(|a| a.state_bytes()).sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "lora"
    }

    fn reset_state(&mut self) {
        self.adaptors.clear();
        self.full_rank.reset_state();
    }

    /// Checkpoint v2: adaptor-init RNG, the full-rank Adam for untargeted
    /// parameters, and every adaptor (base + factors + moments).
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        ser::put_rng(out, &self.rng);
        let mut fr = Vec::new();
        self.full_rank.save_state(&mut fr)?;
        ser::put_bytes(out, &fr);
        let mut params: Vec<usize> = self.adaptors.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in params {
            ser::put_usize(out, p);
            self.adaptors[&p].save_state(out);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.rng = r.rng()?;
        let fr = r.bytes()?;
        let mut frr = ser::Reader::new(fr);
        self.full_rank.load_state(&mut frr)?;
        frr.expect_end()?;
        self.adaptors.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let ad = AdaptorState::load_state(r)?;
            self.adaptors.insert(p, ad);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul;

    #[test]
    fn weight_stays_w0_plus_low_rank() {
        let mut rng = Rng::new(0);
        let mut lora = Lora::new(LoraConfig { rank: 2, alpha: 8.0 });
        let mut w = Matrix::randn(12, 16, 1.0, &mut rng);
        let w0 = w.clone();
        for s in 0..20 {
            let g = Matrix::randn(12, 16, 1.0, &mut rng.child(s));
            lora.step(0, &mut w, &g, 0.05).unwrap();
        }
        // ΔW must have rank <= 2.
        let mut dw = w.clone();
        dw.sub_assign(&w0);
        let svd = crate::linalg::svd_jacobi(&dw);
        assert!(svd.s[2] < 1e-4 * svd.s[0].max(1e-6), "rank leak: {:?}", &svd.s[..4]);
    }

    #[test]
    fn optimizer_state_is_2mr_plus_2nr() {
        let mut rng = Rng::new(1);
        let mut lora = Lora::new(LoraConfig { rank: 4, alpha: 32.0 });
        let mut w = Matrix::randn(16, 32, 1.0, &mut rng);
        let g = Matrix::ones(16, 32);
        lora.step(0, &mut w, &g, 0.01).unwrap();
        // Table 1: 2mr + 2nr floats.
        assert_eq!(lora.state_bytes(), 4 * (2 * 16 * 4 + 2 * 32 * 4));
        assert_eq!(lora.adaptor_bytes(), 4 * (16 * 4 + 4 * 32));
    }

    #[test]
    fn reduces_loss_on_low_rank_target() {
        // Target W* = W0 + rank-2 perturbation: LoRA can fit it.
        let mut rng = Rng::new(2);
        let w0 = Matrix::randn(10, 14, 1.0, &mut rng);
        let u = Matrix::randn(10, 2, 1.0, &mut rng);
        let v = Matrix::randn(2, 14, 1.0, &mut rng);
        let mut w_star = matmul(&u, &v);
        w_star.add_assign(&w0);
        let mut w = w0.clone();
        let mut lora = Lora::new(LoraConfig { rank: 2, alpha: 2.0 });
        let mut last = f32::MAX;
        let mut first = 0.0;
        for t in 0..200 {
            let mut g = w.clone();
            g.sub_assign(&w_star);
            let loss = g.frobenius_norm();
            if t == 0 {
                first = loss;
            }
            last = loss;
            lora.step(0, &mut w, &g, 0.05).unwrap();
        }
        assert!(last < 0.1 * first, "{first} -> {last}");
    }
}
