//! ReLoRA baseline (Lialin et al., 2024): LoRA whose adaptor is merged
//! into W₀ every `merge_every` steps, after which B/A and their optimizer
//! state restart. Evaluated without full-rank warmup, as in Table 2.

use super::lora::{AdaptorState, LoraConfig};
use crate::optim::{Adam, AdamConfig, Optimizer};
use crate::rng::Rng;
use crate::ser;
use crate::tensor::Matrix;
use std::collections::{HashMap, HashSet};

pub struct ReLora {
    pub cfg: LoraConfig,
    pub merge_every: u64,
    adam_cfg: AdamConfig,
    targets: HashSet<usize>,
    explicit_targets: bool,
    adaptors: HashMap<usize, AdaptorState>,
    steps: HashMap<usize, u64>,
    full_rank: Adam,
    rng: Rng,
}

impl ReLora {
    pub fn new(cfg: LoraConfig, merge_every: u64) -> Self {
        ReLora {
            cfg,
            merge_every,
            adam_cfg: AdamConfig::default(),
            targets: HashSet::new(),
            explicit_targets: false,
            adaptors: HashMap::new(),
            steps: HashMap::new(),
            full_rank: Adam::new(AdamConfig::default()),
            rng: Rng::new(0x4E10A4),
        }
    }

    pub fn with_targets(mut self, targets: impl IntoIterator<Item = usize>) -> Self {
        self.targets = targets.into_iter().collect();
        self.explicit_targets = true;
        self
    }

    /// Seed the adaptor-init RNG from the run seed (reproducible runs).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.rng = Rng::new(seed ^ 0x4E10A4);
        self
    }

    fn is_target(&self, param: usize, grad: &Matrix) -> bool {
        if self.explicit_targets {
            return self.targets.contains(&param);
        }
        grad.rows > 1 && grad.cols > 1 && grad.rows.min(grad.cols) > self.cfg.rank
    }
}

impl Optimizer for ReLora {
    fn step(&mut self, param: usize, w: &mut Matrix, grad: &Matrix, lr: f32)
        -> Result<(), String> {
        if !self.is_target(param, grad) {
            return self.full_rank.step(param, w, grad, lr);
        }
        let scale = self.cfg.scale();
        let rank = self.cfg.rank;
        let t = self.steps.entry(param).or_insert(0);
        *t += 1;
        let needs_merge = *t > 1 && (*t - 1) % self.merge_every == 0;
        let rng = &mut self.rng;
        if needs_merge || !self.adaptors.contains_key(&param) {
            if let Some(old) = self.adaptors.remove(&param) {
                // Merge: W0 <- W0 + s·BA (W already holds that value), then
                // restart the adaptor and its optimizer state.
                *w = old.materialize(scale);
            }
            self.adaptors.insert(param, AdaptorState::new(w, rank, rng));
        }
        let ad = self.adaptors.get_mut(&param).unwrap();
        ad.update_factors(grad, lr, scale, &self.adam_cfg);
        ad.materialize_into(scale, w);
        Ok(())
    }

    fn state_bytes(&self) -> usize {
        self.full_rank.state_bytes()
            + self.adaptors.values().map(|a| a.state_bytes()).sum::<usize>()
    }

    fn name(&self) -> &'static str {
        "relora"
    }

    fn reset_state(&mut self) {
        self.adaptors.clear();
        self.steps.clear();
        self.full_rank.reset_state();
    }

    /// Checkpoint v2: like LoRA plus the per-parameter step counters that
    /// drive the merge cadence, so a resumed run merges at the same steps.
    fn save_state(&self, out: &mut Vec<u8>) -> Result<(), String> {
        ser::put_rng(out, &self.rng);
        let mut fr = Vec::new();
        self.full_rank.save_state(&mut fr)?;
        ser::put_bytes(out, &fr);
        let mut params: Vec<usize> = self.steps.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in &params {
            ser::put_usize(out, *p);
            ser::put_u64(out, self.steps[p]);
        }
        let mut params: Vec<usize> = self.adaptors.keys().copied().collect();
        params.sort_unstable();
        ser::put_u32(out, params.len() as u32);
        for p in params {
            ser::put_usize(out, p);
            self.adaptors[&p].save_state(out);
        }
        Ok(())
    }

    fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        self.rng = r.rng()?;
        let fr = r.bytes()?;
        let mut frr = ser::Reader::new(fr);
        self.full_rank.load_state(&mut frr)?;
        frr.expect_end()?;
        self.steps.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let t = r.u64()?;
            self.steps.insert(p, t);
        }
        self.adaptors.clear();
        let n = r.u32()?;
        for _ in 0..n {
            let p = r.usize()?;
            let ad = AdaptorState::load_state(r)?;
            self.adaptors.insert(p, ad);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd_jacobi;

    #[test]
    fn accumulates_rank_beyond_r_after_merges() {
        // The whole point of ReLoRA: after k merges, ΔW can reach rank k·r.
        let mut rng = Rng::new(0);
        let mut relora = ReLora::new(LoraConfig { rank: 1, alpha: 1.0 }, 10);
        let mut w = Matrix::randn(12, 12, 1.0, &mut rng);
        let w0 = w.clone();
        for s in 0..60 {
            let g = Matrix::randn(12, 12, 1.0, &mut rng.child(s));
            relora.step(0, &mut w, &g, 0.05).unwrap();
        }
        let mut dw = w.clone();
        dw.sub_assign(&w0);
        let svd = svd_jacobi(&dw);
        // With 6 windows of rank-1 updates the effective rank exceeds 1.
        let effective = svd.s.iter().filter(|&&s| s > 1e-3 * svd.s[0]).count();
        assert!(effective >= 3, "effective rank {effective}, s={:?}", &svd.s[..6]);
    }

    #[test]
    fn merge_resets_optimizer_state() {
        let mut rng = Rng::new(1);
        let mut relora = ReLora::new(LoraConfig { rank: 2, alpha: 4.0 }, 5);
        let mut w = Matrix::randn(8, 8, 1.0, &mut rng);
        for s in 0..5 {
            let g = Matrix::randn(8, 8, 1.0, &mut rng.child(s));
            relora.step(0, &mut w, &g, 0.01).unwrap();
        }
        let before = relora.adaptors[&0].opt_b.t;
        assert_eq!(before, 5);
        let g = Matrix::randn(8, 8, 1.0, &mut rng.child(99));
        relora.step(0, &mut w, &g, 0.01).unwrap(); // step 6 triggers merge+reset
        assert_eq!(relora.adaptors[&0].opt_b.t, 1);
    }

    #[test]
    fn converges_on_full_rank_target() {
        // Unlike plain LoRA, ReLoRA can track a full-rank W* over time.
        let mut rng = Rng::new(2);
        let w_star = Matrix::randn(10, 10, 1.0, &mut rng);
        let mut w = Matrix::zeros(10, 10);
        let mut relora = ReLora::new(LoraConfig { rank: 2, alpha: 2.0 }, 25);
        let mut first = 0.0;
        let mut last = 0.0;
        for t in 0..500 {
            let mut g = w.clone();
            g.sub_assign(&w_star);
            let loss = g.frobenius_norm();
            if t == 0 {
                first = loss;
            }
            last = loss;
            relora.step(0, &mut w, &g, 0.05).unwrap();
        }
        assert!(last < 0.3 * first, "{first} -> {last}");
    }
}
