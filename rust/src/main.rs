//! `galore` — the training launcher.
//!
//! Subcommands:
//!   train    — run one training job (flags or --config file)
//!   serve    — resident multi-job daemon on a Unix-domain socket
//!   client   — talk to a running daemon (submit/status/pause/…)
//!   memory   — print the Fig. 1-style memory breakdown for a model/method
//!   info     — list model configs and available artifacts
//!   dp-smoke — exercise the multi-process DP socket ring without a trainer
//!   lint     — run the in-tree invariant analyzer over rust/src (CI gate)
//!
//! `train --dp-transport process` and `dp-smoke` respawn this binary for
//! worker ranks; a spawned worker is recognized by the rendezvous
//! environment variable and joins the host's ring instead of printing
//! banners.
//!
//! Examples:
//!   galore train --model micro --method galore --steps 200 --layerwise
//!   galore train --config configs/pretrain_micro.toml
//!   galore serve --max-jobs 3 --mem-budget-mb 2048
//!   galore client submit --task syn-cola --method galore --steps 400
//!   galore memory --model 7b --method galore8bit --rank 1024 --layerwise
//!   galore info

use anyhow::{anyhow, bail, Result};
use galore::config::{BackendKind, Cli, DpTransport, MethodKind, RunConfig, ServeConfig, TomlDoc};
use galore::coordinator::{train_data_parallel_resumable, Trainer};
use galore::memory::{estimate, fmt_gib, Method, TrainOpts};
use galore::model::{ModelConfig, WeightPrecision};
use galore::optim::{ProjectorQuant, RankScheduleKind};
use galore::runtime::{default_dir, Manifest};

const SWITCHES: &[&str] = &["layerwise", "fused", "dp-compress", "help"];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cli = Cli::from_env(SWITCHES).map_err(|e| anyhow!("{e}"))?;
    if cli.has("help") || cli.positional().is_empty() {
        usage();
        return Ok(());
    }
    match cli.positional()[0].as_str() {
        "train" => train(&cli),
        "serve" => serve(&cli),
        "client" => client(&cli),
        "memory" => memory(&cli),
        "info" => info(&cli),
        "dp-smoke" => dp_smoke(&cli),
        "lint" => lint(&cli),
        other => bail!(
            "unknown subcommand '{other}' \
             (train | serve | client | memory | info | dp-smoke | lint; try --help)"
        ),
    }
}

fn usage() {
    println!(
        "galore — GaLore training framework (Zhao et al., ICML 2024 reproduction)

USAGE:
  galore train  [--config FILE] [--model NAME] [--method NAME] [--steps N]
                [--batch N] [--lr F] [--rank N] [--update-freq N] [--scale F]
                [--rank-schedule fixed|decay|spectral] [--rank-floor N]
                [--rank-decay F] [--rank-energy F] [--refresh-gate-cos F]
                [--projector-quant f32|block8|dyn8|int4]
                [--seed N] [--eval-every N] [--eval-batches N]
                [--dp-workers N] [--dp-compress] [--dp-transport thread|process]
                [--dp-bucket-mb N] [--layerwise]
                [--weight-precision f32|bf16|int8] [--threads N]
                [--backend rust|artifact] [--fused] [--csv PATH]
                [--checkpoint PATH] [--checkpoint-every N]
                [--checkpoint-dir DIR] [--keep-last N] [--resume PATH]
                [--artifact-dir DIR]
  galore serve  [--config FILE] [--socket PATH] [--max-jobs N]
                [--mem-budget-mb N] [--slice-steps N] [--job-dir DIR]
  galore client submit (--config FILE | --task NAME [--model NAME]
                        [--method NAME] [--rank N] [--steps N])
                [--socket PATH]
  galore client (status|pause|resume|cancel) --id N [--socket PATH]
  galore client (list|shutdown) [--socket PATH]
  galore memory --model NAME [--method NAME] [--rank N] [--layerwise]
                [--token-batch N] [--weight-precision f32|bf16|int8]
                [--projector-quant f32|block8|dyn8|int4]
  galore info   [--artifact-dir DIR]
  galore dp-smoke [--world N] [--steps N] [--die-rank R --die-step S]
  galore lint   [PATH]   (default: rust/src; exits 1 with file:line
                diagnostics on any invariant violation)

METHODS: full-rank adamw adam8bit adafactor galore galore8bit
         galore-adafactor lora relora low-rank
MODELS:  nano micro mini small (trainable proxies) + 60m 130m 350m 1b 7b
         (paper shapes, memory estimation only)

Adaptive rank (galore methods): --rank-schedule decay|spectral lets each
layer shrink/grow its projector rank at subspace refreshes within
[--rank-floor, --rank]; --refresh-gate-cos T skips the refresh SVD when
the cached subspace still captures cosine >= T of the gradient.

Data parallelism: --dp-workers W trains W lockstep replicas with a ring
all-reduce; --dp-compress (GaLore methods) exchanges the projected r x n
gradient between subspace refreshes instead of the full m x n one — a
min(m,n)/r traffic cut per targeted layer. --dp-transport process runs
each replica in its own spawned worker process over a Unix-socket ring
(default: threads over in-memory channels); --dp-bucket-mb N overlaps
the all-reduce with backprop by reducing N-MiB gradient buckets as
layers finish (0 = reduce everything at the step barrier). Both knobs
leave the loss curve bit-identical. `galore dp-smoke` exercises the
multi-process ring without a trainer. See EXPERIMENTS.md
section 'DP communication'.

Precision/threads: --weight-precision bf16 keeps the master weight store
rounded to bfloat16 (f32 working tensors and accumulation — halves
accelerator weight bytes); --weight-precision int8 holds it block-
quantized at ~1 byte/el with stochastic rounding on commit, and
--projector-quant int4 packs the GaLore projection bases two elements
per byte (the full Q-GaLore recipe; all knobs are part of the resume
fingerprint, and int8 runs snapshot their rounding RNG in checkpoints);
--threads N sizes the worker pool behind the threaded kernels and the
cross-layer parallel optimizer step (default: GALORE_THREADS env var,
else all cores, capped at 16; results are bit-identical at any width).

Step backend: --backend artifact (alias --fused) runs the GaLore compact
update through the fused Pallas/HLO AOT kernels instead of the Rust tail
(method galore only; needs `make artifacts`). Composes with --dp-workers,
--dp-compress, rank schedules, the refresh gate, and checkpoints — see
EXPERIMENTS.md section 'Backend API'.

Checkpoint/resume: --checkpoint-every N writes a full-state (v2) snapshot
every N steps into --checkpoint-dir (retention --keep-last, 0 = keep all);
--resume PATH restores one and continues bit-exactly (same config
required); --checkpoint PATH writes a final full-state snapshot. See
EXPERIMENTS.md §Checkpoint/resume.

Serve: `galore serve` runs a resident daemon that schedules many jobs
over one process — round-robin --slice-steps step slices across up to
--max-jobs resident jobs, admission-controlled against --mem-budget-mb
(a job that doesn't fit waits in the queue; it is never OOM-admitted),
one shared artifact/engine cache across jobs with identical layer
shapes. Jobs pause/resume through full-state checkpoints in --job-dir
(bit-exact; a paused job costs disk, not RAM). `galore client` drives
the daemon over its --socket: submit a config file (add a [job] section
for name/workload) or a --task from the fine-tune roster, then
status/pause/resume/cancel/list/shutdown. [serve] keys in a --config
file set the same knobs. See EXPERIMENTS.md §Serve.

Artifacts: --artifact-dir DIR (or GALORE_ARTIFACTS/GALORE_ARTIFACT_DIR)
points the engine at an AOT artifact set other than ./artifacts.

Lint: `galore lint` runs the in-tree invariant analyzer (SAFETY comments
on unsafe sites, no unlisted panics on resident-process paths,
fingerprint coverage of every config field, checkpoint-section
symmetry) over rust/src and exits nonzero with file:line diagnostics on
any violation. CI runs it as a gate. See EXPERIMENTS.md
section 'Static analysis'."
    );
}

/// `lint`: the in-tree invariant analyzer (see `galore::analysis`).
fn lint(cli: &Cli) -> Result<()> {
    let root = cli.positional().get(1).map(std::path::PathBuf::from).unwrap_or_else(|| {
        // Default to the source tree whether invoked from the repo root
        // or from rust/.
        let repo_root = std::path::PathBuf::from("rust/src");
        if repo_root.is_dir() {
            repo_root
        } else {
            std::path::PathBuf::from("src")
        }
    });
    let diags = galore::analysis::run_lint(&root).map_err(|e| anyhow!(e))?;
    if diags.is_empty() {
        println!("lint: clean ({})", root.display());
        return Ok(());
    }
    for d in &diags {
        eprintln!("{d}");
    }
    bail!("lint: {} violation(s) under {}", diags.len(), root.display());
}

fn build_run_config(cli: &Cli) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = cli.get("config") {
        let doc = TomlDoc::load(path).map_err(|e| anyhow!(e))?;
        RunConfig::from_toml(&doc).map_err(|e| anyhow!(e))?
    } else {
        let model_name = cli.get("model").unwrap_or("micro");
        let model = ModelConfig::by_name(model_name)
            .ok_or_else(|| anyhow!("unknown model '{model_name}'"))?;
        let method = MethodKind::parse(cli.get("method").unwrap_or("galore"))
            .ok_or_else(|| anyhow!("unknown method"))?;
        RunConfig::new(model, method)
    };
    if let Some(v) = cli.get_parse::<usize>("steps").map_err(|e| anyhow!("{e}"))? {
        cfg.steps = v;
    }
    if let Some(v) = cli.get_parse::<usize>("batch").map_err(|e| anyhow!("{e}"))? {
        cfg.batch = v;
    }
    if let Some(v) = cli.get_parse::<f32>("lr").map_err(|e| anyhow!("{e}"))? {
        cfg.lr = v;
    }
    if let Some(v) = cli.get_parse::<usize>("rank").map_err(|e| anyhow!("{e}"))? {
        cfg.galore.rank = v;
        cfg.lowrank_rank = v;
        // A --rank override caps whatever floor the config carried (the
        // CLI rank wins; a run must stay launchable). Pass --rank-floor
        // explicitly to set the floor alongside the new rank.
        cfg.galore.rank_floor = cfg.galore.rank_floor.min(v).max(1);
    }
    if let Some(v) = cli.get_parse::<u64>("update-freq").map_err(|e| anyhow!("{e}"))? {
        cfg.galore.update_freq = v;
    }
    if let Some(v) = cli.get_parse::<f32>("scale").map_err(|e| anyhow!("{e}"))? {
        cfg.galore.scale = v;
    }
    if let Some(v) = cli.get("rank-schedule") {
        cfg.galore.rank_schedule = RankScheduleKind::parse(v)
            .ok_or_else(|| anyhow!("unknown --rank-schedule '{v}' (fixed|decay|spectral)"))?;
    }
    if let Some(v) = cli.get_parse::<usize>("rank-floor").map_err(|e| anyhow!("{e}"))? {
        cfg.galore.rank_floor = v;
    }
    if let Some(v) = cli.get_parse::<f32>("rank-decay").map_err(|e| anyhow!("{e}"))? {
        cfg.galore.rank_decay = v;
    }
    if let Some(v) = cli.get_parse::<f32>("rank-energy").map_err(|e| anyhow!("{e}"))? {
        cfg.galore.rank_energy = v;
    }
    if let Some(v) = cli.get_parse::<f32>("refresh-gate-cos").map_err(|e| anyhow!("{e}"))? {
        cfg.galore.refresh_gate_cos = v;
    }
    if let Some(v) = cli.get("projector-quant") {
        cfg.galore.projector_quant = ProjectorQuant::parse(v)
            .ok_or_else(|| anyhow!("unknown --projector-quant '{v}' (f32|block8|dyn8|int4)"))?;
    }
    if let Some(v) = cli.get_parse::<u64>("seed").map_err(|e| anyhow!("{e}"))? {
        cfg.seed = v;
    }
    if let Some(v) = cli.get_parse::<usize>("eval-every").map_err(|e| anyhow!("{e}"))? {
        cfg.eval_every = v;
    }
    if let Some(v) = cli.get_parse::<usize>("eval-batches").map_err(|e| anyhow!("{e}"))? {
        cfg.eval_batches = v;
    }
    if let Some(v) = cli.get_parse::<usize>("dp-workers").map_err(|e| anyhow!("{e}"))? {
        cfg.dp_workers = v;
    }
    if cli.has("dp-compress") {
        cfg.dp_compress = true;
    }
    if let Some(v) = cli.get("dp-transport") {
        cfg.dp_transport = DpTransport::parse(v)
            .ok_or_else(|| anyhow!("unknown --dp-transport '{v}' (thread|process)"))?;
    }
    if let Some(v) = cli.get_parse::<usize>("dp-bucket-mb").map_err(|e| anyhow!("{e}"))? {
        cfg.dp_bucket_mb = v;
    }
    if cli.has("layerwise") {
        cfg.layerwise = true;
    }
    if let Some(v) = cli.get("weight-precision") {
        cfg.weight_precision = WeightPrecision::parse(v)
            .ok_or_else(|| anyhow!("unknown --weight-precision '{v}' (f32|bf16|int8)"))?;
    }
    if let Some(v) = cli.get_parse::<usize>("threads").map_err(|e| anyhow!("{e}"))? {
        cfg.threads = v;
    }
    if let Some(v) = cli.get_parse::<usize>("checkpoint-every").map_err(|e| anyhow!("{e}"))? {
        cfg.checkpoint_every = v;
    }
    if let Some(v) = cli.get_parse::<usize>("keep-last").map_err(|e| anyhow!("{e}"))? {
        cfg.checkpoint_keep_last = v;
    }
    if let Some(v) = cli.get("checkpoint-dir") {
        cfg.checkpoint_dir = v.to_string();
    }
    if let Some(v) = cli.get("artifact-dir") {
        cfg.artifact_dir = v.to_string();
    }
    // Step backend: --backend NAME, with --fused kept as the historical
    // shorthand for --backend artifact. Contradictory spellings are an
    // error, not a silent override.
    if let Some(v) = cli.get("backend") {
        cfg.backend = BackendKind::parse(v)
            .ok_or_else(|| anyhow!("unknown --backend '{v}' (rust|artifact)"))?;
        if cli.has("fused") && cfg.backend != BackendKind::Artifact {
            bail!("--fused contradicts --backend {v}: drop one of the two flags");
        }
    }
    if cli.has("fused") {
        cfg.backend = BackendKind::Artifact;
    }
    // CLI overrides can reintroduce degenerate values (e.g. --update-freq
    // 0) after from_toml validated; re-check the final config.
    cfg.validate().map_err(|e| anyhow!(e))?;
    Ok(cfg)
}

fn train(cli: &Cli) -> Result<()> {
    let cfg = build_run_config(cli)?;
    let resume = cli.get("resume").map(std::path::PathBuf::from);
    // A spawned DP worker process (rank >= 1): the host re-executed this
    // binary with its own argv, so `cfg` is identical by construction.
    // Join the host's ring and run quietly — the host owns the console.
    if let Some(path) = std::env::var_os(galore::coordinator::transport::RENDEZVOUS_ENV) {
        return galore::coordinator::parallel::dp_process_child(
            &cfg,
            std::path::Path::new(&path),
            resume.as_deref(),
        );
    }
    println!(
        "train: model={} method={} backend={} steps={} batch={} lr={} rank={} T={} alpha={} \
         schedule={} quant={} gate={} layerwise={} dp={} dp_compress={} dp_transport={} \
         dp_bucket_mb={} wprec={} threads={}",
        cfg.model.name,
        cfg.method.label(),
        cfg.backend.label(),
        cfg.steps,
        cfg.batch,
        cfg.lr,
        cfg.galore.rank,
        cfg.galore.update_freq,
        cfg.galore.scale,
        cfg.galore.rank_schedule.label(),
        cfg.galore.projector_quant.label(),
        cfg.galore.refresh_gate_cos,
        cfg.layerwise,
        cfg.dp_workers,
        cfg.dp_compress,
        cfg.dp_transport.label(),
        cfg.dp_bucket_mb,
        cfg.weight_precision.label(),
        if cfg.threads > 0 { cfg.threads } else { galore::runtime::pool::default_threads() }
    );
    if cfg.dp_workers > 1 {
        // Backends compose with data parallelism: each worker's
        // `build_optimizer` stands up its own artifact engine when
        // `--backend artifact` (alias `--fused`) is set, and the compact
        // (`dp_compress`) entry runs the shared tail on either backend —
        // the old "--fused is not available with --dp-workers" restriction
        // is gone.
        let res = train_data_parallel_resumable(&cfg, resume.as_deref())?;
        println!(
            "done: train_loss={:.4} eval_loss={:.4} eval_ppl={:.2} tokens={} \
             optimizer_state={} comm={}/step elapsed={:.1}s",
            res.final_train_loss,
            res.final_eval_loss,
            res.final_eval_loss.exp(),
            res.total_tokens,
            fmt_gib(res.final_state_bytes as u64),
            fmt_gib(4 * res.comm_f32s_last_step),
            res.elapsed.as_secs_f64()
        );
        return Ok(());
    }
    let mut trainer = Trainer::from_config(cfg.clone())?;
    if cfg.backend == BackendKind::Artifact {
        println!("step backend: artifact (fused Pallas/HLO AOT kernels)");
    }
    if let Some(path) = &resume {
        trainer.restore_checkpoint(path)?;
        println!("resumed from {} at step {}", path.display(), trainer.step);
    }
    let log_every = (cfg.steps / 20).max(1);
    while trainer.step < cfg.steps {
        let step = trainer.step;
        let loss = trainer.train_step()?;
        if step % log_every == 0 || step + 1 == cfg.steps {
            println!(
                "step {:>6}/{} loss {:.4} lr {:.5} ({:.0} tok/s)",
                step + 1,
                cfg.steps,
                loss,
                trainer.schedule.at(step),
                trainer.metrics.tokens_per_sec()
            );
        }
        // The final eval is logged once, below — skip the in-loop row at
        // the last step (the old loop logged it twice when
        // steps % eval_every == 0).
        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 && step + 1 < cfg.steps {
            let l = trainer.eval(cfg.eval_batches)?;
            trainer.metrics.log_eval(step + 1, l);
            println!("  eval loss {:.4} ppl {:.2}", l, l.exp());
        }
        if cfg.checkpoint_every > 0 && trainer.step % cfg.checkpoint_every == 0 {
            trainer.save_periodic_checkpoint()?;
        }
    }
    let eval = trainer.eval(cfg.eval_batches)?;
    trainer.metrics.log_eval(cfg.steps, eval);
    println!(
        "final: eval_loss={:.4} eval_ppl={:.2} optimizer_state={} tok/s={:.0}",
        eval,
        eval.exp(),
        fmt_gib(trainer.optimizer_state_bytes() as u64),
        trainer.metrics.tokens_per_sec()
    );
    if cfg.galore.is_adaptive() {
        let profile = trainer.opt.rank_profile();
        if !profile.is_empty() {
            let ranks: Vec<String> =
                profile.iter().map(|&(p, r)| format!("{p}:{r}")).collect();
            println!("final per-layer ranks (param:rank): {}", ranks.join(" "));
        }
    }
    if cfg.galore.refresh_gate_cos > 0.0 {
        // One gate implementation across backends: `GaLore` counts skips
        // itself regardless of which substrate applies the update.
        let skips = trainer.opt.gate_skips();
        println!("lazy-refresh gate: {skips} SVD refreshes skipped");
    }
    if let Some(csv) = cli.get("csv") {
        let p = trainer.metrics.write_csv(csv)?;
        println!("wrote {}", p.display());
    }
    if let Some(ckpt) = cli.get("checkpoint") {
        trainer.save_checkpoint(ckpt)?;
        println!("wrote full-state checkpoint {ckpt}");
    }
    Ok(())
}

/// `serve`: run the resident multi-job daemon (see `galore::serve`).
fn serve(cli: &Cli) -> Result<()> {
    let mut cfg = if let Some(path) = cli.get("config") {
        let doc = TomlDoc::load(path).map_err(|e| anyhow!(e))?;
        ServeConfig::from_toml(&doc).map_err(|e| anyhow!(e))?
    } else {
        ServeConfig::default()
    };
    if let Some(v) = cli.get("socket") {
        cfg.socket_path = v.to_string();
    }
    if let Some(v) = cli.get_parse::<usize>("max-jobs").map_err(|e| anyhow!("{e}"))? {
        cfg.max_jobs = v;
    }
    if let Some(v) = cli.get_parse::<usize>("mem-budget-mb").map_err(|e| anyhow!("{e}"))? {
        cfg.mem_budget_mb = v;
    }
    if let Some(v) = cli.get_parse::<usize>("slice-steps").map_err(|e| anyhow!("{e}"))? {
        cfg.slice_steps = v;
    }
    if let Some(v) = cli.get("job-dir") {
        cfg.job_dir = v.to_string();
    }
    cfg.validate().map_err(|e| anyhow!(e))?;
    println!(
        "serve: socket={} max_jobs={} mem_budget={} slice_steps={} job_dir={}",
        cfg.socket_path,
        cfg.max_jobs,
        if cfg.mem_budget_mb > 0 { fmt_gib(cfg.budget_bytes()) } else { "unlimited".into() },
        cfg.slice_steps,
        cfg.job_dir
    );
    galore::serve::serve(cfg)
}

/// `client`: one verb against a running daemon's socket.
fn client(cli: &Cli) -> Result<()> {
    use galore::serve::{request, Request, Response};
    let default_socket = ServeConfig::default().socket_path;
    let socket = cli.get("socket").unwrap_or(&default_socket);
    let verb = cli
        .positional()
        .get(1)
        .map(String::as_str)
        .ok_or_else(|| anyhow!(
            "client needs a verb: submit | status | pause | resume | cancel | list | shutdown"
        ))?;
    let id = || -> Result<u64> {
        cli.get_parse::<u64>("id")
            .map_err(|e| anyhow!("{e}"))?
            .ok_or_else(|| anyhow!("'{verb}' needs --id N"))
    };
    let req = match verb {
        "submit" => {
            let payload = if let Some(path) = cli.get("config") {
                std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("cannot read submit config {path}: {e}"))?
            } else if let Some(name) = cli.get("task") {
                let task = galore::exp::finetune::Task::by_name(name).ok_or_else(|| {
                    anyhow!(
                        "unknown task '{name}' (roster: {})",
                        galore::exp::finetune::TASKS
                            .iter()
                            .map(|t| t.name)
                            .collect::<Vec<_>>()
                            .join(" ")
                    )
                })?;
                let method = MethodKind::parse(cli.get("method").unwrap_or("galore"))
                    .ok_or_else(|| anyhow!("unknown method"))?;
                let model = cli.get("model").unwrap_or("nano");
                let rank =
                    cli.get_parse::<usize>("rank").map_err(|e| anyhow!("{e}"))?.unwrap_or(4);
                let steps =
                    cli.get_parse::<usize>("steps").map_err(|e| anyhow!("{e}"))?.unwrap_or(100);
                task.submit_payload(model, method, rank, steps)
            } else {
                bail!("submit needs --config FILE or --task NAME");
            };
            Request::Submit { payload }
        }
        "status" => Request::Status { id: id()? },
        "pause" => Request::Pause { id: id()? },
        "resume" => Request::Resume { id: id()? },
        "cancel" => Request::Cancel { id: id()? },
        "list" => Request::List,
        "shutdown" => Request::Shutdown,
        other => bail!(
            "unknown client verb '{other}' \
             (submit | status | pause | resume | cancel | list | shutdown)"
        ),
    };
    match request(socket, &req)? {
        Response::Err(e) => bail!("daemon: {e}"),
        Response::Ok => println!("ok"),
        Response::Submitted { id } => println!("submitted job {id}"),
        Response::Job(info) => print_job_line(&info),
        Response::List { budget_bytes, resident_bytes, jobs } => {
            println!(
                "jobs: {} | budget: {} | resident: {}",
                jobs.len(),
                if budget_bytes > 0 { fmt_gib(budget_bytes) } else { "unlimited".into() },
                fmt_gib(resident_bytes)
            );
            for info in &jobs {
                print_job_line(info);
            }
        }
    }
    Ok(())
}

fn print_job_line(info: &galore::coordinator::JobInfo) {
    let loss = info.tail_loss.map(|l| format!("{l:.4}")).unwrap_or_else(|| "-".into());
    println!(
        "job {:>3} {:<16} {:<8} step {:>6}/{} tail_loss {} tokens {} est {}{}{}",
        info.id,
        info.name,
        info.state.label(),
        info.step,
        info.steps_total,
        loss,
        info.tokens,
        fmt_gib(info.est_bytes),
        if info.resident { " [resident]" } else { "" },
        info.error.as_ref().map(|e| format!(" error: {e}")).unwrap_or_default()
    );
}

/// `dp-smoke`: a trainer-free exercise of the multi-process socket ring.
/// The host spawns `--world - 1` worker processes of this binary, runs
/// `--steps` all-reduce rounds over a deterministic payload, and
/// bit-compares the checksums every rank reports. `--die-rank R
/// --die-step S` makes rank R exit(1) at step S — the dropout drill the
/// integration tests use to check that survivors error out (no hang) and
/// rank 0 names the failed worker.
fn dp_smoke(cli: &Cli) -> Result<()> {
    let steps =
        cli.get_parse::<usize>("steps").map_err(|e| anyhow!("{e}"))?.unwrap_or(5);
    // Spawned worker: argv is the host's argv, so the kill schedule
    // arrives through the same flags.
    if let Some(path) = std::env::var_os(galore::coordinator::transport::RENDEZVOUS_ENV) {
        let die_rank = cli.get_parse::<usize>("die-rank").map_err(|e| anyhow!("{e}"))?;
        let die_step = cli.get_parse::<usize>("die-step").map_err(|e| anyhow!("{e}"))?;
        let die = match (die_rank, die_step) {
            (Some(r), Some(s)) => Some((r, s)),
            (None, None) => None,
            _ => bail!("--die-rank and --die-step must be given together"),
        };
        return galore::coordinator::parallel::dp_smoke_child(
            std::path::Path::new(&path),
            steps,
            die,
        );
    }
    if let Some(r) = cli.get_parse::<usize>("die-rank").map_err(|e| anyhow!("{e}"))? {
        if r == 0 {
            bail!("--die-rank must be >= 1 (rank 0 is the reporting host)");
        }
        if cli.get("die-step").is_none() {
            bail!("--die-rank and --die-step must be given together");
        }
    } else if cli.get("die-step").is_some() {
        bail!("--die-rank and --die-step must be given together");
    }
    let world = cli.get_parse::<usize>("world").map_err(|e| anyhow!("{e}"))?.unwrap_or(2);
    galore::coordinator::parallel::dp_smoke_host(world, steps)
}

fn memory(cli: &Cli) -> Result<()> {
    let model_name = cli.get("model").unwrap_or("7b");
    let model = ModelConfig::by_name(model_name)
        .ok_or_else(|| anyhow!("unknown model '{model_name}'"))?;
    let rank = cli
        .get_parse::<usize>("rank")
        .map_err(|e| anyhow!("{e}"))?
        .unwrap_or_else(|| model.default_rank());
    // One method vocabulary: the same `MethodKind::parse` the trainer
    // uses, then the single `Method::for_kind` conversion — the estimator
    // cannot drift from the trainer about what a method string means (the
    // old hand-rolled match here silently lacked `adamw`,
    // `galore-adafactor`, and the alias spellings).
    let method_str = cli.get("method").unwrap_or("galore8bit");
    let kind = MethodKind::parse(method_str)
        .ok_or_else(|| anyhow!("unknown method '{method_str}' (see METHODS in --help)"))?;
    let method = Method::for_kind(kind, rank);
    let wprec = match cli.get("weight-precision") {
        Some(v) => Some(WeightPrecision::parse(v).ok_or_else(|| {
            anyhow!("unknown --weight-precision '{v}' (f32|bf16|int8)")
        })?),
        None => None,
    };
    let pquant = match cli.get("projector-quant") {
        Some(v) => Some(ProjectorQuant::parse(v).ok_or_else(|| {
            anyhow!("unknown --projector-quant '{v}' (f32|block8|dyn8|int4)")
        })?),
        None => None,
    };
    let opts = TrainOpts {
        layerwise_updates: cli.has("layerwise"),
        activation_checkpoint: false,
        token_batch: cli
            .get_parse::<usize>("token-batch")
            .map_err(|e| anyhow!("{e}"))?
            .unwrap_or(256),
        weight_precision: wprec,
        projector_quant: pquant,
    };
    let b = estimate(model, method, opts);
    println!(
        "memory breakdown: {} / {} (token batch {})",
        model.name,
        method.label(),
        opts.token_batch
    );
    println!("  weights:          {}", fmt_gib(b.weights));
    println!("  optimizer states: {}", fmt_gib(b.optim_states));
    println!("  weight gradients: {}", fmt_gib(b.gradients));
    println!("  activations:      {}", fmt_gib(b.activations));
    println!("  TOTAL:            {}", fmt_gib(b.total()));
    // Master weight-store bytes at each supported precision (the new
    // closed forms) — the bf16/int8 stores' savings used to be invisible
    // here. The breakdown above prices weights per --weight-precision
    // (default: the paper's BF16 accounting).
    let store = |p| {
        estimate(model, method, TrainOpts { weight_precision: Some(p), ..opts }).weights
    };
    println!(
        "  weight store:     f32 {} | bf16 {} | int8 {}{}",
        fmt_gib(store(WeightPrecision::F32)),
        fmt_gib(store(WeightPrecision::Bf16)),
        fmt_gib(store(WeightPrecision::Int8)),
        match wprec {
            Some(p) => format!("  (active: {})", p.label()),
            None => String::new(),
        }
    );
    Ok(())
}

fn info(cli: &Cli) -> Result<()> {
    println!("model configs:");
    for c in ModelConfig::all() {
        println!(
            "  {:>6}: dim={} inter={} heads={} layers={} vocab={} seq={} (~{:.1}M params)",
            c.name,
            c.dim,
            c.intermediate,
            c.heads,
            c.layers,
            c.vocab,
            c.seq,
            c.n_params() as f64 / 1e6
        );
    }
    // --artifact-dir beats the GALORE_ARTIFACTS/GALORE_ARTIFACT_DIR env
    // override built into `default_dir`.
    let dir = cli.get("artifact-dir").map(std::path::PathBuf::from).unwrap_or_else(default_dir);
    match Manifest::load(dir) {
        Ok(m) => {
            println!("\nartifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!("  {:<32} kind={:<12} outputs={}", a.name, a.kind, a.n_outputs);
            }
        }
        Err(e) => println!("\nno artifacts: {e}"),
    }
    Ok(())
}
