//! Byte-level tokenizer for tiny real-text runs (vocab 256). Lets the
//! quickstart train on an embedded corpus without any external vocabulary,
//! and gives the fine-tune experiments a second, non-synthetic domain.

pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// A small embedded corpus (public-domain style prose assembled for the
/// repo) so byte-level runs have real, non-synthetic structure.
pub const EMBEDDED_CORPUS: &str = "\
the gradient of a deep network is not an arbitrary matrix. during training \
it acquires structure: directions of large curvature dominate, and the \
spectrum decays. galore exploits exactly this. rather than constraining \
the weights to a low rank subspace, it projects the gradient into the \
leading singular subspace, runs the optimizer in that compact space, and \
expands the update back. the weights remain full rank; only the optimizer \
states shrink. every few hundred steps the subspace is recomputed from a \
fresh gradient, so over the course of training the updates sweep through a \
sequence of subspaces and the composition recovers full parameter learning. \
the memory saved is the point: adam keeps two statistics per parameter, so \
for a seven billion parameter model the states alone dwarf the weights. \
projecting them to rank r divides that cost by the ratio of the dimension \
to r. with eight bit quantization of the compact statistics the optimizer \
nearly vanishes from the memory budget, and a consumer graphics card can \
pretrain a model that previously demanded a server. none of this requires \
changing the architecture, the loss, or the data: it is a property of the \
training dynamics, available to any stateful optimizer that is willing to \
look at its gradients a little more carefully than usual. ";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "hello galore 123!";
        assert_eq!(ByteTokenizer::decode(&ByteTokenizer::encode(s)), s);
    }

    #[test]
    fn tokens_in_range() {
        let toks = ByteTokenizer::encode(EMBEDDED_CORPUS);
        assert!(toks.iter().all(|&t| (0..256).contains(&t)));
        assert!(toks.len() > 1000);
    }
}
