//! Batched next-token data loader.
//!
//! Streams (tokens, targets) batches of static shape (batch, seq) — the
//! shape the AOT training artifact was lowered for. Two sources:
//! fresh-shard synthetic data (pre-training; never repeats) or a fixed
//! token buffer cycled with a shuffled window order (fine-tuning epochs).
//!
//! Both sources reserve genuinely held-out evaluation data: synthetic
//! sources use a shard range training never mints, fixed sources a tail
//! slice of windows that is excluded from the shuffled training order
//! *and* separated by a `seq`-token gap, so no training window shares even
//! one token with the eval tail.

use super::SyntheticCorpus;
use crate::rng::Rng;
use crate::ser;

/// One training batch: row-major (batch, seq) token ids and their
/// next-token targets.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

impl Batch {
    pub fn n_tokens(&self) -> usize {
        self.batch * self.seq
    }
}

enum Source {
    Synthetic {
        corpus: SyntheticCorpus,
        next_shard: u64,
    },
    Fixed {
        data: Vec<i32>,
        /// Shuffled *training* window starts — never reaches `eval_start`.
        order: Vec<usize>,
        cursor: usize,
        rng: Rng,
        /// First window start of the held-out eval tail.
        eval_start: usize,
        /// Number of eval windows in the tail.
        n_eval: usize,
    },
}

pub struct DataLoader {
    batch: usize,
    seq: usize,
    source: Source,
}

impl DataLoader {
    /// Never-repeating synthetic stream (pre-training).
    pub fn synthetic(corpus: SyntheticCorpus, batch: usize, seq: usize) -> Self {
        DataLoader { batch, seq, source: Source::Synthetic { corpus, next_shard: 0 } }
    }

    /// Fixed-buffer loader (fine-tuning / eval) over windows of `seq`+1.
    /// The last ~10% of windows (at least one) are reserved as a held-out
    /// eval tail; training windows additionally stop `seq` starts earlier,
    /// so training and eval are disjoint at the *token* level, not just by
    /// window index.
    pub fn fixed(data: Vec<i32>, batch: usize, seq: usize, seed: u64) -> Self {
        assert!(data.len() > seq + 1, "corpus shorter than one window");
        let n_windows = data.len() - seq - 1;
        let n_eval = (n_windows / 10).max(1);
        let eval_start = n_windows - n_eval;
        let n_train = eval_start.saturating_sub(seq);
        assert!(
            n_train >= 1,
            "fixed corpus too short to reserve a held-out eval tail: \
             {n_windows} windows of seq {seq} leave no training windows"
        );
        let mut order: Vec<usize> = (0..n_train).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut order);
        DataLoader {
            batch,
            seq,
            source: Source::Fixed { data, order, cursor: 0, rng, eval_start, n_eval },
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    /// Produce the next batch. Infinite iterator: synthetic sources mint
    /// new shards, fixed sources reshuffle each epoch.
    pub fn next_batch(&mut self) -> Batch {
        let (b, s) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        match &mut self.source {
            Source::Synthetic { corpus, next_shard } => {
                for _ in 0..b {
                    let row = corpus.shard(*next_shard, s + 1);
                    *next_shard += 1;
                    tokens.extend_from_slice(&row[..s]);
                    targets.extend_from_slice(&row[1..]);
                }
            }
            Source::Fixed { data, order, cursor, rng, .. } => {
                for _ in 0..b {
                    if *cursor >= order.len() {
                        rng.shuffle(order);
                        *cursor = 0;
                    }
                    let start = order[*cursor];
                    *cursor += 1;
                    tokens.extend_from_slice(&data[start..start + s]);
                    targets.extend_from_slice(&data[start + 1..start + s + 1]);
                }
            }
        }
        Batch { batch: b, seq: s, tokens, targets }
    }

    /// A held-out evaluation batch that training never sees: synthetic
    /// sources use a reserved shard range, fixed sources the reserved tail
    /// windows (disjoint from every training window's tokens).
    pub fn eval_batch(&self, index: u64) -> Batch {
        let (b, s) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        match &self.source {
            Source::Synthetic { corpus, .. } => {
                for i in 0..b {
                    // Shards >= 2^40 are reserved for eval.
                    let shard = (1u64 << 40) + index * b as u64 + i as u64;
                    let row = corpus.shard(shard, s + 1);
                    tokens.extend_from_slice(&row[..s]);
                    targets.extend_from_slice(&row[1..]);
                }
            }
            Source::Fixed { data, eval_start, n_eval, .. } => {
                for i in 0..b {
                    // Walk the tail directly — a fancier stride (the old
                    // `* 97`) collapses to one window whenever the factor
                    // divides n_eval.
                    let start = *eval_start + (index as usize * b + i) % *n_eval;
                    tokens.extend_from_slice(&data[start..start + s]);
                    targets.extend_from_slice(&data[start + 1..start + s + 1]);
                }
            }
        }
        Batch { batch: b, seq: s, tokens, targets }
    }

    /// Checkpoint v2: the loader's *position* — the synthetic shard
    /// counter, or the fixed source's shuffled order + cursor + shuffle
    /// RNG. The corpus/data themselves are reconstructed from the run
    /// config, so the blob stays small.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        match &self.source {
            Source::Synthetic { next_shard, .. } => {
                ser::put_u8(out, 0);
                ser::put_u64(out, *next_shard);
            }
            Source::Fixed { order, cursor, rng, .. } => {
                ser::put_u8(out, 1);
                ser::put_u64(out, *cursor as u64);
                ser::put_u64(out, order.len() as u64);
                for &w in order {
                    ser::put_u64(out, w as u64);
                }
                ser::put_rng(out, rng);
            }
        }
    }

    /// Restore a position saved by [`DataLoader::save_state`] into a
    /// loader built from the same config. Errors on a source-kind or
    /// window-count mismatch (different corpus/seq than the checkpoint).
    pub fn load_state(&mut self, r: &mut ser::Reader<'_>) -> Result<(), String> {
        let tag = r.u8()?;
        match (&mut self.source, tag) {
            (Source::Synthetic { next_shard, .. }, 0) => {
                *next_shard = r.u64()?;
                Ok(())
            }
            (Source::Fixed { order, cursor, rng, .. }, 1) => {
                let cur = r.u64()? as usize;
                let n = r.u64()? as usize;
                if n != order.len() {
                    return Err(format!(
                        "fixed loader has {} training windows, checkpoint has {n} \
                         (different corpus or seq)",
                        order.len()
                    ));
                }
                if cur > n {
                    return Err(format!("loader cursor {cur} beyond {n} windows"));
                }
                let limit = order.len();
                for w in order.iter_mut() {
                    let v = r.u64()? as usize;
                    if v >= limit {
                        return Err(format!("window start {v} outside training range {limit}"));
                    }
                    *w = v;
                }
                *cursor = cur;
                *rng = r.rng()?;
                Ok(())
            }
            (_, t) => Err(format!(
                "loader source kind mismatch: checkpoint tag {t} does not match this run's \
                 data source"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_batches_never_repeat() {
        let mut dl = DataLoader::synthetic(SyntheticCorpus::new(128, 0), 2, 16);
        let b1 = dl.next_batch();
        let b2 = dl.next_batch();
        assert_ne!(b1.tokens, b2.tokens);
        assert_eq!(b1.tokens.len(), 32);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut dl = DataLoader::synthetic(SyntheticCorpus::new(128, 0), 1, 8);
        let b = dl.next_batch();
        // target[i] is the token that followed tokens[i] in the stream:
        // consistency check via a regenerated shard.
        let c = SyntheticCorpus::new(128, 0);
        let row = c.shard(0, 9);
        assert_eq!(b.tokens, row[..8].to_vec());
        assert_eq!(b.targets, row[1..9].to_vec());
    }

    #[test]
    fn fixed_loader_cycles_with_reshuffle() {
        let data: Vec<i32> = (0..50).collect();
        let mut dl = DataLoader::fixed(data, 4, 8, 3);
        let mut seen = Vec::new();
        for _ in 0..30 {
            let b = dl.next_batch();
            assert_eq!(b.tokens.len(), 32);
            // windows must be contiguous runs
            for r in 0..4 {
                let row = &b.tokens[r * 8..(r + 1) * 8];
                for w in row.windows(2) {
                    assert_eq!(w[1], w[0] + 1);
                }
            }
            seen.push(b);
        }
    }

    #[test]
    fn eval_batches_disjoint_from_training_shards() {
        let dl = DataLoader::synthetic(SyntheticCorpus::new(128, 0), 2, 16);
        let e0 = dl.eval_batch(0);
        let e0b = dl.eval_batch(0);
        let e1 = dl.eval_batch(1);
        assert_eq!(e0.tokens, e0b.tokens, "eval must be deterministic");
        assert_ne!(e0.tokens, e1.tokens);
    }

    #[test]
    fn fixed_eval_tail_is_token_disjoint_from_training() {
        // Ramp data: a token's value IS its position, so disjointness of
        // token values proves disjointness of the underlying slices. This
        // pins the fix for the old eval path, which strode over *all*
        // windows and so evaluated on training data.
        let data: Vec<i32> = (0..400).collect();
        let mut dl = DataLoader::fixed(data, 4, 8, 7);
        let mut max_train_token = i32::MIN;
        // Several epochs so every training window is visited.
        for _ in 0..300 {
            let b = dl.next_batch();
            max_train_token = max_train_token.max(*b.targets.iter().max().unwrap());
        }
        let mut min_eval_token = i32::MAX;
        for i in 0..64 {
            let e = dl.eval_batch(i);
            min_eval_token = min_eval_token.min(*e.tokens.iter().min().unwrap());
        }
        assert!(
            max_train_token < min_eval_token,
            "training tokens reach {max_train_token}, eval tail starts at {min_eval_token}"
        );
    }

    #[test]
    fn fixed_eval_batches_are_deterministic_and_vary() {
        let data: Vec<i32> = (0..400).collect();
        let dl = DataLoader::fixed(data, 4, 8, 7);
        assert_eq!(dl.eval_batch(0).tokens, dl.eval_batch(0).tokens);
        assert_ne!(dl.eval_batch(0).tokens, dl.eval_batch(1).tokens);
    }

    #[test]
    fn synthetic_state_roundtrip_resumes_stream() {
        let mut a = DataLoader::synthetic(SyntheticCorpus::new(128, 5), 2, 16);
        for _ in 0..7 {
            a.next_batch();
        }
        let mut blob = Vec::new();
        a.save_state(&mut blob);
        let mut b = DataLoader::synthetic(SyntheticCorpus::new(128, 5), 2, 16);
        let mut r = crate::ser::Reader::new(&blob);
        b.load_state(&mut r).unwrap();
        r.expect_end().unwrap();
        for _ in 0..5 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn fixed_state_roundtrip_resumes_mid_epoch() {
        let data: Vec<i32> = (0..400).collect();
        let mut a = DataLoader::fixed(data.clone(), 4, 8, 11);
        for _ in 0..13 {
            a.next_batch();
        }
        let mut blob = Vec::new();
        a.save_state(&mut blob);
        let mut b = DataLoader::fixed(data, 4, 8, 11);
        let mut r = crate::ser::Reader::new(&blob);
        b.load_state(&mut r).unwrap();
        r.expect_end().unwrap();
        // Identical through the epoch boundary (same reshuffle RNG state).
        for _ in 0..200 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn state_kind_mismatch_is_rejected() {
        let mut syn = DataLoader::synthetic(SyntheticCorpus::new(128, 0), 2, 16);
        let mut blob = Vec::new();
        syn.save_state(&mut blob);
        let data: Vec<i32> = (0..400).collect();
        let mut fixed = DataLoader::fixed(data, 2, 16, 0);
        let mut r = crate::ser::Reader::new(&blob);
        assert!(fixed.load_state(&mut r).is_err());
        let mut blob2 = Vec::new();
        fixed.save_state(&mut blob2);
        let mut r2 = crate::ser::Reader::new(&blob2);
        assert!(syn.load_state(&mut r2).is_err());
    }
}
