//! Batched next-token data loader.
//!
//! Streams (tokens, targets) batches of static shape (batch, seq) — the
//! shape the AOT training artifact was lowered for. Two sources:
//! fresh-shard synthetic data (pre-training; never repeats) or a fixed
//! token buffer cycled with a shuffled window order (fine-tuning epochs).

use super::SyntheticCorpus;
use crate::rng::Rng;

/// One training batch: row-major (batch, seq) token ids and their
/// next-token targets.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

impl Batch {
    pub fn n_tokens(&self) -> usize {
        self.batch * self.seq
    }
}

enum Source {
    Synthetic { corpus: SyntheticCorpus, next_shard: u64 },
    Fixed { data: Vec<i32>, order: Vec<usize>, cursor: usize, rng: Rng },
}

pub struct DataLoader {
    batch: usize,
    seq: usize,
    source: Source,
}

impl DataLoader {
    /// Never-repeating synthetic stream (pre-training).
    pub fn synthetic(corpus: SyntheticCorpus, batch: usize, seq: usize) -> Self {
        DataLoader { batch, seq, source: Source::Synthetic { corpus, next_shard: 0 } }
    }

    /// Fixed-buffer loader (fine-tuning / eval) over windows of `seq`+1.
    pub fn fixed(data: Vec<i32>, batch: usize, seq: usize, seed: u64) -> Self {
        assert!(data.len() > seq + 1, "corpus shorter than one window");
        let n_windows = data.len() - seq - 1;
        let mut order: Vec<usize> = (0..n_windows).collect();
        let mut rng = Rng::new(seed);
        rng.shuffle(&mut order);
        DataLoader { batch, seq, source: Source::Fixed { data, order, cursor: 0, rng } }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    /// Produce the next batch. Infinite iterator: synthetic sources mint
    /// new shards, fixed sources reshuffle each epoch.
    pub fn next_batch(&mut self) -> Batch {
        let (b, s) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        match &mut self.source {
            Source::Synthetic { corpus, next_shard } => {
                for _ in 0..b {
                    let row = corpus.shard(*next_shard, s + 1);
                    *next_shard += 1;
                    tokens.extend_from_slice(&row[..s]);
                    targets.extend_from_slice(&row[1..]);
                }
            }
            Source::Fixed { data, order, cursor, rng } => {
                for _ in 0..b {
                    if *cursor >= order.len() {
                        rng.shuffle(order);
                        *cursor = 0;
                    }
                    let start = order[*cursor];
                    *cursor += 1;
                    tokens.extend_from_slice(&data[start..start + s]);
                    targets.extend_from_slice(&data[start + 1..start + s + 1]);
                }
            }
        }
        Batch { batch: b, seq: s, tokens, targets }
    }

    /// A held-out evaluation batch that training never sees: synthetic
    /// sources use a reserved shard range, fixed sources the tail windows.
    pub fn eval_batch(&self, index: u64) -> Batch {
        let (b, s) = (self.batch, self.seq);
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        match &self.source {
            Source::Synthetic { corpus, .. } => {
                for i in 0..b {
                    // Shards >= 2^40 are reserved for eval.
                    let shard = (1u64 << 40) + index * b as u64 + i as u64;
                    let row = corpus.shard(shard, s + 1);
                    tokens.extend_from_slice(&row[..s]);
                    targets.extend_from_slice(&row[1..]);
                }
            }
            Source::Fixed { data, .. } => {
                let n_windows = data.len() - s - 1;
                for i in 0..b {
                    let start = ((index as usize * b + i) * 97) % n_windows;
                    tokens.extend_from_slice(&data[start..start + s]);
                    targets.extend_from_slice(&data[start + 1..start + s + 1]);
                }
            }
        }
        Batch { batch: b, seq: s, tokens, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_batches_never_repeat() {
        let mut dl = DataLoader::synthetic(SyntheticCorpus::new(128, 0), 2, 16);
        let b1 = dl.next_batch();
        let b2 = dl.next_batch();
        assert_ne!(b1.tokens, b2.tokens);
        assert_eq!(b1.tokens.len(), 32);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut dl = DataLoader::synthetic(SyntheticCorpus::new(128, 0), 1, 8);
        let b = dl.next_batch();
        // target[i] is the token that followed tokens[i] in the stream:
        // consistency check via a regenerated shard.
        let c = SyntheticCorpus::new(128, 0);
        let row = c.shard(0, 9);
        assert_eq!(b.tokens, row[..8].to_vec());
        assert_eq!(b.targets, row[1..9].to_vec());
    }

    #[test]
    fn fixed_loader_cycles_with_reshuffle() {
        let data: Vec<i32> = (0..50).collect();
        let mut dl = DataLoader::fixed(data, 4, 8, 3);
        let mut seen = Vec::new();
        for _ in 0..30 {
            let b = dl.next_batch();
            assert_eq!(b.tokens.len(), 32);
            // windows must be contiguous runs
            for r in 0..4 {
                let row = &b.tokens[r * 8..(r + 1) * 8];
                for w in row.windows(2) {
                    assert_eq!(w[1], w[0] + 1);
                }
            }
            seen.push(b);
        }
    }

    #[test]
    fn eval_batches_disjoint_from_training_shards() {
        let dl = DataLoader::synthetic(SyntheticCorpus::new(128, 0), 2, 16);
        let e0 = dl.eval_batch(0);
        let e0b = dl.eval_batch(0);
        let e1 = dl.eval_batch(1);
        assert_eq!(e0.tokens, e0b.tokens, "eval must be deterministic");
        assert_ne!(e0.tokens, e1.tokens);
    }
}
