//! Zipfian Markov-chain corpus: the offline stand-in for C4.
//!
//! Construction: each token's successor distribution mixes
//!   * a global Zipf(α) unigram draw (weight 1 − p_bi), and
//!   * a per-token deterministic-ish bigram table of `fanout` preferred
//!     successors (weight p_bi),
//! giving text-like statistics: heavy-tailed frequencies, learnable local
//! structure (so the loss falls well below the unigram entropy), and
//! enough entropy that models can't memorize it at our training sizes.

use crate::rng::{Rng, Zipf};

pub struct SyntheticCorpus {
    vocab: usize,
    zipf: Zipf,
    /// Preferred successors per token: (vocab, fanout), derived from seed.
    bigram: Vec<u32>,
    fanout: usize,
    /// Probability of following the bigram table.
    p_bigram: f64,
    seed: u64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self::with_params(vocab, seed, 4, 0.65, 1.05)
    }

    pub fn with_params(vocab: usize, seed: u64, fanout: usize, p_bigram: f64, alpha: f64) -> Self {
        assert!(vocab >= 4);
        let mut rng = Rng::new(seed ^ 0xC4C4_C4C4);
        let zipf = Zipf::new(vocab, alpha);
        // Preferred successors are themselves Zipf-distributed so frequent
        // tokens chain into frequent tokens (like function words).
        let mut bigram = Vec::with_capacity(vocab * fanout);
        for _ in 0..vocab {
            for _ in 0..fanout {
                bigram.push(zipf.sample(&mut rng) as u32);
            }
        }
        SyntheticCorpus { vocab, zipf, bigram, fanout, p_bigram, seed }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Generate shard `shard` of length `len` tokens. Deterministic in
    /// (corpus seed, shard); distinct shards are fresh data (no repetition).
    pub fn shard(&self, shard: u64, len: usize) -> Vec<i32> {
        let mut rng = Rng::new(self.seed).child(0x5AD ^ shard);
        let mut out = Vec::with_capacity(len);
        let mut prev = self.zipf.sample(&mut rng);
        out.push(prev as i32);
        while out.len() < len {
            let next = if rng.next_f64() < self.p_bigram {
                self.bigram[prev * self.fanout + rng.below(self.fanout)] as usize
            } else {
                self.zipf.sample(&mut rng)
            };
            out.push(next as i32);
            prev = next;
        }
        out
    }

    /// Upper bound on achievable cross-entropy: the unigram entropy of the
    /// Zipf marginal (a model with no context beats this via the bigram
    /// structure). Used by tests as a sanity line.
    pub fn unigram_entropy(&self) -> f64 {
        // Estimate from a long sample.
        let sample = self.shard(u64::MAX, 200_000);
        let mut counts = vec![0usize; self.vocab];
        for &t in &sample {
            counts[t as usize] += 1;
        }
        let n = sample.len() as f64;
        -counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                p * p.ln()
            })
            .sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_shard() {
        let c = SyntheticCorpus::new(512, 7);
        assert_eq!(c.shard(3, 1000), c.shard(3, 1000));
        assert_ne!(c.shard(3, 1000), c.shard(4, 1000));
    }

    #[test]
    fn tokens_in_vocab() {
        let c = SyntheticCorpus::new(100, 0);
        assert!(c.shard(0, 10_000).iter().all(|&t| (0..100).contains(&t)));
    }

    #[test]
    fn heavy_tailed_unigrams() {
        let c = SyntheticCorpus::new(256, 1);
        let toks = c.shard(0, 100_000);
        let mut counts = vec![0usize; 256];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Top token much more frequent than median token.
        assert!(counts[0] > 10 * counts[128].max(1));
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // Conditional entropy H(next | prev) must be clearly below the
        // unigram entropy H(next) — that's the signal models learn.
        let c = SyntheticCorpus::new(64, 2);
        let toks = c.shard(0, 300_000);
        let mut uni = vec![0f64; 64];
        let mut bi = vec![0f64; 64 * 64];
        for w in toks.windows(2) {
            uni[w[0] as usize] += 1.0;
            bi[w[0] as usize * 64 + w[1] as usize] += 1.0;
        }
        let n: f64 = uni.iter().sum();
        let h_uni: f64 = -uni
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| (c / n) * (c / n).ln())
            .sum::<f64>();
        let mut h_cond = 0.0;
        for p in 0..64 {
            let row_n: f64 = bi[p * 64..(p + 1) * 64].iter().sum();
            if row_n == 0.0 {
                continue;
            }
            let h_row: f64 = -bi[p * 64..(p + 1) * 64]
                .iter()
                .filter(|&&c| c > 0.0)
                .map(|&c| (c / row_n) * (c / row_n).ln())
                .sum::<f64>();
            h_cond += (row_n / n) * h_row;
        }
        assert!(
            h_cond < 0.8 * h_uni,
            "conditional entropy {h_cond} not « unigram {h_uni}"
        );
    }
}
