//! Data pipeline: the synthetic-C4 corpus substitute, a byte-level
//! tokenizer for tiny-corpus runs, and the batched loader the coordinator
//! streams from.
//!
//! C4 is unavailable offline; `SyntheticCorpus` generates a Zipfian
//! Markov-chain token process (heavy-tailed unigram frequencies + sparse
//! learnable bigram structure) that is non-trivially predictable — exactly
//! what the optimizer comparisons need (DESIGN.md §4). Data is generated
//! in shards on the fly, never repeated (matching the paper's "without
//! data repetition" protocol), and fully determined by (seed, shard).

mod loader;
mod synthetic;
mod tokenizer;

pub use loader::{Batch, DataLoader};
pub use synthetic::SyntheticCorpus;
pub use tokenizer::{ByteTokenizer, EMBEDDED_CORPUS};
