//! Singular value decomposition: one-sided Jacobi (small/accurate) and
//! randomized truncated SVD (the production projector refresh).

use super::qr::{qr_with, QrScratch};
use crate::rng::Rng;
use crate::tensor::{matmul, matmul_a_bt_into, matmul_at_b, matmul_at_b_into, matmul_into, Matrix};

/// Thin SVD result: `a ≈ u @ diag(s) @ vt` with `u` (m, k), `s` (k),
/// `vt` (k, n), singular values descending.
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub vt: Matrix,
}

/// One-sided Jacobi SVD (Hestenes): orthogonalize the columns of A by plane
/// rotations; accurate for small matrices (we use it on the (r+p)-wide
/// sketch produced by `randomized_svd`). Requires m >= n; callers with
/// m < n should factor the transpose.
pub fn svd_jacobi(a: &Matrix) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        // SVD(A^T) = (V, S, U^T) -> swap factors.
        let t = svd_jacobi(&a.transpose());
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }
    let mut u = a.clone(); // will hold U * diag(s) columns
    let mut v = Matrix::eye(n);
    let max_sweeps = 60;
    let tol = 1e-12f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram entries for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let up = u.at(i, p) as f64;
                    let uq = u.at(i, q) as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off += apq * apq;
                if apq.abs() <= tol * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation that annihilates the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let up = u.at(i, p);
                    let uq = u.at(i, q);
                    *u.at_mut(i, p) = cf * up - sf * uq;
                    *u.at_mut(i, q) = sf * up + cf * uq;
                }
                for i in 0..n {
                    let vp = v.at(i, p);
                    let vq = v.at(i, q);
                    *v.at_mut(i, p) = cf * vp - sf * vq;
                    *v.at_mut(i, q) = sf * vp + cf * vq;
                }
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
    }
    // Extract singular values (column norms of U) and normalize.
    let mut s: Vec<f32> = (0..n)
        .map(|j| {
            (0..m).map(|i| (u.at(i, j) as f64).powi(2)).sum::<f64>().sqrt() as f32
        })
        .collect();
    // Sort descending, permuting U and V consistently.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].partial_cmp(&s[i]).unwrap());
    let mut u_sorted = Matrix::zeros(m, n);
    let mut vt = Matrix::zeros(n, n);
    let mut s_sorted = vec![0.0f32; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        let sv = s[old_j];
        s_sorted[new_j] = sv;
        let inv = if sv > 1e-20 { 1.0 / sv } else { 0.0 };
        for i in 0..m {
            *u_sorted.at_mut(i, new_j) = u.at(i, old_j) * inv;
        }
        for i in 0..n {
            *vt.at_mut(new_j, i) = v.at(i, old_j);
        }
    }
    s = s_sorted;
    Svd { u: u_sorted, s, vt }
}

/// Symmetric Jacobi eigendecomposition of a small k×k PSD matrix.
/// Returns (eigenvalues desc, eigenvectors as columns). Allocating wrapper
/// over [`eigh_jacobi_with`].
pub fn eigh_jacobi(m_in: &Matrix) -> (Vec<f32>, Matrix) {
    let mut scratch = EighScratch::new();
    let mut evals = Vec::new();
    let mut evecs = Matrix::zeros(0, 0);
    eigh_jacobi_with(m_in, &mut scratch, &mut evals, &mut evecs);
    (evals, evecs)
}

/// Reusable working set for the small projected eigensolve.
struct EighScratch {
    a: Matrix,
    v: Matrix,
    diag: Vec<f32>,
    order: Vec<usize>,
}

impl EighScratch {
    fn new() -> Self {
        EighScratch {
            a: Matrix::zeros(0, 0),
            v: Matrix::zeros(0, 0),
            diag: Vec::new(),
            order: Vec::new(),
        }
    }
}

/// As [`eigh_jacobi`], with every buffer caller-provided: zero heap
/// allocations once `scratch`/`evals`/`evecs` have warmed up on the shape
/// (`sort_unstable` keeps the ordering pass allocation-free too).
fn eigh_jacobi_with(
    m_in: &Matrix,
    scratch: &mut EighScratch,
    evals: &mut Vec<f32>,
    evecs: &mut Matrix,
) {
    let k = m_in.rows;
    assert_eq!(m_in.rows, m_in.cols, "eigh needs a square matrix");
    scratch.a.copy_from(m_in);
    let a = &mut scratch.a;
    let v = &mut scratch.v;
    v.resize(k, k);
    v.data.fill(0.0);
    for i in 0..k {
        *v.at_mut(i, i) = 1.0;
    }
    for _sweep in 0..40 {
        let mut off = 0.0f64;
        for p in 0..k.saturating_sub(1) {
            for q in (p + 1)..k {
                let apq = a.at(p, q) as f64;
                off += apq * apq;
                if apq.abs() < 1e-12 {
                    continue;
                }
                let app = a.at(p, p) as f64;
                let aqq = a.at(q, q) as f64;
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                // Rotate rows/cols p, q of A and accumulate V.
                for i in 0..k {
                    let aip = a.at(i, p);
                    let aiq = a.at(i, q);
                    *a.at_mut(i, p) = cf * aip - sf * aiq;
                    *a.at_mut(i, q) = sf * aip + cf * aiq;
                }
                for i in 0..k {
                    let api = a.at(p, i);
                    let aqi = a.at(q, i);
                    *a.at_mut(p, i) = cf * api - sf * aqi;
                    *a.at_mut(q, i) = sf * api + cf * aqi;
                }
                for i in 0..k {
                    let vip = v.at(i, p);
                    let viq = v.at(i, q);
                    *v.at_mut(i, p) = cf * vip - sf * viq;
                    *v.at_mut(i, q) = sf * vip + cf * viq;
                }
            }
        }
        if off < 1e-18 {
            break;
        }
    }
    let order = &mut scratch.order;
    order.clear();
    order.extend(0..k);
    let diag = &mut scratch.diag;
    diag.clear();
    diag.extend((0..k).map(|i| a.at(i, i)));
    order.sort_unstable_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
    evals.clear();
    evals.extend(order.iter().map(|&i| diag[i].max(0.0)));
    evecs.resize(k, k);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..k {
            *evecs.at_mut(i, new_j) = v.at(i, old_j);
        }
    }
}

/// Reusable buffers for the randomized SVD: the Gaussian sketch, the
/// power-iteration range, the projected problem, and the QR scratch. A
/// workspace cycled through the same gradient shapes stops allocating
/// after the first refresh of each shape (EXPERIMENTS.md §Perf), so the
/// periodic GaLore subspace refresh no longer churns the allocator.
pub struct SvdWorkspace {
    omega: Matrix,     // (n, k) Gaussian sketch
    y: Matrix,         // (m, k) range sample A·Ω / A·Z
    z: Matrix,         // (n, k) power-iteration staging AᵀQ
    b: Matrix,         // (k, n) projected problem QᵀA
    bbt: Matrix,       // (k, k) Gram matrix B·Bᵀ
    evals: Vec<f32>,   // eigenvalues of B·Bᵀ, descending
    evecs: Matrix,     // (k, k) eigenvectors of B·Bᵀ
    e_r: Matrix,       // (k, r_eff) leading eigenvectors
    eigh: EighScratch, // k×k eigensolve working set
    qr: QrScratch,
}

impl SvdWorkspace {
    pub fn new() -> Self {
        SvdWorkspace {
            omega: Matrix::zeros(0, 0),
            y: Matrix::zeros(0, 0),
            z: Matrix::zeros(0, 0),
            b: Matrix::zeros(0, 0),
            bbt: Matrix::zeros(0, 0),
            evals: Vec::new(),
            evecs: Matrix::zeros(0, 0),
            e_r: Matrix::zeros(0, 0),
            eigh: EighScratch::new(),
            qr: QrScratch::new(),
        }
    }

    /// Squared singular-value estimates (the eigenvalues of B·Bᵀ,
    /// descending, clamped at zero) left behind by the most recent sketch
    /// or refresh through this workspace. The rank-adaptation policies
    /// (`optim::rank::RankSchedule::next_rank`) read the spectrum from
    /// here, so adapting costs nothing beyond the refresh the optimizer
    /// was doing anyway.
    pub fn sq_spectrum(&self) -> &[f32] {
        &self.evals
    }

    /// Pre-size the extraction buffer for a `(k, r)` worst case. Every
    /// other buffer here is sized by the sketch width alone and warms at
    /// the first (largest) refresh, but `e_r` is `(k, r_eff)` — under an
    /// adaptive schedule that shrinks and later *grows* the rank, a small
    /// first extraction would leave it under-sized. Called once per
    /// parameter by the adaptive GaLore path so rank growth stays
    /// allocation-free.
    pub fn warm_extract(&mut self, k: usize, r: usize) {
        self.e_r.resize(k, r.min(k).max(1));
    }
}

impl Default for SvdWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// Range finder + projected problem against the workspace: leaves Q in
/// `ws.qr.q`, B = QᵀA in `ws.b`, and the eigendecomposition of B·Bᵀ in
/// `ws.evals` / `ws.evecs`. Zero heap allocations once `ws` is warm on the
/// shape.
fn projected_eigh(a: &Matrix, k: usize, power_iters: usize, rng: &mut Rng, ws: &mut SvdWorkspace) {
    let n = a.cols;
    // Sketch the range: Y = A Omega, Omega (n, k) Gaussian.
    ws.omega.resize(n, k);
    rng.fill_normal(&mut ws.omega.data, 1.0);
    matmul_into(a, &ws.omega, &mut ws.y);
    qr_with(&ws.y, &mut ws.qr);
    for _ in 0..power_iters {
        // Power iteration with re-orthonormalization: Q <- qr(A (A^T Q)).
        matmul_at_b_into(a, &ws.qr.q, &mut ws.z); // (n, k)
        matmul_into(a, &ws.z, &mut ws.y); // (m, k)
        qr_with(&ws.y, &mut ws.qr);
    }
    // Small projected problem: B = Q^T A (k, n); eigendecompose B B^T (k, k).
    matmul_at_b_into(&ws.qr.q, a, &mut ws.b);
    matmul_a_bt_into(&ws.b, &ws.b, &mut ws.bbt);
    let SvdWorkspace { bbt, eigh, evals, evecs, .. } = ws;
    eigh_jacobi_with(bbt, eigh, evals, evecs);
}

/// Copy the leading `r_eff` eigenvector columns from `ws.evecs` into
/// `ws.e_r`.
fn stage_e_r(r_eff: usize, ws: &mut SvdWorkspace) {
    let SvdWorkspace { evecs, e_r, .. } = ws;
    let k = evecs.rows;
    e_r.resize(k, r_eff);
    for i in 0..k {
        e_r.row_mut(i).copy_from_slice(&evecs.row(i)[..r_eff]);
    }
}

/// Sketch oversampling used by every randomized-SVD entry point: the
/// range finder works on `r + SKETCH_OVERSAMPLE` columns (clamped to the
/// matrix size). The spectral rank policy can also *grow* a layer's rank
/// by up to this much per refresh, since the sketch sees that many
/// directions beyond the current rank.
pub const SKETCH_OVERSAMPLE: usize = 8;

/// Stage 1 of a split projector refresh: range-find + projected eigensolve
/// for a sketch of width `k`, leaving Q, B and the eigen-pairs in `ws`
/// (read the squared spectrum via [`SvdWorkspace::sq_spectrum`], then
/// materialize a basis with [`extract_left_subspace_into`]). Zero heap
/// allocations once `ws` is warm on the shape.
pub fn sketch_left_subspace_into(g: &Matrix, k: usize, rng: &mut Rng, ws: &mut SvdWorkspace) {
    projected_eigh(g, k, 2, rng, ws);
}

/// Stage 2: write the top-`r` left-subspace basis from the most recent
/// sketch in `ws` into `out` (clamped to the sketch width). `sketch` +
/// `extract` at the same `(k, r)` is bit-for-bit identical to
/// [`top_r_left_subspace_into`].
pub fn extract_left_subspace_into(r: usize, ws: &mut SvdWorkspace, out: &mut Matrix) {
    let r_eff = r.min(ws.evecs.cols).max(1);
    stage_e_r(r_eff, ws);
    matmul_into(&ws.qr.q, &ws.e_r, out);
}

/// Randomized truncated SVD (Halko–Martinsson–Tropp): returns the top-`r`
/// factors of `a` using `power_iters` subspace iterations and oversampling
/// (clamped to the matrix size). Thin wrapper over [`randomized_svd_with`]
/// with a throwaway workspace.
///
/// §Perf note: the projected problem is solved via a k×k symmetric Jacobi
/// eigendecomposition of B·Bᵀ (B = QᵀA) rather than a one-sided Jacobi SVD
/// of the k×n matrix B — that single change took the 512×1376 r=128
/// projector refresh from 12 s to the low tens of milliseconds.
pub fn randomized_svd(a: &Matrix, r: usize, power_iters: usize, rng: &mut Rng) -> Svd {
    randomized_svd_with(a, r, power_iters, rng, &mut SvdWorkspace::new())
}

/// As [`randomized_svd`], but sketch/power-iteration buffers come from the
/// caller's workspace; only the returned factors are freshly allocated.
/// Bit-for-bit identical to [`randomized_svd`] for the same RNG state.
pub fn randomized_svd_with(
    a: &Matrix,
    r: usize,
    power_iters: usize,
    rng: &mut Rng,
    ws: &mut SvdWorkspace,
) -> Svd {
    let (m, n) = a.shape();
    let k = (r + SKETCH_OVERSAMPLE).min(m).min(n);
    projected_eigh(a, k, power_iters, rng, ws);
    let r_eff = r.min(k);
    let s: Vec<f32> = ws.evals[..r_eff].iter().map(|&e| e.sqrt()).collect();
    stage_e_r(r_eff, ws);
    // U = Q @ E_r.
    let u = matmul(&ws.qr.q, &ws.e_r);
    // Vt = diag(1/s) E_r^T B.
    let mut vt = matmul_at_b(&ws.e_r, &ws.b);
    for (i, &sv) in s.iter().enumerate() {
        let inv = if sv > 1e-20 { 1.0 / sv } else { 0.0 };
        for x in vt.row_mut(i) {
            *x *= inv;
        }
    }
    Svd { u, s, vt }
}

/// The GaLore projector refresh (Eqn. 12/13): top-`r` left singular
/// subspace of the gradient. For wide gradients callers pass the gradient
/// as-is; for tall ones the optimizer transposes first (§4.2: only the
/// short side is projected).
pub fn top_r_left_subspace(g: &Matrix, r: usize, rng: &mut Rng) -> Matrix {
    randomized_svd(g, r, 2, rng).u
}

/// As [`top_r_left_subspace`], but writes the basis into `out` and draws
/// every intermediate buffer from `ws` — the steady-state refresh path of
/// the GaLore optimizer (zero allocations once `ws` and `out` are warm).
pub fn top_r_left_subspace_into(
    g: &Matrix,
    r: usize,
    rng: &mut Rng,
    ws: &mut SvdWorkspace,
    out: &mut Matrix,
) {
    let (m, n) = g.shape();
    let k = (r + SKETCH_OVERSAMPLE).min(m).min(n);
    sketch_left_subspace_into(g, k, rng, ws);
    extract_left_subspace_into(r, ws, out);
}

/// Stable rank ||A||_F^2 / ||A||_2^2 (used by the Lemma 3.3 experiment).
pub fn stable_rank(a: &Matrix, rng: &mut Rng) -> f64 {
    let fro2 = {
        let f = a.frobenius_norm() as f64;
        f * f
    };
    // Spectral norm via a few power iterations on A^T A.
    let (_, n) = a.shape();
    let mut v = Matrix::randn(n, 1, 1.0, rng);
    let mut sigma2 = 0.0f64;
    for _ in 0..50 {
        let av = matmul(a, &v); // (m, 1)
        let atav = matmul_at_b(a, &av); // (n, 1)
        let norm = atav.frobenius_norm();
        if norm < 1e-30 {
            return 0.0;
        }
        sigma2 = norm as f64;
        v = atav;
        v.scale(1.0 / norm);
    }
    fro2 / sigma2
}

/// Reconstruction helper for tests: U diag(s) Vt.
pub fn reconstruct(svd: &Svd) -> Matrix {
    let mut us = svd.u.clone();
    for i in 0..us.rows {
        for (j, &sv) in svd.s.iter().enumerate() {
            *us.at_mut(i, j) *= sv;
        }
    }
    matmul(&us, &svd.vt)
}

#[cfg(test)]
mod tests {
    use super::super::qr::qr;
    use super::*;
    use crate::tensor::matmul_a_bt;

    fn planted(m: usize, n: usize, spectrum: &[f32], rng: &mut Rng) -> (Matrix, Matrix) {
        // Random orthonormal U0 (m, k), V0 (n, k), A = U0 diag(s) V0^T.
        let k = spectrum.len();
        let u0 = qr(&Matrix::randn(m, k, 1.0, rng)).q;
        let v0 = qr(&Matrix::randn(n, k, 1.0, rng)).q;
        let mut us = u0.clone();
        for i in 0..m {
            for j in 0..k {
                *us.at_mut(i, j) *= spectrum[j];
            }
        }
        (matmul_a_bt(&us, &v0), u0)
    }

    #[test]
    fn jacobi_reconstructs() {
        let mut rng = Rng::new(0);
        for &(m, n) in &[(6, 4), (10, 10), (4, 7), (20, 5)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = svd_jacobi(&a);
            let rec = reconstruct(&svd);
            let mut err = a.clone();
            err.sub_assign(&rec);
            assert!(err.frobenius_norm() < 1e-3 * a.frobenius_norm().max(1.0));
        }
    }

    #[test]
    fn jacobi_orthonormal_factors() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(12, 8, 1.0, &mut rng);
        let svd = svd_jacobi(&a);
        let utu = matmul_at_b(&svd.u, &svd.u);
        let vvt = matmul_a_bt(&svd.vt, &svd.vt);
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - expect).abs() < 1e-3);
                assert!((vvt.at(i, j) - expect).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn jacobi_singular_values_descending_and_correct() {
        let mut rng = Rng::new(2);
        let (a, _) = planted(16, 12, &[9.0, 5.0, 2.0, 0.5], &mut rng);
        let svd = svd_jacobi(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        assert!((svd.s[0] - 9.0).abs() < 1e-2);
        assert!((svd.s[3] - 0.5).abs() < 1e-2);
        assert!(svd.s[4..].iter().all(|&s| s < 1e-3));
    }

    #[test]
    fn randomized_svd_finds_planted_subspace() {
        let mut rng = Rng::new(3);
        let (a, u0) = planted(80, 60, &[20.0, 15.0, 10.0, 8.0, 0.01, 0.005], &mut rng);
        let svd = randomized_svd(&a, 4, 2, &mut rng);
        // Principal angles between span(U[:, :4]) and planted top-4.
        let u0_top = u0.slice_cols(0, 4);
        let overlap = matmul_at_b(&u0_top, &svd.u); // (4, 4)
        let gram = matmul_at_b(&overlap, &overlap);
        for i in 0..4 {
            assert!(gram.at(i, i) > 0.98, "weak alignment: {}", gram.at(i, i));
        }
    }

    #[test]
    fn top_r_left_subspace_is_orthonormal() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(50, 70, 1.0, &mut rng);
        let p = top_r_left_subspace(&a, 8, &mut rng);
        assert_eq!(p.shape(), (50, 8));
        let ptp = matmul_at_b(&p, &p);
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((ptp.at(i, j) - expect).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn workspace_svd_matches_fresh_svd_bitwise() {
        // Same RNG stream, same input: the workspace path must be
        // bit-identical to the allocating path, across shape changes that
        // exercise buffer reuse.
        let mut ws = SvdWorkspace::new();
        for (i, &(m, n, r)) in [(40usize, 30usize, 4usize), (24, 64, 6), (40, 30, 4)]
            .iter()
            .enumerate()
        {
            let mut rng = Rng::new(100 + i as u64);
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let mut rng_a = Rng::new(7);
            let mut rng_b = Rng::new(7);
            let fresh = randomized_svd(&a, r, 2, &mut rng_a);
            let reused = randomized_svd_with(&a, r, 2, &mut rng_b, &mut ws);
            assert_eq!(fresh.u.data, reused.u.data, "{m}x{n} r{r}");
            assert_eq!(fresh.s, reused.s);
            assert_eq!(fresh.vt.data, reused.vt.data);

            let mut out = Matrix::zeros(0, 0);
            let mut rng_c = Rng::new(7);
            top_r_left_subspace_into(&a, r, &mut rng_c, &mut ws, &mut out);
            assert_eq!(out.data, fresh.u.data);
        }
    }

    #[test]
    fn stable_rank_of_rank_one_is_one() {
        let mut rng = Rng::new(5);
        let u = Matrix::randn(30, 1, 1.0, &mut rng);
        let v = Matrix::randn(20, 1, 1.0, &mut rng);
        let a = matmul_a_bt(&u, &v);
        let sr = stable_rank(&a, &mut rng);
        assert!((sr - 1.0).abs() < 0.05, "sr = {sr}");
    }

    #[test]
    fn stable_rank_of_identity_is_n() {
        let mut rng = Rng::new(6);
        let a = Matrix::eye(16);
        let sr = stable_rank(&a, &mut rng);
        assert!((sr - 16.0).abs() < 0.5, "sr = {sr}");
    }
}
